"""Core TEDA correctness: Algorithm 1 fidelity + form equivalences."""
import numpy as np
import jax
import jax.numpy as jnp

from conftest import given_or_cases

from repro.core import (teda_init, teda_step, teda_stream, teda_scan,
                        teda_threshold)
from repro.core.teda import teda_numpy_loop


def _stream(T, N, seed=0, spike=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(T, N)).astype(np.float32)
    if spike is not None:
        lo, hi, amp = spike
        x[lo:hi] += amp
    return x


# ---------------------------------------------------------------- fidelity
def test_first_sample_branch():
    """Algorithm 1 lines 3..5: k=1 sets mu<-x, var<-0, no outlier."""
    st0 = teda_init((), 3)
    x1 = jnp.asarray([1.0, -2.0, 5.0])
    st1, out = teda_step(st0, x1)
    np.testing.assert_allclose(st1.mean, x1)
    assert float(st1.var) == 0.0
    assert float(st1.k) == 1.0
    assert not bool(out.outlier)


def test_recursions_match_closed_form():
    """eq (2) mean equals the batch mean; eq (5)-(6) algebra."""
    x = _stream(64, 4, seed=3)
    state, out = teda_stream(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(state.mean), x.mean(0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out.zeta), np.asarray(out.ecc) / 2)
    k = np.arange(1, 65)
    np.testing.assert_allclose(np.asarray(out.threshold),
                               (3.0 ** 2 + 1) / (2 * k), rtol=1e-6)


def test_stream_matches_python_loop():
    x = _stream(500, 2, seed=1, spike=(200, 215, 7.0))
    ref = teda_numpy_loop(x, 3.0)
    _, out = teda_stream(jnp.asarray(x), 3.0)
    np.testing.assert_allclose(np.asarray(out.ecc), ref["ecc"], rtol=2e-4,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out.outlier), ref["outlier"])
    assert ref["outlier"][200:215].sum() > 0  # the fault is detected


def test_scan_equals_stream():
    """Beyond-paper parallel form == paper-faithful sequential form."""
    x = _stream(333, 5, seed=2, spike=(100, 120, 5.0))
    _, seq = teda_stream(jnp.asarray(x), 2.5)
    _, par = teda_scan(jnp.asarray(x), 2.5)
    np.testing.assert_allclose(np.asarray(par.ecc), np.asarray(seq.ecc),
                               rtol=2e-4, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(par.outlier),
                                  np.asarray(seq.outlier))


def test_state_continuation():
    """Scanning two halves with carried state == scanning the whole."""
    x = _stream(256, 3, seed=4)
    xj = jnp.asarray(x)
    full_state, full = teda_stream(xj)
    st1, _ = teda_stream(xj[:100])
    st2, second = teda_stream(xj[100:], state=st1)
    np.testing.assert_allclose(np.asarray(st2.mean),
                               np.asarray(full_state.mean), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st2.var),
                               np.asarray(full_state.var), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(second.ecc),
                               np.asarray(full.ecc)[100:], rtol=1e-4,
                               atol=1e-6)


def test_scan_continuation():
    x = _stream(200, 2, seed=5)
    xj = jnp.asarray(x)
    st1, out1 = teda_scan(xj[:77])
    st2, out2 = teda_scan(xj[77:], state=st1)
    _, full = teda_scan(xj)
    np.testing.assert_allclose(np.asarray(out2.ecc),
                               np.asarray(full.ecc)[77:], rtol=1e-4,
                               atol=1e-6)


def test_batched_streams_are_independent():
    """Leading batch dims = independent streams (vmap semantics)."""
    xa = _stream(128, 2, seed=6)
    xb = _stream(128, 2, seed=7, spike=(50, 60, 9.0))
    both = jnp.stack([xa, xb], axis=1)  # (T, 2, N)
    _, out = teda_stream(both)
    _, oa = teda_stream(jnp.asarray(xa))
    _, ob = teda_stream(jnp.asarray(xb))
    np.testing.assert_allclose(np.asarray(out.ecc)[:, 0],
                               np.asarray(oa.ecc), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out.ecc)[:, 1],
                               np.asarray(ob.ecc), rtol=1e-6)


def test_constant_stream_never_outlier():
    """Zero variance: eq (1) guard; ecc = 1/k, never above threshold."""
    x = jnp.ones((50, 2))
    _, out = teda_stream(x, 3.0)
    assert not bool(jnp.any(out.outlier))
    np.testing.assert_allclose(np.asarray(out.ecc),
                               1.0 / np.arange(1, 51), rtol=1e-6)


def test_m_controls_sensitivity():
    x = _stream(400, 1, seed=8, spike=(300, 310, 4.0))
    _, loose = teda_stream(jnp.asarray(x), m=5.0)
    _, tight = teda_stream(jnp.asarray(x), m=1.0)
    assert int(tight.outlier.sum()) >= int(loose.outlier.sum())


def test_jit_and_grad_safety():
    """teda_scan must be jittable and differentiable (guard integration)."""
    x = jnp.asarray(_stream(64, 2, seed=9))
    f = jax.jit(lambda v: teda_scan(v)[1].ecc.sum())
    g = jax.grad(f)(x)
    assert jnp.all(jnp.isfinite(g))


# ------------------------------------------------------------- properties
@given_or_cases(
    "t,n,seed,m",
    [(2, 1, 0, 0.5), (37, 3, 123, 3.0), (111, 2, 999, 1.5),
     (200, 6, 7, 6.0), (64, 4, 2 ** 16, 2.0)],
    lambda st: dict(t=st.integers(2, 200), n=st.integers(1, 6),
                    seed=st.integers(0, 2 ** 16), m=st.floats(0.5, 6.0)),
    max_examples=25)
def test_property_equivalence_and_invariants(t, n, seed, m):
    x = _stream(t, n, seed=seed)
    ref = teda_numpy_loop(x, m)
    _, seq = teda_stream(jnp.asarray(x), m)
    _, par = teda_scan(jnp.asarray(x), m)
    # invariant: zeta sums telescoping — sum of ecc over k samples == k * E
    # (eq 5 normalization: mean of zeta over any prefix is 1/2... checked
    # via the loop oracle instead: forms agree and verdicts identical)
    np.testing.assert_allclose(np.asarray(seq.ecc), ref["ecc"], rtol=5e-3,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(par.ecc), ref["ecc"], rtol=5e-3,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(seq.outlier), ref["outlier"])
    # typicality complement, eq (4)
    np.testing.assert_allclose(np.asarray(seq.typ),
                               1.0 - np.asarray(seq.ecc), rtol=1e-6)
    # eccentricity positivity and normalization bound (ecc in (0, 2])
    assert np.all(np.asarray(seq.ecc) > 0)


@given_or_cases(
    "seed,amp", [(0, 20.0), (123, 45.0), (2 ** 16, 80.0)],
    lambda st: dict(seed=st.integers(0, 2 ** 16),
                    amp=st.floats(20.0, 80.0)),
    max_examples=15)
def test_property_large_spike_always_detected(seed, amp):
    """A >>m-sigma spike after burn-in must trip eq (6) with m=3."""
    x = _stream(300, 2, seed=seed)
    x[250] += amp
    _, out = teda_stream(jnp.asarray(x), 3.0)
    assert bool(out.outlier[250])


def test_threshold_helper():
    np.testing.assert_allclose(teda_threshold(jnp.asarray(10.0), 3.0), 0.5)


def test_detectability_bound_k_le_m_squared():
    """zeta <= (k+1)/(2k) (eq 3 absorbs the sample), so eq (6) with m
    cannot trip at k <= m^2 — DESIGN.md §7. Verified with an extreme
    spike at every early position."""
    for spike_at in range(1, 9):  # k = spike_at + 1 <= 9 = m^2
        x = np.ones((10, 1), np.float32) * 5.0
        x[spike_at] = 1e6
        _, out = teda_stream(jnp.asarray(x[:spike_at + 1]), m=3.0)
        assert not bool(out.outlier[spike_at]), spike_at
    # but at k = 10 > m^2 the same spike trips
    x = np.ones((11, 1), np.float32) * 5.0
    x[:10] += 0.01 * np.random.default_rng(0).normal(size=(10, 1))
    x[10] = 1e6
    _, out = teda_stream(jnp.asarray(x), m=3.0)
    assert bool(out.outlier[10])
