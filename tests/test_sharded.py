"""Fleet-scale sharded pool acceptance suite (ISSUE 9).

The behavior contract under test: a K-shard `ShardedPool` is bit-exact
with a single-device `SlotPool` on the pallas-q path for ANY routing
and ANY migration schedule — sharding moves placement, never
arithmetic.  Around that contract: consistent-hash ring stability (a
fleet growing N→N+1 remaps <= 2/N of streams), live migration carrying
the ensemble aux column exactly, per-shard PoolFull backpressure that
leaves other shards' verdicts untouched, the sharded
`BatchingScheduler`/`serve_streams` path pinned deterministic across
runs and pipeline depths, and the virtual-device topology CI runs it
all on (`REPRO_VIRTUAL_DEVICES=8`).
"""
import numpy as np
import pytest

from conftest import given_or_cases, virtual_devices

from repro.engine import (HashRing, PoolFull, ShardedPool, SlotPool,
                          stable_hash)
from repro.fixedpoint import QFormat
from repro.launch.batching import BatchingScheduler, Request
from repro.launch.serve import serve_streams
from repro.obs import MetricsRegistry

FMT = QFormat(32, 20)


# ------------------------------------------------------------ hash ring
def test_stable_hash_is_process_stable():
    # pinned digests: a restart (new PYTHONHASHSEED) must not re-route
    assert stable_hash("tenant-a") == stable_hash("tenant-a")
    assert stable_hash("tenant-a") != stable_hash("tenant-b")
    assert 0 <= stable_hash("x") < 2 ** 64


def test_ring_assignment_is_deterministic_across_instances():
    a = HashRing(range(4))
    b = HashRing(range(4))
    keys = [f"r{i}" for i in range(500)]
    assert [a.assign(k) for k in keys] == [b.assign(k) for k in keys]


def test_ring_spreads_keys_over_every_shard():
    ring = HashRing(range(4))
    owners = {ring.assign(f"r{i}") for i in range(2000)}
    assert owners == {0, 1, 2, 3}


@given_or_cases(
    "n,seed", [(2, 0), (4, 1), (8, 2)],
    lambda st: {"n": st.integers(2, 12), "seed": st.integers(0, 99)},
    max_examples=20)
def test_ring_grow_remaps_at_most_2_over_n(n, seed):
    keys = [f"stream-{seed}-{i}" for i in range(3000)]
    ring = HashRing(range(n))
    before = {k: ring.assign(k) for k in keys}
    ring.add(n)
    moved = [k for k in keys if ring.assign(k) != before[k]]
    # ~1/(n+1) expected; 2/n is the generous stability bound the
    # ISSUE pins (vnodes smooth the arcs enough to hold it)
    assert len(moved) / len(keys) <= 2.0 / n
    # every moved key landed on the new shard — growth never shuffles
    # streams between the old shards
    assert all(ring.assign(k) == n for k in moved)


def test_ring_remove_only_moves_the_removed_shards_keys():
    ring = HashRing(range(4))
    keys = [f"r{i}" for i in range(1000)]
    before = {k: ring.assign(k) for k in keys}
    ring.remove(2)
    for k in keys:
        if before[k] != 2:
            assert ring.assign(k) == before[k]
        else:
            assert ring.assign(k) != 2


def test_ring_validation():
    ring = HashRing(range(2))
    with pytest.raises(ValueError, match="already on the ring"):
        ring.add(1)
    with pytest.raises(ValueError, match="not on the ring"):
        ring.remove(7)
    with pytest.raises(ValueError, match="vnodes"):
        HashRing(range(2), vnodes=0)
    with pytest.raises(ValueError, match="empty ring"):
        HashRing().assign("x")


# --------------------------------------------------- pool fundamentals
def test_sharded_pool_routes_and_places():
    pool = ShardedPool("scan", shards=3, buckets=(4, 8))
    for i in range(6):
        rid = f"r{i}"
        shard, slot = pool.acquire(rid)
        assert shard == pool.route(rid)
        assert pool.lookup(rid) == (shard, slot)
    assert pool.occupancy == 6
    assert sum(pool.occupancies()) == 6
    assert pool.imbalance == max(pool.occupancies()) - min(
        pool.occupancies())
    st = pool.stats()
    assert st["shards"] == 3 and st["occupancy"] == 6
    assert len(st["per_shard"]) == 3


def test_sharded_pool_validation():
    with pytest.raises(ValueError, match="shards"):
        ShardedPool("scan", shards=0)
    with pytest.raises(ValueError, match="rebalance_threshold"):
        ShardedPool("scan", shards=2, rebalance_threshold=1)
    pool = ShardedPool("scan", shards=2, buckets=(2,))
    pool.acquire("a")
    with pytest.raises(ValueError, match="already attached"):
        pool.acquire("a")
    with pytest.raises(ValueError, match="out of range"):
        pool.acquire("b", shard=5)
    with pytest.raises(KeyError, match="unknown stream"):
        pool.lookup("ghost")
    with pytest.raises(KeyError, match="unknown stream"):
        pool.release("ghost")
    with pytest.raises(ValueError, match="out of range"):
        pool.migrate("a", 9)


def test_release_frees_the_routed_shard():
    pool = ShardedPool("scan", shards=2, buckets=(2,))
    s, _ = pool.acquire("a")
    pool.release("a")
    assert pool.occupancy == 0
    # the slot is reusable on the same shard
    assert pool.acquire("a") == (s, 0) or pool.occupancy == 1


# ------------------------------------------- bit-exactness under churn
def _lockstep_compare(backend, seed, shards, fmt=None, chunks=4, t=8,
                      n_streams=6, **opts):
    """Feed identical streams to one SlotPool and one K-shard
    ShardedPool in lockstep, randomly migrating / detaching /
    re-attaching sharded streams between chunks; every surviving
    stream's outlier+ecc columns must match bit-for-bit."""
    rng = np.random.default_rng(seed)
    rids = [f"s{i}" for i in range(n_streams)]
    data = {}
    for i, rid in enumerate(rids):
        d = rng.normal(size=(chunks * t,)).astype(np.float32)
        if i % 2 == 0:
            d[chunks * t // 2] += 20.0  # loud burst: non-trivial flags
        data[rid] = d
    single = SlotPool(backend, buckets=(4, 8), fmt=fmt, **opts)
    sharded = ShardedPool(backend, shards=shards, buckets=(4, 8),
                          fmt=fmt, **opts)
    s_slots = {rid: int(single.acquire(1)[0]) for rid in rids}
    for rid in rids:
        sharded.acquire(rid)
    for c in range(chunks):
        if c:  # churn between chunks
            for _ in range(3):
                rid = rids[int(rng.integers(n_streams))]
                try:
                    sharded.migrate(rid, int(rng.integers(shards)))
                except PoolFull:
                    pass
            if rng.random() < 0.5:  # detach + cold re-attach, both pools
                rid = rids[int(rng.integers(n_streams))]
                single.release([s_slots[rid]])
                sharded.release(rid)
                s_slots[rid] = int(single.acquire(1)[0])
                sharded.acquire(rid)
        xs = np.zeros((t, single.capacity), np.float32)
        vl = np.zeros((single.capacity,), np.int32)
        for rid in rids:
            xs[:, s_slots[rid]] = data[rid][c * t:(c + 1) * t]
            vl[s_slots[rid]] = t
        ref = single.process(xs, valid_lens=vl)
        ref_out = np.asarray(ref["outlier"])
        ref_ecc = np.asarray(ref["ecc"])
        by_shard = {}
        for rid in rids:
            s, slot = sharded.lookup(rid)
            by_shard.setdefault(s, []).append((rid, slot))
        for s, members in sorted(by_shard.items()):
            cap = sharded.shard_capacity(s)
            x = np.zeros((t, cap), np.float32)
            v = np.zeros((cap,), np.int32)
            for rid, slot in members:
                x[:, slot] = data[rid][c * t:(c + 1) * t]
                v[slot] = t
            out = sharded.process_shard(s, x, valid_lens=v)
            got_out = np.asarray(out["outlier"])
            got_ecc = np.asarray(out["ecc"])
            for rid, slot in members:
                np.testing.assert_array_equal(
                    got_out[:, slot], ref_out[:, s_slots[rid]],
                    err_msg=f"outlier diverged for {rid} chunk {c}")
                np.testing.assert_array_equal(
                    got_ecc[:, slot], ref_ecc[:, s_slots[rid]],
                    err_msg=f"ecc diverged for {rid} chunk {c}")
    assert sharded.migrations > 0  # the schedule actually moved slots


@given_or_cases(
    "seed,shards", [(0, 2), (1, 3), (2, 4)],
    lambda st: {"seed": st.integers(0, 999),
                "shards": st.integers(2, 4)},
    max_examples=8)
def test_sharded_bitexact_pallas_q_under_migration_churn(seed, shards):
    """THE contract: K shards == one pool, exact Q-format bits, for a
    randomized routing + migration + attach/detach schedule."""
    _lockstep_compare("pallas-q", seed, shards, fmt=FMT,
                      interpret=True)


def test_sharded_bitexact_scan_backend():
    _lockstep_compare("scan", seed=7, shards=2)


# ------------------------------------------------------- live migration
def test_migrate_is_noop_to_same_shard():
    pool = ShardedPool("scan", shards=2, buckets=(4,))
    s, slot = pool.acquire("a")
    assert pool.migrate("a", s) == slot
    assert pool.migrations == 0


def test_migration_carries_ensemble_aux_exactly():
    """A mid-window zscore/ensemble slot keeps its aux state rows,
    per-slot m, detector weights and threshold bit-for-bit across the
    move — and its future verdicts match the unmigrated twin."""
    opts = dict(shards=2, buckets=(2, 4), block_t=8, interpret=True)
    moved = ShardedPool("ensemble", **opts)
    still = ShardedPool("ensemble", **opts)
    rng = np.random.default_rng(5)
    x = rng.normal(size=(24,)).astype(np.float32)
    x[17] += 25.0
    for pool in (moved, still):
        pool.acquire("a", m=2.5, detectors=("zscore", "teda"),
                     vote="any")

    def feed(pool, samples):
        s, slot = pool.lookup("a")
        cap = pool.shard_capacity(s)
        chunk = np.zeros((len(samples), cap), np.float32)
        vl = np.zeros((cap,), np.int32)
        chunk[:, slot] = samples
        vl[slot] = len(samples)
        out = pool.process_shard(s, chunk, valid_lens=vl)
        return (np.asarray(out["outlier"])[:, slot],
                np.asarray(out["ecc"])[:, slot])

    feed(moved, x[:12]), feed(still, x[:12])  # mid-window warm state
    src_s, src_slot = moved.lookup("a")
    eng = moved.pools[src_s].engine
    pre = {
        "k": np.asarray(eng.state.k)[src_slot],
        "mean": np.asarray(eng.state.mean)[src_slot],
        "var": np.asarray(eng.state.var)[src_slot],
        "aux": np.asarray(eng.state.aux)[:, src_slot].copy(),
        "m": eng._m[src_slot],
        "det_w": eng._det_w[:, src_slot].copy(),
        "det_thr": eng._det_thr[src_slot],
    }
    assert pre["aux"].any()  # mid-window: zscore aux is warm, not zero
    dst = 1 - src_s
    new_slot = moved.migrate("a", dst)
    deng = moved.pools[dst].engine
    np.testing.assert_array_equal(
        np.asarray(deng.state.k)[new_slot], pre["k"])
    np.testing.assert_array_equal(
        np.asarray(deng.state.mean)[new_slot], pre["mean"])
    np.testing.assert_array_equal(
        np.asarray(deng.state.var)[new_slot], pre["var"])
    np.testing.assert_array_equal(
        np.asarray(deng.state.aux)[:, new_slot], pre["aux"])
    assert deng._m[new_slot] == pre["m"]
    np.testing.assert_array_equal(deng._det_w[:, new_slot],
                                  pre["det_w"])
    assert deng._det_thr[new_slot] == pre["det_thr"]
    # verdicts after the move == the twin that never moved
    out_m, ecc_m = feed(moved, x[12:])
    out_s, ecc_s = feed(still, x[12:])
    np.testing.assert_array_equal(out_m, out_s)
    np.testing.assert_array_equal(ecc_m, ecc_s)
    assert out_m.any()  # the burst at x[17] actually flagged


def test_migrate_to_full_shard_leaves_stream_in_place():
    pool = ShardedPool("scan", shards=2, buckets=(2,))
    pool.acquire("a", shard=0)
    pool.acquire("b", shard=1)
    pool.acquire("c", shard=1)  # shard 1 now at its top bucket
    with pytest.raises(PoolFull, match="migration target shard 1"):
        pool.migrate("a", 1)
    assert pool.lookup("a")[0] == 0  # untouched
    assert pool.migrations == 0


def test_rebalancer_flattens_occupancy_deterministically():
    pool = ShardedPool("scan", shards=2, buckets=(8,))
    for i in range(6):
        pool.acquire(f"r{i}", shard=0)
    assert pool.occupancies() == [6, 0]
    moves = pool.rebalance()
    assert pool.imbalance < pool.rebalance_threshold
    # deterministic candidate order: lexicographically smallest rids
    assert [m[0] for m in moves] == ["r0", "r1"] or len(moves) >= 2
    twin = ShardedPool("scan", shards=2, buckets=(8,))
    for i in range(6):
        twin.acquire(f"r{i}", shard=0)
    assert twin.rebalance() == moves


def test_rebalancer_respects_avoid_set():
    pool = ShardedPool("scan", shards=2, buckets=(8,))
    for i in range(4):
        pool.acquire(f"r{i}", shard=0)
    moves = pool.rebalance(avoid={f"r{i}" for i in range(4)})
    assert moves == []  # everything movable pinned: try next tick
    assert pool.occupancies() == [4, 0]


def test_migration_metrics_and_events():
    reg = MetricsRegistry()
    from repro.obs import EventBus
    bus = EventBus()
    seen = []
    bus.attach(seen.append)
    pool = ShardedPool("scan", shards=2, buckets=(4,),
                       registry=reg, events=bus)
    pool.acquire("a", shard=0)
    pool.migrate("a", 1, tick=42)
    assert pool.migrations == 1
    ev = [e for e in seen if e.kind == "shard_migrated"]
    assert len(ev) == 1
    assert ev[0].rid == "a" and ev[0].tick == 42
    assert ev[0].data["src"] == 0 and ev[0].data["dst"] == 1
    snap = reg.snapshot()
    assert any("sharded_migrations_total" in k for k in snap)


# --------------------------------------------- per-shard backpressure
def test_pool_full_on_one_shard_spares_the_others():
    """Filling one shard's ladder backpressures streams routed there
    and does not perturb another shard's verdicts by one bit."""
    pool = ShardedPool("scan", shards=2, buckets=(2,))
    by_shard = {0: [], 1: []}
    i = 0
    while len(by_shard[0]) < 3 or len(by_shard[1]) < 1:
        rid = f"t{i}"
        by_shard[pool.route(rid)].append(rid)
        i += 1
    for rid in by_shard[0][:2]:
        pool.acquire(rid)
    lone = by_shard[1][0]
    pool.acquire(lone)
    with pytest.raises(PoolFull, match="shard 0"):
        pool.acquire(by_shard[0][2])  # shard 0 ladder is full
    # shard 1's stream serves bit-exact with a solo single pool
    rng = np.random.default_rng(3)
    x = rng.normal(size=(16,)).astype(np.float32)
    x[11] += 30.0
    solo = SlotPool("scan", buckets=(2,))
    solo_slot = int(solo.acquire(1)[0])
    s, slot = pool.lookup(lone)
    cap = pool.shard_capacity(s)
    chunk = np.zeros((16, cap), np.float32)
    vl = np.zeros((cap,), np.int32)
    chunk[:, slot] = x
    vl[slot] = 16
    ref = np.zeros((16, solo.capacity), np.float32)
    rvl = np.zeros((solo.capacity,), np.int32)
    ref[:, solo_slot] = x
    rvl[solo_slot] = 16
    got = pool.process_shard(s, chunk, valid_lens=vl)
    want = solo.process(ref, valid_lens=rvl)
    np.testing.assert_array_equal(
        np.asarray(got["outlier"])[:, slot],
        np.asarray(want["outlier"])[:, solo_slot])


# ------------------------------------------------- sharded scheduler
def _interleave(sched, specs, max_ticks=500):
    order = list(specs)
    fed = {rid: 0 for rid in specs}
    closed = set()
    for tick in range(max_ticks):
        if tick < len(order):
            rid = order[tick]
            h, live, m = specs[rid]
            assert sched.submit(Request(rid, h, m=m))
            if not live.size:
                sched.close(rid)
                closed.add(rid)
        for rid, (h, live, m) in specs.items():
            if rid not in sched.stats_by_rid or rid in closed:
                continue
            if fed[rid] < live.size:
                sched.feed(rid, live[fed[rid]:fed[rid] + 1])
                fed[rid] += 1
            if fed[rid] == live.size:
                sched.close(rid)
                closed.add(rid)
        if len(closed) == len(specs):
            break
        sched.step()
    sched.drain()


def _churn_specs(n, seed):
    rng = np.random.default_rng(seed)
    specs = {}
    for i in range(n):
        h = rng.normal(size=(int(rng.integers(4, 24)),)).astype(
            np.float32)
        live = rng.normal(size=(int(rng.integers(0, 8)),)).astype(
            np.float32)
        if live.size and i % 3 == 0:
            live[live.size // 2] += 25.0
        specs[f"r{i}"] = (h, live, [1.5, 3.0, 6.0][i % 3])
    return specs


def test_sharded_scheduler_bitexact_with_single_pool():
    """The scheduler contract on the Q path: shards=2 with forced
    rebalancer migrations returns the same per-sample verdict bits as
    the single-pool scheduler."""
    specs = _churn_specs(6, seed=11)
    kw = dict(buckets=(2, 4), chunk_t=8, fmt=FMT, interpret=True,
              collect=True, measure_latency=False)
    single = BatchingScheduler("pallas-q", **kw)
    sharded = BatchingScheduler("pallas-q", shards=2,
                                rebalance_every=2, **kw)
    _interleave(single, specs)
    _interleave(sharded, specs)
    for rid in specs:
        a = single.results(rid)
        b = sharded.results(rid)
        np.testing.assert_array_equal(
            a["outlier"], b["outlier"],
            err_msg=f"verdicts diverged for {rid}")
        np.testing.assert_array_equal(a["ecc"], b["ecc"])
    st = sharded.stats()
    assert st["shards"] == 2
    assert st["pool"]["shards"] == 2


def test_sharded_scheduler_rebalances_under_skew():
    """Rids hand-picked onto one ring shard: the rebalancer must move
    some mid-run, and verdicts must still match the single pool."""
    probe = ShardedPool("scan", shards=2, buckets=(8,))
    rng = np.random.default_rng(4)
    rids, i = [], 0
    while len(rids) < 5:
        if probe.route(f"skew{i}") == 0:
            rids.append(f"skew{i}")
        i += 1
    specs = {rid: (rng.normal(size=(12,)).astype(np.float32),
                   rng.normal(size=(4,)).astype(np.float32), 3.0)
             for rid in rids}
    kw = dict(buckets=(8,), chunk_t=8, collect=True,
              measure_latency=False)
    single = BatchingScheduler("scan", **kw)
    sharded = BatchingScheduler("scan", shards=2, rebalance_every=2,
                                **kw)
    _interleave(single, specs)
    _interleave(sharded, specs)
    assert sharded.pool.migrations > 0  # skew actually triggered moves
    assert sharded.stats()["migrations"] > 0
    for rid in specs:
        np.testing.assert_array_equal(
            single.results(rid)["outlier"],
            sharded.results(rid)["outlier"])
    moved = [rid for rid in rids
             if sharded.telemetry(rid).migrations > 0]
    assert moved  # per-request telemetry recorded the moves


def test_sharded_scheduler_full_shard_blocks_only_that_class():
    """One shard's ladder filling up must not wedge admission for
    streams routed to shards with room."""
    probe = ShardedPool("scan", shards=2, buckets=(2,))
    on0 = [f"c{i}" for i in range(40) if probe.route(f"c{i}") == 0]
    on1 = [f"c{i}" for i in range(40) if probe.route(f"c{i}") == 1]
    sched = BatchingScheduler("scan", shards=2, buckets=(2,),
                              chunk_t=8, queue_limit=16,
                              collect=True, measure_latency=False)
    rng = np.random.default_rng(9)
    rids = on0[:3] + on1[:1]  # 3 onto the 2-slot shard + 1 elsewhere
    for rid in rids:
        assert sched.submit(Request(
            rid, rng.normal(size=(12,)).astype(np.float32)))
        sched.close(rid)
    sched.drain()
    assert sched.completed == len(rids)
    for rid in rids:
        assert sched.telemetry(rid).samples == 12


def test_scheduler_shard_validation():
    with pytest.raises(ValueError, match="shards"):
        BatchingScheduler("scan", shards=0)
    with pytest.raises(ValueError, match="rebalance_every"):
        BatchingScheduler("scan", shards=2, rebalance_every=-1)


# ------------------------------------------------ gateway determinism
def test_gateway_determinism_across_runs_and_depths():
    """serve_streams with sharding on: identical per-request flags and
    det_flags across two identical runs AND across pipeline_depth
    {1, 4} — pins the async+sharded path against nondeterministic
    retirement ordering."""
    rng = np.random.default_rng(21)
    streams = []
    for i in range(6):
        h = rng.normal(size=(10,)).astype(np.float32)
        lv = rng.normal(size=(6,)).astype(np.float32)
        if i % 2 == 0:
            lv[3] += 25.0
        streams.append((f"t{i}", h, lv, None))
    kw = dict(backend="scan", buckets=(2, 4), chunk_t=8, shards=2,
              rebalance_every=2, measure_latency=False)
    runs = [serve_streams(streams, pipeline_depth=1, **kw),
            serve_streams(streams, pipeline_depth=1, **kw),
            serve_streams(streams, pipeline_depth=4, **kw)]
    base = runs[0]
    assert base["shards"] == 2
    for other in runs[1:]:
        assert other["flagged"] == base["flagged"]
        for rid, pr in base["per_request"].items():
            opr = other["per_request"][rid]
            assert opr["flags"] == pr["flags"], rid
            assert opr["det_flags"] == pr["det_flags"], rid
            assert opr["samples"] == pr["samples"], rid


# ------------------------------------------------- virtual devices
def test_virtual_device_mesh_fanout_bitexact():
    """>= 4 virtual devices (REPRO_VIRTUAL_DEVICES=8 in CI): 2 shards
    x 2-device channel fan-out meshes must match the single-device
    pool exactly."""
    devs = virtual_devices(4)
    single = SlotPool("scan", buckets=(4, 8))
    pool = ShardedPool("scan", shards=2, buckets=(4, 8),
                       devices=devs[:4])
    rng = np.random.default_rng(13)
    rids = [f"v{i}" for i in range(5)]
    s_slots = {rid: int(single.acquire(1)[0]) for rid in rids}
    for rid in rids:
        pool.acquire(rid)
    x = rng.normal(size=(16, len(rids))).astype(np.float32)
    x[9, 0] += 30.0
    xs = np.zeros((16, single.capacity), np.float32)
    vl = np.zeros((single.capacity,), np.int32)
    for j, rid in enumerate(rids):
        xs[:, s_slots[rid]] = x[:, j]
        vl[s_slots[rid]] = 16
    ref = np.asarray(single.process(xs, valid_lens=vl)["outlier"])
    by_shard = {}
    for j, rid in enumerate(rids):
        s, slot = pool.lookup(rid)
        by_shard.setdefault(s, []).append((rid, slot, j))
    for s, members in by_shard.items():
        cap = pool.shard_capacity(s)
        chunk = np.zeros((16, cap), np.float32)
        v = np.zeros((cap,), np.int32)
        for rid, slot, j in members:
            chunk[:, slot] = x[:, j]
            v[slot] = 16
        got = np.asarray(pool.process_shard(
            s, chunk, valid_lens=v)["outlier"])
        for rid, slot, j in members:
            np.testing.assert_array_equal(got[:, slot],
                                          ref[:, s_slots[rid]])


def test_virtual_device_sharded_scheduler_end_to_end():
    devs = virtual_devices(4)
    specs = _churn_specs(5, seed=17)
    kw = dict(buckets=(4, 8), chunk_t=8, collect=True,
              measure_latency=False)
    single = BatchingScheduler("scan", **kw)
    sharded = BatchingScheduler("scan", shards=2, shard_devices=devs[:4],
                                rebalance_every=2, **kw)
    _interleave(single, specs)
    _interleave(sharded, specs)
    for rid in specs:
        np.testing.assert_array_equal(
            single.results(rid)["outlier"],
            sharded.results(rid)["outlier"])


def test_uneven_device_split_is_rejected():
    devs = virtual_devices(4)
    with pytest.raises(ValueError, match="split evenly"):
        ShardedPool("scan", shards=3, devices=devs[:4])
    with pytest.raises(ValueError, match="not divisible"):
        ShardedPool("scan", shards=2, buckets=(3, 6),
                    devices=devs[:4])
