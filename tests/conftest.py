"""Shared test helpers."""
import pytest


def given_or_cases(argnames, cases, strategies, max_examples=100):
    """Property test when hypothesis is installed, fixed cases otherwise.

    `strategies` is a callable receiving `hypothesis.strategies` and
    returning the kwargs for `@given`; `cases` are
    `@pytest.mark.parametrize(argnames, ...)` tuples in the same order,
    used on minimal installs so the module still collects and runs.
    """
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        return pytest.mark.parametrize(argnames, cases)

    def deco(fn):
        return settings(max_examples=max_examples,
                        deadline=None)(given(**strategies(st))(fn))

    return deco
