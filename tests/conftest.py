"""Shared test helpers."""
import os
import sys

import pytest

# Virtual-device harness for the sharding suite: when the run opts in
# via REPRO_VIRTUAL_DEVICES=N, split the host CPU into N XLA devices
# *before* jax initializes its backend (the flag is inert afterwards —
# hence env-guarded module-level setup, not a fixture).  Regular runs
# see the usual single device and every tier-1 result is untouched.
_N_VIRTUAL = int(os.environ.get("REPRO_VIRTUAL_DEVICES", "0") or 0)
if _N_VIRTUAL > 1 and "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_N_VIRTUAL}"
    ).strip()


def virtual_devices(n):
    """The first `n` jax devices, or skip the test cleanly.

    Sharding tests call this to run on a real multi-device topology in
    CPU-only CI (`REPRO_VIRTUAL_DEVICES=8` splits the host before jax
    boots).  Without the opt-in — or when the flag could not apply, e.g.
    jax was already initialized — the suite still collects and the
    multi-device cases skip with the recipe in the reason.
    """
    import jax
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip(
            f"needs {n} devices, have {len(devs)} — run with "
            f"REPRO_VIRTUAL_DEVICES={max(n, 8)} to split the host CPU")
    return devs[:n]


def given_or_cases(argnames, cases, strategies, max_examples=100):
    """Property test when hypothesis is installed, fixed cases otherwise.

    `strategies` is a callable receiving `hypothesis.strategies` and
    returning the kwargs for `@given`; `cases` are
    `@pytest.mark.parametrize(argnames, ...)` tuples in the same order,
    used on minimal installs so the module still collects and runs.
    """
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        return pytest.mark.parametrize(argnames, cases)

    def deco(fn):
        return settings(max_examples=max_examples,
                        deadline=None)(given(**strategies(st))(fn))

    return deco
