"""Continuous-batching scheduler + autoscaling pool acceptance suite.

Acceptance (ISSUE 3 + ISSUE 4): interleaved prefill/decode across N
concurrent requests through `BatchingScheduler` must be bit-exact (Q
path) with each request run alone on a fresh engine; every tick is ONE
fused ragged (chunk_t, C) call, so a prefill tail retires in
ceil(history / chunk_t) ticks rather than draining 1/tick; the
`SlotPool` must grow and shrink through its bucket ladder without
perturbing live tenants; a full pool and a full admission queue must
be explicit backpressure, never silent drops; and the scheduler's
retention caps (`keep_finished`, `call_log_len`) must actually evict.
"""
import numpy as np
import pytest

from conftest import given_or_cases

from repro.engine import PoolFull, SlotPool, StreamEngine, list_backends
from repro.fixedpoint import QFormat
from repro.launch.batching import (BatchingScheduler, EvictedRequest,
                                   Request)

FMT = QFormat(32, 20)


def _mk_sched(backend, **kw):
    kw.setdefault("buckets", (2, 4))
    kw.setdefault("chunk_t", 8)
    return BatchingScheduler(backend, fmt=FMT, **kw)


def _workload(n, seed):
    """n requests: ragged history/live lengths, a burst, mixed m."""
    rng = np.random.default_rng(seed)
    specs = {}
    for i in range(n):
        h = rng.normal(size=(int(rng.integers(0, 30)),)).astype(np.float32)
        live = rng.normal(size=(int(rng.integers(0, 10)),)).astype(
            np.float32)
        if live.size and i % 3 == 0:
            live[live.size // 2] += 25.0
        specs[f"r{i}"] = (h, live, [1.5, 3.0, 6.0][i % 3])
    return specs


def _serve_interleaved(sched, specs, max_ticks=500):
    """Staggered submits (one per tick), live fed one sample per tick."""
    order = list(specs)
    fed = {rid: 0 for rid in specs}
    closed = set()
    for tick in range(max_ticks):
        if tick < len(order):
            rid = order[tick]
            h, live, m = specs[rid]
            assert sched.submit(Request(rid, h, m=m))
            if not live.size:
                sched.close(rid)
                closed.add(rid)
        for rid, (h, live, m) in specs.items():
            if rid not in sched.stats_by_rid or rid in closed:
                continue
            if fed[rid] < live.size:
                sched.feed(rid, live[fed[rid]:fed[rid] + 1])
                fed[rid] += 1
            if fed[rid] == live.size:
                sched.close(rid)
                closed.add(rid)
        sched.step()
        if sched.completed == len(specs):
            return
    raise AssertionError(f"did not drain: {sched.stats()}")


# ------------------------------------------- interleaved == isolated
@pytest.mark.parametrize("backend", list_backends())
@given_or_cases(
    "n,seed", [(5, 0), (4, 1), (6, 2)],
    lambda st: dict(n=st.integers(2, 6), seed=st.integers(0, 2 ** 16)),
    max_examples=3)
def test_interleaved_equals_isolated(backend, n, seed):
    specs = _workload(n, seed)
    sched = _mk_sched(backend, measure_latency=True)
    _serve_interleaved(sched, specs)

    for rid, (h, live, m) in specs.items():
        full = np.concatenate([h, live])
        res = sched.results(rid)
        assert res["outlier"].shape[0] == full.size
        if not full.size:
            continue
        # the oracle: this request alone on a fresh single-slot engine
        oracle = StreamEngine(1, backend, fmt=FMT, block_t=8, m=m)
        ref = oracle.process(full[:, None])
        np.testing.assert_array_equal(
            res["outlier"], np.asarray(ref["outlier"])[:, 0], err_msg=rid)
        if backend == "pallas-q":  # quantized datapath: exact bits
            np.testing.assert_array_equal(
                res["ecc"], np.asarray(ref["ecc"])[:, 0], err_msg=rid)
        else:
            np.testing.assert_allclose(
                res["ecc"], np.asarray(ref["ecc"])[:, 0],
                rtol=1e-4, atol=1e-6, err_msg=rid)
        st = sched.telemetry(rid)
        assert st.samples == full.size
        assert st.done_tick is not None


def test_prefill_tail_retires_in_ceil_ticks():
    """Regression (ISSUE 4): a 30-sample history on chunk_t=8 retires in
    ceil(30/8) = 4 fused calls — the 6-sample tail rides the same
    (chunk_t, C) program as the full chunks via its per-slot valid
    length, instead of draining 1 sample/tick on a trickle program."""
    sched = _mk_sched("scan", chunk_t=8)
    h = np.random.default_rng(0).normal(size=(30,)).astype(np.float32)
    sched.submit(Request("a", h))
    sched.close("a")
    ticks = sched.drain()
    assert ticks == 4                      # not 3 + 6 = 9 as before
    st = sched.telemetry("a")
    assert st.samples == 30
    assert st.prefill_chunks == 4          # 8 + 8 + 8 + 6
    assert st.decode_steps == 0            # no 1-sample drain ticks
    assert {c["kind"] for c in sched.call_log} == {"fused"}
    assert all(c["t"] == 8 for c in sched.call_log)  # one program shape
    assert [c["retired"] for c in sched.call_log] == [8, 8, 8, 6]


def test_mixed_prefill_decode_slots_share_one_call():
    """A prefill-heavy and a decode-phase request advance in the SAME
    fused call, each retiring its own sample count."""
    sched = _mk_sched("scan", chunk_t=8)
    h = np.random.default_rng(1).normal(size=(20,)).astype(np.float32)
    sched.submit(Request("big", h))        # prefill-heavy
    sched.submit(Request("drip"))          # decode-phase, fed 1/tick
    sched.close("big")
    for i in range(3):
        sched.feed("drip", [float(i)])
        sched.step()
    # each tick made exactly one fused call serving both slots
    log = list(sched.call_log)
    assert [c["kind"] for c in log] == ["fused"] * 3
    assert [c["slots"] for c in log] == [2, 2, 2]
    assert [c["retired"] for c in log] == [9, 9, 5]  # 8+1, 8+1, 4+1
    big, drip = sched.telemetry("big"), sched.telemetry("drip")
    assert big.samples == 20 and big.prefill_chunks == 3
    assert drip.samples == 3 and drip.decode_steps == 3


def test_backpressure_queue_and_pool():
    """Full admission queue rejects; full pool queues; both explicit."""
    sched = _mk_sched("scan", buckets=(2,), queue_limit=2)
    h = np.zeros((4,), np.float32)
    for i in range(4):
        ok = sched.submit(Request(f"r{i}", h))
        assert ok == (i < 2)               # queue_limit=2: r2, r3 rejected
    assert sched.rejected == 2
    sched.step()                           # admits r0, r1 (bucket 2)
    assert sched.submit(Request("r4", h))  # queue drained by admission
    assert sched.submit(Request("r5", h))
    sched.step()
    assert len(sched.runs) == 2            # pool full: r4/r5 wait queued
    assert len(sched.queue) == 2
    for rid in ("r0", "r1", "r4", "r5"):
        sched.close(rid)
    sched.drain()
    assert sched.completed == 4            # everyone served eventually


def test_results_and_feed_lifecycle_errors():
    sched = _mk_sched("scan")
    with pytest.raises(KeyError):
        sched.results("ghost")
    with pytest.raises(KeyError):
        sched.feed("ghost", [0.0])
    sched.submit(Request("a", np.zeros((3,), np.float32)))
    with pytest.raises(ValueError):
        sched.submit(Request("a"))         # duplicate rid
    sched.close("a")
    with pytest.raises(ValueError):
        sched.feed("a", [1.0])             # closed


# ------------------------------------------------ async loop (ISSUE 5)
def test_async_equals_sync_bit_exact():
    """Acceptance (ISSUE 5): the async double-buffered loop is
    bit-exact with the synchronous loop on the Q path — per-request
    ecc/outlier identical across an interleaved priority mix, because
    scheduling decisions depend only on host-side counters, never on
    fetched verdicts."""
    specs = _workload(5, seed=7)
    prios = {rid: ("latency" if i % 2 else "bulk")
             for i, rid in enumerate(specs)}

    def run(measure_latency):
        sched = _mk_sched("pallas-q", measure_latency=measure_latency,
                          class_weights={"latency": 3.0, "bulk": 1.0})
        order = list(specs)
        fed = {rid: 0 for rid in specs}
        closed = set()
        for tick in range(500):
            if tick < len(order):
                rid = order[tick]
                h, live, m = specs[rid]
                assert sched.submit(
                    Request(rid, h, m=m, priority=prios[rid]))
                if not live.size:
                    sched.close(rid)
                    closed.add(rid)
            for rid, (h, live, m) in specs.items():
                if rid not in sched.stats_by_rid or rid in closed:
                    continue
                if fed[rid] < live.size:
                    sched.feed(rid, live[fed[rid]:fed[rid] + 1])
                    fed[rid] += 1
                if fed[rid] == live.size:
                    sched.close(rid)
                    closed.add(rid)
            sched.step()
            if sched.completed == len(specs):
                return sched
        raise AssertionError("did not drain")

    sync, asyn = run(True), run(False)
    for rid in specs:
        rs, ra = sync.results(rid), asyn.results(rid)
        np.testing.assert_array_equal(rs["ecc"], ra["ecc"], err_msg=rid)
        np.testing.assert_array_equal(rs["outlier"], ra["outlier"],
                                      err_msg=rid)
        ts, ta = sync.telemetry(rid), asyn.telemetry(rid)
        assert (ts.samples, ts.flags) == (ta.samples, ta.flags)


def test_adaptive_decode_short_program():
    """Decode-only ticks ride the cached (decode_t, C) program instead
    of the full (chunk_t, C) chunk, and the program cache stays flat
    after warmup (no per-tick recompiles)."""
    sched = _mk_sched("scan", chunk_t=8, decode_t=1)
    h = np.random.default_rng(3).normal(size=(10,)).astype(np.float32)
    sched.submit(Request("a", h))
    sched.step()                           # prefill: avail 10 -> chunk
    sched.step()                           # tail 2 > decode_t -> chunk
    for i in range(5):                     # decode trickle: avail 1
        sched.feed("a", [float(i)])
        sched.step()
    sched.close("a")
    sched.drain()
    log = list(sched.call_log)
    assert [c["t"] for c in log] == [8, 8, 1, 1, 1, 1, 1]
    assert [c["retired"] for c in log] == [8, 2, 1, 1, 1, 1, 1]
    assert sched.short_ticks == 5
    st = sched.stats()
    # two cached programs at bucket 2, nothing else ever compiled
    assert st["programs"] == [(2, 1), (2, 8)]
    assert sched.telemetry("a").samples == 15


def test_drain_open_request_raises_helpfully():
    """Regression (ISSUE 5): drain with an open request must raise
    immediately, naming the rids, not spin max_ticks times."""
    sched = _mk_sched("scan")
    h = np.zeros((6,), np.float32)
    sched.submit(Request("open-a", h))
    with pytest.raises(RuntimeError, match=r"open-a.*close\(\)"):
        sched.drain()
    assert sched.tick_no < 10              # stalled detection, not 100k


def test_admission_during_pool_resize_tick():
    """A request admitted in a tick where the pool grows a bucket —
    while the previous tick's call is still in flight — is served
    bit-exactly (Q path): the in-flight outputs keep their dispatch-
    time slot indices and the re-padded state is exact."""
    rng = np.random.default_rng(9)
    hs = {f"r{i}": rng.normal(size=(12,)).astype(np.float32)
          for i in range(3)}
    sched = _mk_sched("pallas-q", buckets=(2, 4), chunk_t=4)
    sched.submit(Request("r0", hs["r0"]))
    sched.submit(Request("r1", hs["r1"]))
    sched.step()                           # bucket 2, call in flight
    sched.submit(Request("r2", hs["r2"]))
    sched.step()                           # grows 2 -> 4 mid-tick
    assert sched.pool.stats()["resizes"] == 1
    for rid in hs:
        sched.close(rid)
    sched.drain()
    for rid, h in hs.items():
        oracle = StreamEngine(1, "pallas-q", fmt=FMT, block_t=8)
        ref = oracle.process(h[:, None])
        res = sched.results(rid)
        np.testing.assert_array_equal(
            res["ecc"], np.asarray(ref["ecc"])[:, 0], err_msg=rid)
        np.testing.assert_array_equal(
            res["outlier"], np.asarray(ref["outlier"])[:, 0],
            err_msg=rid)


# ------------------------------------- priority admission (ISSUE 5)
def test_priority_weighted_admission_no_starvation():
    """A burst of bulk prefills cannot starve the latency class: the
    weighted-deficit queues admit latency tenants ahead of the bulk
    backlog."""
    sched = _mk_sched("scan", buckets=(2,), queue_limit=16,
                      class_weights={"bulk": 1.0, "latency": 3.0})
    h = np.zeros((4,), np.float32)
    for i in range(6):
        assert sched.submit(Request(f"b{i}", h, priority="bulk"))
        sched.close(f"b{i}")
    for i in range(2):
        assert sched.submit(Request(f"l{i}", h, priority="latency"))
        sched.close(f"l{i}")
    sched.drain()
    adm = {rid: sched.telemetry(rid).admitted_tick
           for rid in list(sched.stats_by_rid)}
    # latency submitted last but admitted within the first two ticks;
    # the bulk backlog tail waits behind them
    assert max(adm["l0"], adm["l1"]) <= 2
    assert max(adm[f"b{i}"] for i in range(6)) > 2
    classes = sched.stats()["classes"]
    assert classes["latency"]["completed"] == 2
    assert classes["bulk"]["completed"] == 6
    assert (classes["latency"]["queue_wait_ticks_p95"]
            <= classes["bulk"]["queue_wait_ticks_p95"])


def test_per_class_state_is_pruned_when_drained():
    """A forever-running gateway must not accumulate per-class state
    for every priority string ever seen: drained classes are pruned
    (ctor-declared weights are the one retained configuration)."""
    sched = _mk_sched("scan", class_weights={"latency": 2.0})
    for i in range(8):
        sched.submit(Request(f"r{i}", np.zeros((2,), np.float32),
                             priority=f"tenant-{i}"))  # unique classes
        sched.close(f"r{i}")
    sched.drain()
    assert sched.completed == 8
    assert not sched._queues and not sched._deficit
    assert set(sched._weights) == {"latency"}   # ctor config retained


def test_evicted_ring_survives_resubmit_cycle():
    """A rid that is evicted twice (resubmit cycle) must still report
    EvictedRequest after its *stale* ring entry rotates out — the ring
    is refcounted, not a set."""
    from collections import deque as _deque
    sched = _mk_sched("scan", keep_finished=1)
    sched._evicted = _deque(maxlen=2)           # tiny ring for rotation
    sched._note_evicted("a")                    # first eviction
    sched._note_evicted("a")                    # evicted again (reuse)
    sched._note_evicted("b")                    # rotates the stale "a"
    assert list(sched._evicted) == ["a", "b"]
    with pytest.raises(EvictedRequest):         # newer "a" entry lives
        sched.results("a")
    sched._note_evicted("c")                    # rotates the live "a"
    with pytest.raises(KeyError) as ei:
        sched.results("a")                      # now genuinely unknown
    assert not isinstance(ei.value, EvictedRequest)


# -------------------------------------- lifecycle telemetry (ISSUE 5)
def test_phase_transitions_prefill_to_decode():
    """Regression (ISSUE 5): `phase` must leave PREFILL once the
    history cursor passes the replayed prefix (it used to stay PREFILL
    for the whole decode phase)."""
    sched = _mk_sched("scan", chunk_t=8)
    h = np.zeros((10,), np.float32)
    sched.submit(Request("a", h))
    assert sched.request_phase("a") == "queued"
    sched.step()                           # consumed 8 < 10
    assert sched.request_phase("a") == "prefill"
    sched.step()                           # consumed 10 >= 10
    assert sched.request_phase("a") == "decode"
    sched.feed("a", [1.0])
    sched.step()
    assert sched.request_phase("a") == "decode"
    sched.close("a")
    sched.drain()
    assert sched.request_phase("a") == "done"
    with pytest.raises(KeyError):
        sched.request_phase("ghost")


def test_empty_history_starts_in_decode():
    sched = _mk_sched("scan")
    sched.submit(Request("d"))
    sched.step()
    assert sched.request_phase("d") == "decode"
    sched.close("d")
    sched.drain()


def test_evicted_rid_error_is_distinct():
    """Regression (ISSUE 5): results()/telemetry() on a request evicted
    by the keep_finished cap must raise a distinct error, not the same
    bare KeyError as a never-submitted rid."""
    sched = _mk_sched("scan", keep_finished=2)
    for i in range(5):
        sched.submit(Request(f"r{i}", np.zeros((2,), np.float32)))
        sched.close(f"r{i}")
    sched.drain()
    for fn in (sched.results, sched.telemetry, sched.request_phase):
        with pytest.raises(EvictedRequest, match="keep_finished=2"):
            fn("r0")
        with pytest.raises(KeyError) as ei:
            fn("never-submitted")
        assert not isinstance(ei.value, EvictedRequest)
        assert "unknown" in str(ei.value)
    assert isinstance(EvictedRequest("x"), KeyError)  # except-compat
    sched.results("r4")                    # retained rids still resolve


def test_latency_log_pairs_and_cap():
    """Regression (ISSUE 5): the per-request latency log records
    (wall, retired_this_call) pairs — the shared fused-call wall is no
    longer attributed wholesale to every member — and its cap is the
    `latency_log_len` ctor knob, not a hard-coded 4096."""
    sched = _mk_sched("scan", chunk_t=4, latency_log_len=3,
                      measure_latency=True)
    sched.submit(Request("a", np.zeros((18,), np.float32)))
    sched.close("a")
    sched.drain()                          # 5 calls: 4,4,4,4,2
    st = sched.telemetry("a")
    assert st.samples == 18
    assert len(st.chunk_latency_s) == 3    # capped by the ctor knob
    for wall, retired in st.chunk_latency_s:
        assert wall > 0 and retired == 4   # honest per-call weights


def test_feed_after_close_on_queued_request():
    """Edge (ISSUE 5): a request closed while still *queued* (pool
    full, never admitted) must reject feed the same way a running
    closed request does."""
    sched = _mk_sched("scan", buckets=(2,), queue_limit=4)
    for i in range(2):                     # occupy the whole pool
        sched.submit(Request(f"hold{i}", np.zeros((2,), np.float32)))
    sched.step()
    sched.submit(Request("q", np.zeros((2,), np.float32)))
    sched.step()                           # pool full: "q" stays queued
    assert sched.request_phase("q") == "queued"
    sched.close("q")
    with pytest.raises(ValueError, match="closed"):
        sched.feed("q", [1.0])
    for i in range(2):
        sched.close(f"hold{i}")
    sched.drain()
    assert sched.completed == 3            # q admitted after a release


# --------------------------------------------------- autoscaling pool
@pytest.mark.parametrize("backend", ["scan", "pallas-q"])
def test_pool_grow_preserves_tenants(backend):
    """Growing to the next bucket re-pads state without perturbing it."""
    rng = np.random.default_rng(1)
    xa = rng.normal(size=(20, 2)).astype(np.float32)
    xb = rng.normal(size=(20, 4)).astype(np.float32)
    xb[:, :2] = xa

    pool = SlotPool(backend, buckets=(2, 4), fmt=FMT, block_t=8)
    pool.acquire(2)
    assert pool.capacity == 2
    pool.process(xa)
    pool.acquire(1)                        # 3 tenants: bucket 2 -> 4
    assert pool.capacity == 4 and pool.resizes == 1
    out = pool.process(xb, active=[0, 1])

    flat = StreamEngine(2, backend, fmt=FMT, block_t=8)  # no-resize oracle
    flat.process(xa)
    ref = flat.process(xb[:, :2])
    np.testing.assert_array_equal(np.asarray(out["outlier"])[:, :2],
                                  np.asarray(ref["outlier"]))
    if backend == "pallas-q":
        np.testing.assert_array_equal(np.asarray(out["ecc"])[:, :2],
                                      np.asarray(ref["ecc"]))
    assert pool.engine.samples_seen[:3].tolist() == [40, 40, 0]


def test_pool_shrinks_and_caches_buckets():
    pool = SlotPool("scan", buckets=(2, 4, 8))
    slots = pool.acquire(7)
    assert pool.capacity == 8
    pool.release(slots[2:])                # max live index is 1 -> bucket 2
    assert pool.capacity == 2
    assert pool.stats()["compiled_buckets"] == [2, 8]
    pool.acquire(2)                        # back up a bucket
    assert pool.capacity == 4 and pool.occupancy == 4
    assert pool.stats()["compiled_buckets"] == [2, 4, 8]


def test_pool_full_is_explicit():
    pool = SlotPool("scan", buckets=(2, 4))
    pool.acquire(4)
    with pytest.raises(PoolFull) as ei:
        pool.acquire(1)
    assert ei.value.occupancy == 4 and ei.value.capacity == 4
    assert "4/4" in str(ei.value)


def test_finished_retention_is_bounded():
    """A forever-running gateway evicts its oldest finished requests."""
    sched = _mk_sched("scan", keep_finished=3)
    for i in range(6):
        sched.submit(Request(f"r{i}", np.zeros((2,), np.float32)))
        sched.close(f"r{i}")
    sched.drain()
    assert sched.completed == 6
    assert len(sched._finished) == 3
    sched.results("r5")                    # recent results retained
    with pytest.raises(KeyError):
        sched.results("r0")                # oldest evicted
    sched.submit(Request("r0"))            # ...and its rid is reusable
    assert sched.telemetry("r5").done_tick is not None
    # telemetry of evicted requests is gone too (no unbounded dict)
    assert set(sched.stats_by_rid) == {"r3", "r4", "r5", "r0"}


def test_call_log_retention_is_bounded():
    """Regression (ISSUE 4): the engine-call log must be a ring buffer —
    a long-lived gateway keeps only the newest `call_log_len` calls."""
    sched = _mk_sched("scan", chunk_t=2, call_log_len=5)
    sched.submit(Request("a", np.zeros((40,), np.float32)))
    sched.close("a")
    ticks = sched.drain()
    assert ticks == 20                     # 40 samples / chunk_t=2
    assert len(sched.call_log) == 5        # ring buffer, not 20 entries
    assert all(c["kind"] == "fused" for c in sched.call_log)
    # stats() keeps working on the bounded window
    assert sched.stats()["chunk_latency"]["calls"] == 5


def test_serve_streams_outlives_retention_cap():
    """Regression: serve_streams must read every request's telemetry
    after the drain even when the stream count exceeds the scheduler's
    default retention (it sizes keep_finished to the run)."""
    from repro.launch.serve import serve_streams
    streams = [(f"s{i}", np.zeros((3,), np.float32),
                np.zeros((0,), np.float32), None) for i in range(12)]
    res = serve_streams(streams, backend="scan", buckets=(2, 4),
                        chunk_t=2, keep_finished=4)
    assert res["requests"] == 12 and res["samples"] == 36
    assert len(res["per_request"]) == 12


def test_pool_per_tenant_m_survives_resize():
    pool = SlotPool("scan", buckets=(2, 4), m=3.0)
    pool.acquire(2, m=1.25)
    pool.acquire(1, m=9.0)                 # grows to bucket 4
    assert pool.engine.slot_m.tolist() == [1.25, 1.25, 9.0, 3.0]


# ------------------------------------------- deep pipeline (pipeline_depth)
def _run_depth(depth, specs, prios=None, backend="pallas-q",
               check_fence=False, **kw):
    """Serve `specs` interleaved at a given pipeline depth; optionally
    assert the fencing invariant (a slot in at most one in-flight call)
    after every tick."""
    sched = _mk_sched(backend, pipeline_depth=depth, **kw)
    order = list(specs)
    fed = {rid: 0 for rid in specs}
    closed = set()
    for tick in range(800):
        if tick < len(order):
            rid = order[tick]
            h, live, m = specs[rid]
            prio = (prios or {}).get(rid, "default")
            assert sched.submit(Request(rid, h, m=m, priority=prio))
            if not live.size:
                sched.close(rid)
                closed.add(rid)
        for rid, (h, live, m) in specs.items():
            if rid not in sched.stats_by_rid or rid in closed:
                continue
            if fed[rid] < live.size:
                sched.feed(rid, live[fed[rid]:fed[rid] + 1])
                fed[rid] += 1
            if fed[rid] == live.size:
                sched.close(rid)
                closed.add(rid)
        sched.step()
        if check_fence:
            slots = [s for inf in sched._inflight
                     for _, s, _ in inf.members]
            assert len(slots) == len(set(slots)), \
                f"slot fenced twice in flight at tick {tick}: {slots}"
            assert len(sched._inflight) <= depth + 1
        if sched.completed == len(specs):
            return sched
    raise AssertionError("did not drain")


@pytest.mark.parametrize("depth", [2, 4])
def test_pipeline_depth_bit_exact_with_depth_1(depth):
    """Acceptance (ISSUE 7): depth-2/4 pipelines are bit-exact with
    depth 1 at the gateway level on the Q path — fencing keeps each
    slot's chunks in dispatch order, and chunk-exactness makes the
    per-request sample stream independent of tick partitioning."""
    specs = _workload(6, seed=23)
    prios = {rid: ("latency" if i % 2 else "bulk")
             for i, rid in enumerate(specs)}
    base = _run_depth(1, specs, prios,
                      class_weights={"latency": 3.0, "bulk": 1.0})
    deep = _run_depth(depth, specs, prios, check_fence=True,
                      class_weights={"latency": 3.0, "bulk": 1.0})
    for rid in specs:
        rb, rd = base.results(rid), deep.results(rid)
        np.testing.assert_array_equal(rb["ecc"], rd["ecc"], err_msg=rid)
        np.testing.assert_array_equal(rb["outlier"], rd["outlier"],
                                      err_msg=rid)
        tb, td = base.telemetry(rid), deep.telemetry(rid)
        assert (tb.samples, tb.flags) == (td.samples, td.flags)


def test_pipeline_fencing_under_slot_churn():
    """Attach/detach churn: completed requests release slots that new
    requests immediately recycle while older calls may still be in
    flight.  The fencing invariant must hold every tick and results
    must stay bit-exact with the depth-1 loop."""
    rng = np.random.default_rng(31)
    specs = {}
    for i in range(10):  # > 2x pool capacity: constant recycling
        h = rng.normal(size=(int(rng.integers(1, 12)),)).astype(
            np.float32)
        live = rng.normal(size=(int(rng.integers(0, 4)),)).astype(
            np.float32)
        specs[f"c{i}"] = (h, live, 3.0)
    base = _run_depth(1, specs)
    deep = _run_depth(4, specs, check_fence=True)
    for rid in specs:
        np.testing.assert_array_equal(base.results(rid)["outlier"],
                                      deep.results(rid)["outlier"],
                                      err_msg=rid)
        np.testing.assert_array_equal(base.results(rid)["ecc"],
                                      deep.results(rid)["ecc"],
                                      err_msg=rid)


def test_pipeline_programs_flat_after_warmup():
    """Depth > 1 must not defeat the program cache: after the first
    full+short programs compile, further ticks add no new (capacity, t)
    entries."""
    sched = _mk_sched("scan", chunk_t=4, pipeline_depth=3)
    rng = np.random.default_rng(5)
    for i in range(3):
        sched.submit(Request(
            f"w{i}", rng.normal(size=(9,)).astype(np.float32)))
    for _ in range(6):  # warmup: chunk + decode programs both exercised
        sched.step()
    warm = set(sched.stats()["programs"])
    for i in range(3):
        sched.feed(f"w{i}", rng.normal(size=(3,)).astype(np.float32))
        sched.close(f"w{i}")
    sched.drain()
    assert set(sched.stats()["programs"]) == warm
    assert sched.stats()["pipeline_depth"] == 3


def test_pipeline_depth_validation_and_latency_override():
    with pytest.raises(ValueError):
        _mk_sched("scan", pipeline_depth=0)
    # measure_latency=True overrides the pipeline: every call retires
    # synchronously within its own tick, so nothing stays in flight
    sched = _mk_sched("scan", pipeline_depth=4, measure_latency=True)
    sched.submit(Request("a", np.ones((20,), np.float32)))
    for _ in range(4):
        sched.step()
        assert sched.stats()["inflight_calls"] == 0
    assert all(c["sync"] for c in sched.call_log)


def test_pipeline_depth_bounds_inflight_queue():
    """A depth-d scheduler never holds more than d dispatched calls
    after a tick completes (the depth cap is enforced even when
    opportunistic retirement finds nothing ready)."""
    sched = _mk_sched("scan", chunk_t=2, pipeline_depth=2)
    rng = np.random.default_rng(11)
    for i in range(4):
        sched.submit(Request(
            f"b{i}", rng.normal(size=(20,)).astype(np.float32)))
        sched.close(f"b{i}")
    while sched.runs or sched.queued_total:
        sched.step()
        assert len(sched._inflight) <= 2
    sched._flush()
