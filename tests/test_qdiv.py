"""Host-width divider image: bit-equality vs the bit-serial model.

`kernels/qdiv.py` is only allowed to exist because it computes exactly
the function `fixedpoint.qformat._div_mag` models clock-for-clock —
these tests are that license.  Operands cover the full int32 range,
the d == 0 guard, round-half-up ties, and quotient saturation for
several word lengths (including FL = 0, where the fast path is a single
integer divide, and a degenerate FL > INT format).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import given_or_cases

from repro.fixedpoint.qformat import QFormat, div_qi, div_qq
from repro.kernels.qdiv import fast_div_qi, fast_div_qq

FORMATS = [QFormat(32, 20), QFormat(16, 8), QFormat(24, 12),
           QFormat(32, 0), QFormat(12, 10)]

_EDGES = np.array([0, 1, -1, 2, -2, 3, -3, 7, 255, 2**20, -(2**20),
                   2**30, -(2**30), 2**31 - 1, -(2**31 - 1)], np.int64)


def _edge_grid(fmt):
    """Dense cross of adversarial operands for one format."""
    v = np.unique(np.concatenate([
        _EDGES, [fmt.qmax, -fmt.qmax, fmt.qmin, fmt.one, -fmt.one,
                 fmt.one // 2, fmt.one + 1]])).astype(np.int32)
    n, d = np.meshgrid(v, v)
    return jnp.asarray(n.ravel()), jnp.asarray(d.ravel())


@pytest.mark.parametrize("fmt", FORMATS,
                         ids=lambda f: f"Q{f.word_len}.{f.frac_len}")
def test_edge_grid_bit_equal(fmt):
    n, d = _edge_grid(fmt)
    np.testing.assert_array_equal(np.asarray(div_qq(fmt, n, d)),
                                  np.asarray(fast_div_qq(fmt, n, d)))
    np.testing.assert_array_equal(np.asarray(div_qi(fmt, n, d)),
                                  np.asarray(fast_div_qi(fmt, n, d)))


@pytest.mark.parametrize("fmt", FORMATS,
                         ids=lambda f: f"Q{f.word_len}.{f.frac_len}")
def test_random_sweep_bit_equal(fmt):
    rng = np.random.default_rng(fmt.word_len * 100 + fmt.frac_len)
    n = jnp.asarray(rng.integers(-2**31 + 1, 2**31,
                                 size=50_000).astype(np.int32))
    d = jnp.asarray(rng.integers(-2**31 + 1, 2**31,
                                 size=50_000).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(div_qq(fmt, n, d)),
                                  np.asarray(fast_div_qq(fmt, n, d)))
    np.testing.assert_array_equal(np.asarray(div_qi(fmt, n, d)),
                                  np.asarray(fast_div_qi(fmt, n, d)))


@given_or_cases(
    "num,den",
    [(1, 3), (-(2**31 - 1), 1), (2**31 - 1, -1), (5 << 20, 10 << 20),
     (123456789, -987), (0, 0), (42, 0)],
    lambda st: {"num": st.integers(-2**31 + 1, 2**31 - 1),
                "den": st.integers(-2**31 + 1, 2**31 - 1)},
    max_examples=300)
def test_property_scalar_bit_equal(num, den):
    fmt = QFormat(32, 20)
    n = jnp.asarray([num], jnp.int32)
    d = jnp.asarray([den], jnp.int32)
    assert int(div_qq(fmt, n, d)[0]) == int(fast_div_qq(fmt, n, d)[0])
    assert int(div_qi(fmt, n, d)[0]) == int(fast_div_qi(fmt, n, d)[0])


def test_division_by_one_is_identity():
    """The k=1 folding in the Q kernel rests on x/1 == x exactly."""
    fmt = QFormat(32, 20)
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.concatenate([
        rng.integers(fmt.qmin, fmt.qmax + 1, size=10_000),
        [fmt.qmin, fmt.qmax, 0, 1, -1]]).astype(np.int32))
    one = jnp.ones_like(x)
    np.testing.assert_array_equal(np.asarray(fast_div_qi(fmt, x, one)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(div_qi(fmt, x, one)),
                                  np.asarray(x))
