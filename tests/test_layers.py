"""Layer-level equivalence tests: chunked/parallel forms vs naive oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import flash_attention
from repro.models.common import ModelConfig
from repro.models.ssm import (ssm_cache_init, ssm_decode_step, ssm_forward,
                              ssm_init)
from repro.models.xlstm import (mlstm_cache_init, mlstm_decode_step,
                                mlstm_forward, mlstm_init)


def naive_attention(q, k, v, causal=True, window=None, cap=None):
    """O(S^2) reference. q (B,S,KV,G,D), k/v (B,S,KV,D)."""
    b, s, kv, g, d = q.shape
    sk = k.shape[1]
    logits = np.einsum("bqkgd,bckd->bkgqc", np.asarray(q, np.float32),
                       np.asarray(k, np.float32)) * d ** -0.5
    if cap is not None:
        logits = cap * np.tanh(logits / cap)
    iq = np.arange(s)[:, None]
    ik = np.arange(sk)[None, :]
    ok = np.ones((s, sk), bool)
    if causal:
        ok &= ik <= iq
    if window is not None:
        ok &= iq - ik < window
    logits = np.where(ok[None, None, None], logits, -1e30)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = np.where(ok[None, None, None], p, 0)
    p = p / np.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = np.einsum("bkgqc,bckd->bqkgd", p, np.asarray(v, np.float32))
    return out


@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 16, None), (False, None, None),
    (True, None, 30.0)])
@pytest.mark.parametrize("qc,kc", [(8, 16), (64, 64), (16, 8)])
def test_flash_vs_naive(causal, window, cap, qc, kc):
    rng = np.random.default_rng(0)
    b, s, kv, g, d = 2, 64, 2, 3, 16
    q = rng.normal(size=(b, s, kv, g, d)).astype(np.float32)
    k = rng.normal(size=(b, s, kv, d)).astype(np.float32)
    v = rng.normal(size=(b, s, kv, d)).astype(np.float32)
    ref = naive_attention(q, k, v, causal, window, cap)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, window=window, cap=cap,
                          q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


def test_flash_skip_chunks_identical():
    rng = np.random.default_rng(1)
    b, s, kv, g, d = 1, 128, 1, 2, 8
    q = rng.normal(size=(b, s, kv, g, d)).astype(np.float32)
    k = rng.normal(size=(b, s, kv, d)).astype(np.float32)
    v = rng.normal(size=(b, s, kv, d)).astype(np.float32)
    a = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        q_chunk=32, kv_chunk=32, skip_masked_chunks=True)
    bout = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           q_chunk=32, kv_chunk=32,
                           skip_masked_chunks=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bout), rtol=1e-5,
                               atol=1e-6)


def _ssm_cfg():
    return ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                       n_heads=4, n_kv=4, d_ff=64, vocab=64,
                       ssm_state=8, ssm_head_dim=8, ssm_expand=2,
                       ssm_chunk=16, compute_dtype="float32")


def test_ssm_chunked_vs_decode_recurrence():
    """Training chunked SSD == sequential decode steps (same params)."""
    cfg = _ssm_cfg()
    key = jax.random.PRNGKey(0)
    params = ssm_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32)) * 0.5
    y_train = ssm_forward(params, x, cfg)

    cache = ssm_cache_init(cfg, 2)
    ys = []
    for t in range(64):
        y, cache = ssm_decode_step(params, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    # f32 log-space chunked scan vs sequential product: reassociation in
    # exp(cumsum diffs) legitimately drifts ~1e-3 over 64 steps
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=5e-2, atol=5e-3)


def test_ssm_chunk_size_invariance():
    cfg = _ssm_cfg()
    params = ssm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32)) * 0.5
    import dataclasses
    y16 = ssm_forward(params, x, cfg)
    y64 = ssm_forward(params, x, dataclasses.replace(cfg, ssm_chunk=64))
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), rtol=5e-2,
                               atol=5e-3)


def _xlstm_cfg():
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                       n_heads=4, n_kv=4, d_ff=0, vocab=64,
                       mlstm_proj_factor=2.0, ssm_chunk=16,
                       compute_dtype="float32")


def test_mlstm_chunked_vs_decode_recurrence():
    cfg = _xlstm_cfg()
    params = mlstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 32)) * 0.5
    y_train = mlstm_forward(params, x, cfg)
    cache = mlstm_cache_init(cfg, 2)
    ys = []
    for t in range(48):
        y, cache = mlstm_decode_step(params, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunk_size_invariance():
    import dataclasses
    cfg = _xlstm_cfg()
    params = mlstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32)) * 0.5
    y_a = mlstm_forward(params, x, cfg)
    y_b = mlstm_forward(params, x, dataclasses.replace(cfg, ssm_chunk=64))
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b), rtol=2e-3,
                               atol=2e-3)
