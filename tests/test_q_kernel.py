"""Integer Pallas Q-TEDA kernel: bit-exactness vs the pure-JAX scan."""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import given_or_cases

from repro.fixedpoint import QFormat, teda_q_scan_chan
from repro.kernels.ops import teda_q_scan_tpu, teda_scan_tpu

FMT = QFormat(32, 20)


def _x(t, c, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(t, c)).astype(np.float32)


def _assert_bit_exact(x, fmt=FMT, m=3.0, block_t=64):
    fin_k, out_k = teda_q_scan_tpu(jnp.asarray(x), fmt, m,
                                   block_t=block_t)
    fin_s, out_s = teda_q_scan_chan(jnp.asarray(x), fmt, m)
    for key in ("mean", "var", "ecc", "outlier"):
        np.testing.assert_array_equal(np.asarray(out_k[key]),
                                      np.asarray(out_s[key]), err_msg=key)
    np.testing.assert_array_equal(np.asarray(fin_k.mean[:, 0]),
                                  np.asarray(fin_s[1]))
    np.testing.assert_array_equal(np.asarray(fin_k.var),
                                  np.asarray(fin_s[2]))
    return out_k


@pytest.mark.parametrize("t,c", [(64, 1), (100, 3), (256, 5)])
def test_kernel_bit_exact_shapes(t, c):
    _assert_bit_exact(_x(t, c, seed=t + c))


@pytest.mark.parametrize("fmt", [QFormat(16, 10), QFormat(24, 16),
                                 QFormat(32, 20, "round")])
def test_kernel_bit_exact_formats(fmt):
    _assert_bit_exact(_x(128, 2, seed=11), fmt=fmt)


@pytest.mark.parametrize("block_t", [8, 32, 128])
def test_chunking_does_not_change_bits(block_t):
    """Quantized arithmetic is order-sensitive: the chunked kernel must
    preserve the exact sequential order across chunk boundaries."""
    x = _x(160, 2, seed=12)
    out_ref = _assert_bit_exact(x, block_t=64)
    _, out = teda_q_scan_tpu(jnp.asarray(x), FMT, 3.0, block_t=block_t)
    np.testing.assert_array_equal(np.asarray(out["ecc"]),
                                  np.asarray(out_ref["ecc"]))
    np.testing.assert_array_equal(np.asarray(out["outlier"]),
                                  np.asarray(out_ref["outlier"]))


def test_time_padding_does_not_leak():
    """T not a multiple of block_t: padded tail rows must not alter
    outputs or the final state (read from the last valid row)."""
    x = _x(70, 2, seed=13)
    fin_a, out_a = teda_q_scan_tpu(jnp.asarray(x), FMT, block_t=64)
    fin_b, out_b = teda_q_scan_tpu(jnp.asarray(x), FMT, block_t=8)
    np.testing.assert_array_equal(np.asarray(out_a["ecc"]),
                                  np.asarray(out_b["ecc"]))
    np.testing.assert_array_equal(np.asarray(fin_a.var),
                                  np.asarray(fin_b.var))
    assert int(fin_a.k[0]) == 70


def test_state_carry_across_calls_bit_exact():
    x = _x(192, 3, seed=14)
    _, full = teda_q_scan_tpu(jnp.asarray(x), FMT, block_t=32)
    st1, _ = teda_q_scan_tpu(jnp.asarray(x[:96]), FMT, block_t=32)
    st2, out2 = teda_q_scan_tpu(jnp.asarray(x[96:]), FMT, state=st1,
                                block_t=32)
    np.testing.assert_array_equal(np.asarray(out2["ecc"]),
                                  np.asarray(full["ecc"])[96:])
    assert int(st2.k[0]) == 192


def test_spike_detection_per_channel():
    x = _x(300, 4, seed=15)
    x[250:255, 2] += 25.0
    out = _assert_bit_exact(x)
    flags = np.asarray(out["outlier"])
    assert flags[250:255, 2].any()


def test_quantized_verdicts_agree_with_float_kernel():
    """Acceptance: WL=32 Q kernel agrees >= 99% with the float kernel."""
    x = _x(512, 4, seed=16)
    x[400:405, 1] += 12.0
    _, out_q = teda_q_scan_tpu(jnp.asarray(x), FMT, 3.0, block_t=64)
    _, out_f = teda_scan_tpu(jnp.asarray(x), 3.0, block_t=64)
    agree = (np.asarray(out_q["outlier"])
             == np.asarray(out_f["outlier"])).mean()
    assert agree >= 0.99


def test_wrapper_composes_under_jit():
    """teda_q_scan_tpu must stay traceable — carried state (k0) is not
    concretized on the host, matching the float wrapper's contract."""
    import jax
    x = _x(64, 2, seed=18)
    st1, _ = teda_q_scan_tpu(jnp.asarray(x[:32]), FMT, block_t=32)
    f = jax.jit(lambda v, s: teda_q_scan_tpu(
        v, FMT, 3.0, state=s, block_t=32, interpret=True)[1]["ecc"])
    ecc = f(jnp.asarray(x[32:]), st1)
    _, full = teda_q_scan_tpu(jnp.asarray(x), FMT, block_t=32)
    np.testing.assert_array_equal(np.asarray(ecc),
                                  np.asarray(full["ecc"])[32:])


def test_pre_quantized_int_input_passthrough():
    """int32 input must be treated as already-quantized Q values."""
    x = _x(96, 2, seed=17)
    xq = FMT.quantize(jnp.asarray(x))
    _, out_a = teda_q_scan_tpu(xq, FMT, block_t=32)
    _, out_b = teda_q_scan_tpu(jnp.asarray(x), FMT, block_t=32)
    np.testing.assert_array_equal(np.asarray(out_a["ecc"]),
                                  np.asarray(out_b["ecc"]))


# -------------------------------------------- ragged per-channel vlen
def _assert_ragged_bit_exact(x, lens, fmt=FMT, m=3.0, block_t=8):
    """One ragged kernel call; asserts the no-flags-beyond-vlen rule."""
    t, c = x.shape
    fin, out = teda_q_scan_tpu(jnp.asarray(x), fmt, m,
                               valid_lens=np.asarray(lens, np.int32),
                               block_t=block_t)
    flags = np.asarray(out["outlier"])
    assert not flags[np.arange(t)[:, None] >= np.asarray(lens)[None, :]
                     ].any()
    return fin, out


@given_or_cases(
    "t,c,seed,block_t",
    [(24, 3, 0, 8), (64, 4, 1, 32), (100, 2, 2, 8), (40, 5, 3, 8)],
    lambda st: dict(t=st.integers(2, 128), c=st.integers(1, 6),
                    seed=st.integers(0, 2 ** 16),
                    block_t=st.sampled_from([8, 32])),
    max_examples=10)
def test_vlen_vector_matches_chan_oracle(t, c, seed, block_t):
    """Per-channel vlen vector vs `teda_q_scan_chan` on each prefix:
    exact bits for outputs AND final state, incl. vlen 0 / T / rest."""
    rng = np.random.default_rng(seed)
    x = _x(t, c, seed=seed)
    lens = rng.integers(0, t + 1, size=c).astype(np.int32)
    lens[rng.integers(0, c)] = 0
    lens[rng.integers(0, c)] = t
    fin, out = _assert_ragged_bit_exact(x, lens, block_t=block_t)
    np.testing.assert_array_equal(np.asarray(fin.k), lens)
    for ch in range(c):
        n = int(lens[ch])
        if n == 0:
            assert int(np.asarray(fin.mean)[ch, 0]) == 0
            assert int(np.asarray(fin.var)[ch]) == 0
            continue
        f, o = teda_q_scan_chan(jnp.asarray(x[:n, ch:ch + 1]), FMT, 3.0)
        np.testing.assert_array_equal(np.asarray(out["ecc"])[:n, ch],
                                      np.asarray(o["ecc"])[:, 0],
                                      err_msg=f"ch{ch}")
        np.testing.assert_array_equal(
            np.asarray(out["outlier"])[:n, ch],
            np.asarray(o["outlier"])[:, 0], err_msg=f"ch{ch}")
        np.testing.assert_array_equal(np.asarray(fin.mean)[ch, 0],
                                      np.asarray(f[1])[0])
        np.testing.assert_array_equal(np.asarray(fin.var)[ch],
                                      np.asarray(f[2])[0])


def test_vlen_degenerate_vectors_match_scalar_bits():
    """All-T vlen == the default scalar path bit-for-bit (one program,
    broadcast input); all-zeros leaves the carried state untouched."""
    x = _x(70, 3, seed=21)
    fin_a, out_a = teda_q_scan_tpu(jnp.asarray(x), FMT, block_t=8)
    fin_b, out_b = teda_q_scan_tpu(jnp.asarray(x), FMT, block_t=8,
                                   valid_lens=np.full((3,), 70, np.int32))
    for key in ("mean", "var", "ecc", "outlier"):
        np.testing.assert_array_equal(np.asarray(out_a[key]),
                                      np.asarray(out_b[key]), err_msg=key)
    np.testing.assert_array_equal(np.asarray(fin_a.mean),
                                  np.asarray(fin_b.mean))
    np.testing.assert_array_equal(np.asarray(fin_a.var),
                                  np.asarray(fin_b.var))
    # all-zeros: the frozen carries round-trip exactly
    fin_z, out_z = teda_q_scan_tpu(jnp.asarray(x), FMT, state=fin_a,
                                   valid_lens=np.zeros((3,), np.int32),
                                   block_t=8)
    np.testing.assert_array_equal(np.asarray(fin_z.k),
                                  np.asarray(fin_a.k))
    np.testing.assert_array_equal(np.asarray(fin_z.mean),
                                  np.asarray(fin_a.mean))
    np.testing.assert_array_equal(np.asarray(fin_z.var),
                                  np.asarray(fin_a.var))
    assert not np.asarray(out_z["outlier"]).any()


def test_vlen_ragged_state_carry_bit_exact():
    """Ragged call chaining: each channel resumes from its own frozen
    prefix, matching one uninterrupted oracle run bit-for-bit."""
    x = _x(90, 2, seed=22)
    lens1 = np.array([40, 9], np.int32)
    st1, _ = teda_q_scan_tpu(jnp.asarray(x[:48]), FMT, valid_lens=lens1,
                             block_t=8)
    take2 = np.array([50, 81], np.int32)
    x2 = np.zeros((88, 2), np.float32)
    for ch in range(2):
        a, b = int(lens1[ch]), int(lens1[ch] + take2[ch])
        x2[: take2[ch], ch] = x[a:b, ch]
    st2, out2 = teda_q_scan_tpu(jnp.asarray(x2), FMT, state=st1,
                                valid_lens=take2, block_t=8)
    np.testing.assert_array_equal(np.asarray(st2.k), lens1 + take2)
    for ch in range(2):
        f, o = teda_q_scan_chan(jnp.asarray(x[:90, ch:ch + 1]), FMT, 3.0)
        np.testing.assert_array_equal(
            np.asarray(out2["ecc"])[: take2[ch], ch],
            np.asarray(o["ecc"])[lens1[ch]:, 0], err_msg=f"ch{ch}")
        np.testing.assert_array_equal(np.asarray(st2.mean)[ch, 0],
                                      np.asarray(f[1])[0])
        np.testing.assert_array_equal(np.asarray(st2.var)[ch],
                                      np.asarray(f[2])[0])
