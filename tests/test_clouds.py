"""TEDA data clouds (evolving classifier, paper refs [4]/[15])."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.clouds import clouds_init, clouds_run, clouds_step


def _blobs(per=60, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(per, 2)) * 0.15 + np.array([0.0, 0.0])
    b = rng.normal(size=(per, 2)) * 0.15 + np.array([5.0, 5.0])
    c = rng.normal(size=(per, 2)) * 0.15 + np.array([-5.0, 5.0])
    # sequential regimes (the TEDAClass streaming scenario: concept
    # drift with each regime lasting > m^2 samples)
    x = np.concatenate([a, b, c], axis=0)
    labels = np.repeat(np.array([0, 1, 2]), per)
    return x.astype(np.float32), labels


def test_three_blobs_three_clouds():
    x, labels = _blobs()
    state, member = clouds_run(jnp.asarray(x), capacity=8, m=3.0)
    assert int(state.n_active) == 3
    member = np.asarray(member)
    # each sample belongs to exactly its blob's cloud (after warmup)
    owner = member.argmax(axis=1)
    # map blob label -> majority cloud; check purity
    purity = 0
    for lbl in range(3):
        own = owner[labels == lbl][10:]
        purity += (own == np.bincount(own).argmax()).mean()
    assert purity / 3 > 0.95
    # cloud means recover blob centers
    centers = np.asarray(state.mean)[np.asarray(state.k) > 0]
    found = sorted(tuple(np.round(c).tolist()) for c in centers)
    assert found == [(-5.0, 5.0), (0.0, 0.0), (5.0, 5.0)]


def test_capacity_saturation_adopts():
    """At capacity, eccentric samples join the least-eccentric cloud."""
    x, _ = _blobs(per=30)
    state, member = clouds_run(jnp.asarray(x), capacity=2, m=3.0)
    assert int(state.n_active) == 2
    assert bool(np.asarray(member).any(axis=1).all())  # nobody dropped


def test_single_cloud_for_stationary_stream():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(200, 3)).astype(np.float32) * 0.1
    state, _ = clouds_run(jnp.asarray(x), capacity=8, m=3.0)
    assert int(state.n_active) == 1
    np.testing.assert_allclose(np.asarray(state.mean[0]), x.mean(0),
                               atol=1e-4)


def test_step_is_jittable():
    state = clouds_init(4, 2)
    step = jax.jit(lambda s, v: clouds_step(s, v, 3.0))
    state, member = step(state, jnp.asarray([1.0, 2.0]))
    assert int(state.n_active) == 1
    assert member.shape == (4,)
