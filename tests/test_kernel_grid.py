"""2-D (channel-block, time) kernel grid: block_c must never change bits.

Channel strips are independent by construction (no cross-channel data
flow), so every `block_c` — including widths that force channel
padding, the degenerate C == 1, and block_c == C (one strip, the 1-D
grid) — must reproduce the single-strip result exactly: bit-for-bit on
the integer path (vs the `teda_q_scan_chan` oracle) and exactly equal
arrays on the float path (same program, different tiling).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.teda import TedaState
from repro.fixedpoint import QFormat, teda_q_scan_chan
from repro.kernels.ops import (teda_q_scan_tpu, teda_q_scan_verdict,
                               teda_scan_tpu, teda_scan_verdict)

FMT = QFormat(32, 20)


def _x(t, c, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(t, c)).astype(np.float32)


def _q(x):
    return jnp.asarray(np.asarray(FMT.quantize(x)))


# ------------------------------------------------- Q path: bit-exactness
@pytest.mark.parametrize("c,block_c", [
    (200, 128),   # C % block_c != 0 (wrapper pads to 256)
    (1, 128),     # degenerate C=1 (pads to one lane tile)
    (256, 256),   # block_c == padded C: one strip == the 1-D grid
    (300, 128),   # padded C = 384, three strips
])
def test_q_block_c_bit_exact_vs_oracle(c, block_c):
    xq = _q(_x(96, c, seed=c))
    (fk, fm, fv), oro = teda_q_scan_chan(xq, FMT, m=3.0)
    st, out = teda_q_scan_tpu(xq, FMT, m=3.0, block_t=32,
                              block_c=block_c, interpret=True)
    for key in ("mean", "var", "ecc", "outlier"):
        np.testing.assert_array_equal(np.asarray(out[key]),
                                      np.asarray(oro[key]), err_msg=key)
    np.testing.assert_array_equal(np.asarray(st.k), np.asarray(fk))
    np.testing.assert_array_equal(np.asarray(st.mean[:, 0]),
                                  np.asarray(fm))
    np.testing.assert_array_equal(np.asarray(st.var), np.asarray(fv))


@pytest.mark.parametrize("block_c", [None, 128, 256])
def test_q_verdict_equals_full_every_block_c(block_c):
    xq = _q(_x(64, 200, seed=3))
    stf, outf = teda_q_scan_tpu(xq, FMT, m=3.0, block_t=32,
                                block_c=block_c, interpret=True)
    stv, outv = teda_q_scan_verdict(xq, FMT, m=3.0, block_t=32,
                                    block_c=block_c, interpret=True)
    np.testing.assert_array_equal(np.asarray(outv["ecc"]),
                                  np.asarray(outf["ecc"]))
    np.testing.assert_array_equal(np.asarray(outv["outlier"]),
                                  np.asarray(outf["outlier"]))
    np.testing.assert_array_equal(np.asarray(stv.k), np.asarray(stf.k))
    np.testing.assert_array_equal(np.asarray(stv.mean),
                                  np.asarray(stf.mean))
    np.testing.assert_array_equal(np.asarray(stv.var),
                                  np.asarray(stf.var))


@pytest.mark.parametrize("block_c", [128, 256])
def test_q_ragged_vlens_cross_channel_blocks(block_c):
    """Per-channel ragged lengths x channel strips: every channel's
    valid prefix and final state must match its isolated oracle run."""
    t, c = 64, 200
    xq = np.asarray(_q(_x(t, c, seed=9)))
    rng = np.random.default_rng(17)
    vl = rng.integers(0, t + 1, size=c).astype(np.int32)
    k0 = rng.integers(0, 40, size=c).astype(np.int32)
    m0 = np.asarray(FMT.quantize(rng.normal(size=c).astype(np.float32)))
    v0 = np.abs(np.asarray(FMT.quantize(
        rng.uniform(0.1, 2.0, size=c).astype(np.float32))))
    st0 = TedaState(k=jnp.asarray(k0), mean=jnp.asarray(m0)[:, None],
                    var=jnp.asarray(v0))

    st, out = teda_q_scan_verdict(jnp.asarray(xq), FMT, m=3.0,
                                  block_t=32, block_c=block_c,
                                  interpret=True, state=st0,
                                  valid_lens=jnp.asarray(vl))
    ecc = np.asarray(out["ecc"])
    flags = np.asarray(out["outlier"]).astype(bool)
    for ch in range(0, c, 17):  # sampled channels, incl. strip edges
        n = int(vl[ch])
        if n == 0:
            assert int(st.k[ch]) == int(k0[ch])
            assert int(st.var[ch]) == int(v0[ch])
            assert not flags[:, ch].any()
            continue
        (fkc, fmc, fvc), oc = teda_q_scan_chan(
            jnp.asarray(xq[:n, ch:ch + 1]), FMT, m=3.0, k0=int(k0[ch]),
            mean0=jnp.asarray(m0[ch:ch + 1]),
            var0=jnp.asarray(v0[ch:ch + 1]))
        np.testing.assert_array_equal(ecc[:n, ch],
                                      np.asarray(oc["ecc"])[:, 0])
        np.testing.assert_array_equal(flags[:n, ch],
                                      np.asarray(oc["outlier"])[:, 0])
        assert not flags[n:, ch].any()  # no flags past vlen
        assert int(st.k[ch]) == int(fkc[0])
        assert int(st.mean[ch, 0]) == int(fmc[0])
        assert int(st.var[ch]) == int(fvc[0])


def test_q_chunked_state_carry_with_block_c():
    xq = _q(_x(96, 140, seed=5))
    _, oro = teda_q_scan_chan(xq, FMT, m=3.0)
    st, o1 = teda_q_scan_tpu(xq[:48], FMT, m=3.0, block_t=16,
                             block_c=128, interpret=True)
    _, o2 = teda_q_scan_tpu(xq[48:], FMT, m=3.0, block_t=16,
                            block_c=128, interpret=True, state=st)
    ecc = np.concatenate([np.asarray(o1["ecc"]), np.asarray(o2["ecc"])])
    np.testing.assert_array_equal(ecc, np.asarray(oro["ecc"]))


def test_q_invalid_block_c_rejected():
    xq = _q(_x(32, 8, seed=1))
    with pytest.raises(ValueError):
        teda_q_scan_tpu(xq, FMT, m=3.0, block_t=8, block_c=100,
                        interpret=True)


# ------------------------------------------ float path: tiling invariance
@pytest.mark.parametrize("c,block_c", [(200, 128), (1, 128), (256, 256),
                                       (300, 128)])
def test_float_block_c_matches_single_strip(c, block_c):
    x = jnp.asarray(_x(96, c, seed=c + 1))
    fin1, out1 = teda_scan_tpu(x, 3.0, block_t=32, interpret=True)
    fin2, out2 = teda_scan_tpu(x, 3.0, block_t=32, block_c=block_c,
                               interpret=True)
    for key in ("mean", "var", "ecc", "outlier"):
        np.testing.assert_array_equal(np.asarray(out1[key]),
                                      np.asarray(out2[key]), err_msg=key)
    np.testing.assert_array_equal(np.asarray(fin1.var),
                                  np.asarray(fin2.var))


@pytest.mark.parametrize("block_c", [None, 128])
def test_float_verdict_ragged_with_block_c(block_c):
    t, c = 64, 150
    x = _x(t, c, seed=21)
    vl = np.random.default_rng(2).integers(0, t + 1,
                                           size=c).astype(np.int32)
    fin1, out1 = teda_scan_verdict(jnp.asarray(x), 3.0, block_t=32,
                                   interpret=True,
                                   valid_lens=jnp.asarray(vl))
    fin2, out2 = teda_scan_verdict(jnp.asarray(x), 3.0, block_t=32,
                                   block_c=block_c, interpret=True,
                                   valid_lens=jnp.asarray(vl))
    np.testing.assert_array_equal(np.asarray(out1["outlier"]),
                                  np.asarray(out2["outlier"]))
    np.testing.assert_array_equal(np.asarray(fin1.k),
                                  np.asarray(fin2.k))
    np.testing.assert_array_equal(np.asarray(fin1.var),
                                  np.asarray(fin2.var))
