"""Fixed-point subsystem: Q-op exactness vs big-int oracle + Q-TEDA
fidelity vs the float64 software oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.teda import teda_numpy_loop
from repro.fixedpoint import (QFormat, div_qi, div_qq, sat_add, sat_mul,
                              sat_sub, teda_q_stream, teda_q_scan_chan,
                              evaluate_format, wordlength_sweep)

FMT32 = QFormat(32, 20)


# ------------------------------------------------- exact big-int oracle
def _mul_ref(a, b, fmt):
    p = int(a) * int(b)
    neg, mag = p < 0, abs(p)
    if fmt.rounding == "round" and fmt.frac_len:
        mag += 1 << (fmt.frac_len - 1)
    return (-1 if neg else 1) * min(mag >> fmt.frac_len, fmt.qmax)


def _div_ref(n, d, fmt, shift):
    n, d = int(n), int(d)
    if d == 0:
        return fmt.qmax if n >= 0 else -fmt.qmax
    neg = (n < 0) != (d < 0)
    q, r = divmod(abs(n) << shift, abs(d))
    if fmt.rounding == "round" and 2 * r >= abs(d):
        q += 1
    return (-1 if neg else 1) * min(q, fmt.qmax)


@pytest.mark.parametrize("fmt", [
    QFormat(16, 8), QFormat(16, 8, "round"), QFormat(24, 12),
    QFormat(32, 20), QFormat(32, 20, "round"), QFormat(32, 30),
    QFormat(8, 4),
])
def test_q_ops_exact(fmt):
    """Every Q op must be bit-identical to arbitrary-precision math."""
    rng = np.random.default_rng(fmt.word_len * 100 + fmt.frac_len)
    a = rng.integers(fmt.qmin, fmt.qmax + 1, size=300).astype(np.int32)
    b = rng.integers(fmt.qmin, fmt.qmax + 1, size=300).astype(np.int32)
    k = rng.integers(1, 100000, size=300).astype(np.int32)
    aj, bj, kj = jnp.asarray(a), jnp.asarray(b), jnp.asarray(k)

    got = np.asarray(sat_mul(fmt, aj, bj))
    exp = np.array([_mul_ref(x, y, fmt) for x, y in zip(a, b)])
    np.testing.assert_array_equal(got, exp)

    got = np.asarray(div_qq(fmt, aj, bj))
    exp = np.array([_div_ref(x, y, fmt, fmt.frac_len)
                    for x, y in zip(a, b)])
    np.testing.assert_array_equal(got, exp)

    got = np.asarray(div_qi(fmt, aj, kj))
    exp = np.array([_div_ref(x, y, fmt, 0) for x, y in zip(a, k)])
    np.testing.assert_array_equal(got, exp)

    got = np.asarray(sat_add(fmt, aj, bj))
    exp = np.clip(a.astype(np.int64) + b.astype(np.int64),
                  fmt.qmin, fmt.qmax)
    np.testing.assert_array_equal(got, exp)

    got = np.asarray(sat_sub(fmt, aj, bj))
    exp = np.clip(a.astype(np.int64) - b.astype(np.int64),
                  fmt.qmin, fmt.qmax)
    np.testing.assert_array_equal(got, exp)


def test_divider_saturates_on_zero_divisor():
    fmt = QFormat(16, 8)
    z = np.asarray(div_qq(fmt, jnp.asarray([5, -5]), jnp.asarray([0, 0])))
    np.testing.assert_array_equal(z, [fmt.qmax, -fmt.qmax])


def test_quantize_roundtrip_within_one_lsb():
    fmt = QFormat(24, 16)
    x = np.linspace(-50.0, 50.0, 999).astype(np.float32)
    q = fmt.quantize(jnp.asarray(x))
    back = np.asarray(fmt.dequantize(q))
    assert np.abs(back - x).max() <= fmt.resolution


def test_quantize_saturates():
    fmt = QFormat(16, 12)  # range ~ +-8
    q = np.asarray(fmt.quantize(jnp.asarray([1e6, -1e6, np.nan])))
    np.testing.assert_array_equal(q, [fmt.qmax, fmt.qmin, 0])


def test_quantize_wl32_never_emits_int_min():
    """float32 can't represent qmin at WL=32: the clamp must happen in
    the integer domain, or -2^31 (outside the symmetric format) leaks
    into the datapath and breaks the |v| < 2^31 magnitude contract."""
    fmt = QFormat(32, 20)
    q = np.asarray(fmt.quantize(jnp.asarray([-3000.0, -1e30, 1e30])))
    np.testing.assert_array_equal(q, [fmt.qmin, fmt.qmin, fmt.qmax])
    # and the divider treats the saturated value correctly
    r = int(div_qq(fmt, jnp.asarray(fmt.qmin), jnp.asarray(fmt.one)))
    assert r == fmt.qmin  # -qmax / 1.0 == -qmax, not 0


def test_format_validation():
    with pytest.raises(ValueError):
        QFormat(33, 8).validate()
    with pytest.raises(ValueError):
        QFormat(16, 31).validate()
    with pytest.raises(ValueError):
        QFormat(16, 16).validate()  # frac_len must leave the sign bit
    with pytest.raises(ValueError):
        QFormat(16, 8, "stochastic").validate()
    QFormat(16, 15).validate()  # Q0.15-style fractional-only is legal


# --------------------------------------------------- Q-TEDA vs oracle
def _stream(t, n, seed=0, spike=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, n)).astype(np.float32)
    if spike is not None:
        lo, hi, amp = spike
        x[lo:hi] += amp
    return x


def test_q32_verdicts_match_float_oracle():
    """Acceptance bar: >= 99% verdict agreement at WL=32."""
    x = _stream(1200, 2, seed=1, spike=(800, 815, 7.0))
    r = evaluate_format(x, FMT32, 3.0)
    assert r["verdict_agreement"] >= 0.99
    assert r["max_abs_err_ecc"] < 1e-3
    assert r["n_outliers_ref"] > 0  # the spike is detected at all


def test_q_first_sample_and_constant_stream():
    """k=1 branch + var>0 guard: constant stream never flags."""
    x = jnp.ones((50, 2), jnp.float32) * 3.25
    _, out = teda_q_stream(x, FMT32, 3.0)
    assert not bool(np.asarray(out.outlier).any())
    # ecc == 1/k quantized: compare dequantized against 1/k
    ecc = FMT32.dequantize_np(np.asarray(out.ecc))
    np.testing.assert_allclose(ecc, 1.0 / np.arange(1, 51),
                               atol=2 * FMT32.resolution)


def test_q_state_continuation_bit_exact():
    """Integer datapath: carried-state restart is exactly bit-equal."""
    x = _stream(256, 3, seed=4)
    xj = jnp.asarray(x)
    _, full = teda_q_stream(xj, FMT32)
    st1, _ = teda_q_stream(xj[:100], FMT32)
    _, second = teda_q_stream(xj[100:], FMT32, state=st1)
    np.testing.assert_array_equal(np.asarray(second.ecc),
                                  np.asarray(full.ecc)[100:])
    np.testing.assert_array_equal(np.asarray(second.outlier),
                                  np.asarray(full.outlier)[100:])


def test_chan_scan_matches_multivariate_n1():
    """(T, C) channel driver == multivariate driver with N=1, bitwise."""
    x = _stream(200, 4, seed=5)
    fin, outs = teda_q_scan_chan(jnp.asarray(x), FMT32, 3.0)
    _, out_mv = teda_q_stream(jnp.asarray(x[:, :, None]), FMT32, 3.0)
    np.testing.assert_array_equal(np.asarray(outs["ecc"]),
                                  np.asarray(out_mv.ecc))
    np.testing.assert_array_equal(np.asarray(outs["zeta"]),
                                  np.asarray(out_mv.zeta))
    np.testing.assert_array_equal(np.asarray(outs["outlier"]),
                                  np.asarray(out_mv.outlier))


def test_wordlength_sweep_monotone_resolution():
    """Wider FL at fixed WL=32 must not increase eccentricity error."""
    x = _stream(600, 2, seed=7, spike=(400, 410, 6.0))
    rows = wordlength_sweep(x, [QFormat(32, 12), QFormat(32, 20)], 3.0)
    assert rows[1]["max_abs_err_ecc"] <= rows[0]["max_abs_err_ecc"]
    for r in rows:
        assert 0.0 <= r["verdict_agreement"] <= 1.0


def test_skinny_16bit_datapath_runs():
    """WL=16 still detects a huge spike even with coarse resolution."""
    x = _stream(600, 1, seed=8)
    x[500] += 40.0
    _, out = teda_q_stream(jnp.asarray(x), QFormat(16, 10), 3.0)
    assert bool(np.asarray(out.outlier)[500])


def test_q_output_dtypes_and_typicality():
    x = _stream(64, 2, seed=9)
    _, out = teda_q_stream(jnp.asarray(x), FMT32, 3.0)
    assert out.ecc.dtype == jnp.int32
    assert out.outlier.dtype == jnp.bool_
    # eq (4): typ = 1 - ecc in Q arithmetic (saturating)
    one = min(FMT32.one, FMT32.qmax)
    np.testing.assert_array_equal(
        np.asarray(out.typ),
        np.clip(one - np.asarray(out.ecc, np.int64),
                FMT32.qmin, FMT32.qmax))


def test_oracle_agreement_on_damadics_window():
    """Acceptance: >= 99% agreement on the DAMADICS stream at WL=32."""
    from repro.data.damadics import make_benchmark
    x, w = make_benchmark(6, t_len=40000)
    seg = x[w.start - 1000:w.stop + 200]
    ref = teda_numpy_loop(seg.astype(np.float64), 3.0)
    _, out = teda_q_stream(jnp.asarray(seg), FMT32, 3.0)
    agree = (np.asarray(out.outlier) == ref["outlier"]).mean()
    assert agree >= 0.99
    assert ref["outlier"].sum() > 0
