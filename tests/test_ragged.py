"""Ragged-stream conformance suite (ISSUE 4 acceptance).

One fused engine call may retire a different number of samples per
slot (`process(x, valid_lens=...)`, 0..T per slot).  The contract under
test, for every backend in the registry:

  * interleaved ragged calls are bit-exact (Q path) / fp32-tolerant
    (float paths) with running each slot's stream alone on a fresh
    single-slot engine — including vlen = 0 (full suspend), vlen = T
    (full chunk) and awkward remainders in one call;
  * no slot ever flags at rows >= its valid length;
  * attach / detach / reset mid-stream compose with raggedness without
    touching neighbours;
  * the degenerate vectors match the uniform path: all-T equals a plain
    `process(x)` call bit-for-bit, all-0 advances nothing.

The hypothesis-driven cases run a trimmed width by default;
`-m slow` (main-branch CI) runs the full-width sweep.
"""
import numpy as np
import pytest

from conftest import given_or_cases

from repro.engine import StreamEngine, list_backends
from repro.fixedpoint import QFormat

FMT = QFormat(32, 20)


def _mk(c, backend, **kw):
    kw.setdefault("block_t", 8)
    return StreamEngine(c, backend, fmt=FMT, **kw)


def _ragged_lens(rng, c, t):
    """Per-slot lengths covering the edges: a forced 0, a forced T, and
    arbitrary remainders everywhere else."""
    lens = rng.integers(0, t + 1, size=c).astype(np.int32)
    lens[rng.integers(0, c)] = 0
    lens[rng.integers(0, c)] = t
    return lens


def _ragged_calls(eng, rng, c, t, n_calls, spike_every=3):
    """Drive `eng` through ragged calls; returns (per-slot streams,
    per-slot collected verdict prefixes)."""
    streams = [[] for _ in range(c)]
    got = {"ecc": [[] for _ in range(c)], "outlier": [[] for _ in range(c)]}
    for call in range(n_calls):
        lens = _ragged_lens(rng, c, t)
        x = np.zeros((t, c), np.float32)
        for s in range(c):
            xs = rng.normal(size=int(lens[s])).astype(np.float32)
            if xs.size and (call + s) % spike_every == 0:
                xs[xs.size // 2] += 25.0  # make someone flag
            x[: lens[s], s] = xs
            streams[s].append(xs)
        out = eng.process(x, valid_lens=lens)
        ol = np.asarray(out["outlier"])
        ecc = np.asarray(out["ecc"])
        # the ragged-tail guarantee: no verdicts beyond a slot's length
        assert not ol[np.arange(t)[:, None] >= lens[None, :]].any()
        for s in range(c):
            got["ecc"][s].append(ecc[: lens[s], s])
            got["outlier"][s].append(ol[: lens[s], s])
    return streams, got


def _assert_slot_matches_isolated(backend, full, got_ecc, got_out,
                                  m=3.0, err=""):
    """One slot's interleaved verdicts vs its stream alone on slot 0 of
    a fresh single-slot engine (the isolation oracle)."""
    iso = _mk(1, backend, m=m)
    ref = iso.process(full[:, None])
    np.testing.assert_array_equal(
        got_out, np.asarray(ref["outlier"])[:, 0], err_msg=err)
    if backend == "pallas-q":  # quantized datapath: exact bits
        np.testing.assert_array_equal(
            got_ecc, np.asarray(ref["ecc"])[:, 0], err_msg=err)
        return iso
    np.testing.assert_allclose(got_ecc, np.asarray(ref["ecc"])[:, 0],
                               rtol=1e-4, atol=1e-6, err_msg=err)
    return iso


# ---------------------------------------------- ragged == isolated
@pytest.mark.parametrize("backend", list_backends())
@given_or_cases(
    "c,t,n_calls,seed", [(4, 8, 3, 0), (3, 5, 4, 1), (5, 11, 2, 2),
                         (2, 16, 3, 3)],
    lambda st: dict(c=st.integers(2, 5), t=st.integers(2, 16),
                    n_calls=st.integers(1, 4),
                    seed=st.integers(0, 2 ** 16)),
    max_examples=6)
def test_ragged_equals_isolated(backend, c, t, n_calls, seed):
    rng = np.random.default_rng(seed)
    eng = _mk(c, backend)
    streams, got = _ragged_calls(eng, rng, c, t, n_calls)
    total = 0
    for s in range(c):
        full = np.concatenate(streams[s])
        total += full.size
        assert eng.samples_seen[s] == full.size
        if not full.size:
            continue
        iso = _assert_slot_matches_isolated(
            backend, full, np.concatenate(got["ecc"][s]),
            np.concatenate(got["outlier"][s]), err=f"slot {s}")
        # final carried state agrees with the isolated run too
        if backend == "pallas-q":
            np.testing.assert_array_equal(
                np.asarray(eng.state.mean)[s], np.asarray(iso.state.mean)[0])
            np.testing.assert_array_equal(
                np.asarray(eng.state.var)[s], np.asarray(iso.state.var)[0])
        else:
            np.testing.assert_allclose(
                np.asarray(eng.state.var)[s], np.asarray(iso.state.var)[0],
                rtol=1e-4, atol=1e-6)
    assert int(np.asarray(eng.samples_seen).sum()) == total


@pytest.mark.slow
@pytest.mark.parametrize("backend", list_backends())
@given_or_cases(
    "c,t,n_calls,seed", [(8, 32, 6, 10), (6, 24, 8, 11), (9, 40, 5, 12)],
    lambda st: dict(c=st.integers(2, 9), t=st.integers(2, 48),
                    n_calls=st.integers(1, 8),
                    seed=st.integers(0, 2 ** 16)),
    max_examples=25)
def test_ragged_equals_isolated_full_width(backend, c, t, n_calls, seed):
    """The full-width sweep (main-branch CI): wider slot counts, longer
    chunks, more interleaved calls — same bit-exactness contract."""
    rng = np.random.default_rng(seed)
    eng = _mk(c, backend)
    streams, got = _ragged_calls(eng, rng, c, t, n_calls)
    for s in range(c):
        full = np.concatenate(streams[s])
        if not full.size:
            continue
        _assert_slot_matches_isolated(
            backend, full, np.concatenate(got["ecc"][s]),
            np.concatenate(got["outlier"][s]), err=f"slot {s}")


# ------------------------------------- tenancy churn between ragged calls
@pytest.mark.parametrize("backend", list_backends())
def test_ragged_with_midstream_tenancy_churn(backend):
    """attach / detach / reset between ragged calls: the churned slots
    behave like fresh streams, neighbours stay bit-exact."""
    rng = np.random.default_rng(7)
    c, t = 4, 10
    eng = _mk(c, backend)
    streams = [[] for _ in range(c)]
    got = {s: ([], []) for s in range(c)}  # (ecc parts, outlier parts)

    def ragged_call(lens):
        x = np.zeros((t, c), np.float32)
        for s in range(c):
            xs = rng.normal(size=int(lens[s])).astype(np.float32)
            x[: lens[s], s] = xs
            streams[s].append(xs)
        out = eng.process(x, valid_lens=np.asarray(lens, np.int32))
        for s in range(c):
            got[s][0].append(np.asarray(out["ecc"])[: lens[s], s])
            got[s][1].append(np.asarray(out["outlier"])[: lens[s], s])

    ragged_call([3, 10, 0, 7])
    # slot 1: new tenant mid-flight (detach + attach drops its history)
    eng.detach([1])
    eng.attach([1])
    streams[1], got[1] = [], ([], [])
    # slot 3: mid-flight reset (recycle in place)
    eng.reset([3])
    streams[3], got[3] = [], ([], [])
    ragged_call([5, 4, 10, 0])
    ragged_call([0, 10, 2, 6])

    for s in range(c):
        full = np.concatenate(streams[s]) if streams[s] else \
            np.zeros((0,), np.float32)
        assert eng.samples_seen[s] == full.size
        if full.size:
            _assert_slot_matches_isolated(
                backend, full, np.concatenate(got[s][0]),
                np.concatenate(got[s][1]), err=f"slot {s}")


@pytest.mark.parametrize("backend", list_backends())
def test_ragged_detached_slot_stays_frozen(backend):
    """A detached slot is pinned at vlen 0 even when the caller's
    valid_lens claims data for it."""
    c, t = 3, 6
    eng = _mk(c, backend, auto_attach=False)
    eng.attach([0, 2])
    x = np.random.default_rng(8).normal(size=(t, c)).astype(np.float32)
    x[:, 1] += 50.0  # would flag loudly if slot 1 advanced
    out = eng.process(x, valid_lens=[4, 6, 2])
    assert eng.samples_seen.tolist() == [4, 0, 2]
    assert not np.asarray(out["outlier"])[:, 1].any()


# --------------------------------------------------- degenerate vectors
@pytest.mark.parametrize("backend", list_backends())
def test_all_full_vlen_matches_uniform_call(backend):
    """valid_lens = [T]*C is the uniform path, bit-for-bit (identical
    compiled program — the scalar case is a broadcast, not a branch)."""
    c, t = 3, 20
    x = np.random.default_rng(9).normal(size=(t, c)).astype(np.float32)
    x[t // 2, 0] += 25.0
    plain, ragged = _mk(c, backend), _mk(c, backend)
    out_p = plain.process(x)
    out_r = ragged.process(x, valid_lens=np.full((c,), t, np.int32))
    np.testing.assert_array_equal(np.asarray(out_p["ecc"]),
                                  np.asarray(out_r["ecc"]))
    np.testing.assert_array_equal(np.asarray(out_p["outlier"]),
                                  np.asarray(out_r["outlier"]))
    np.testing.assert_array_equal(np.asarray(plain.state.mean),
                                  np.asarray(ragged.state.mean))
    np.testing.assert_array_equal(np.asarray(plain.state.var),
                                  np.asarray(ragged.state.var))


@pytest.mark.parametrize("backend", list_backends())
def test_all_zero_vlen_advances_nothing(backend):
    """valid_lens = 0 everywhere: a no-op call — state frozen at the
    exact packed values (no float round-trip), zero flags."""
    c, t = 3, 12
    rng = np.random.default_rng(10)
    eng = _mk(c, backend)
    eng.process(rng.normal(size=(t, c)).astype(np.float32))
    before = eng.state
    out = eng.process(rng.normal(size=(t, c)).astype(np.float32) + 100.0,
                      valid_lens=0)
    assert not np.asarray(out["outlier"]).any()
    np.testing.assert_array_equal(np.asarray(before.k),
                                  np.asarray(eng.state.k))
    np.testing.assert_array_equal(np.asarray(before.mean),
                                  np.asarray(eng.state.mean))
    np.testing.assert_array_equal(np.asarray(before.var),
                                  np.asarray(eng.state.var))


def test_valid_lens_validation():
    eng = _mk(3, "scan")
    x = np.zeros((4, 3), np.float32)
    with pytest.raises(ValueError, match=r"\[0, T=4\]"):
        eng.process(x, valid_lens=[1, 5, 0])   # beyond T
    with pytest.raises(ValueError, match=r"\[0, T=4\]"):
        eng.process(x, valid_lens=[-1, 2, 0])  # negative
    with pytest.raises(ValueError, match="scalar or"):
        eng.process(x, valid_lens=[1, 2])      # wrong width
