"""Substrate tests: optimizer, checkpointing, data pipeline, sharding rules."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import PrefetchIterator, TokenStream, batch_stats
from repro.core.guard import GuardConfig
from repro.optim import adamw


# ------------------------------------------------------------ optimizer --
def _params():
    return {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))}


def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.asarray(5.0)}
    state = adamw.init(params)

    def loss(p):
        return (p["w"] - 1.0) ** 2

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(g, state, params, cfg)
    assert abs(float(params["w"]) - 1.0) < 0.3


def test_adamw_skip_is_noop():
    cfg = adamw.AdamWConfig()
    params = _params()
    state = adamw.init(params)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    new_p, new_s, m = adamw.update(grads, state, params, cfg, skip=True)
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), new_p, params))
    assert int(new_s.count) == 0
    assert float(m["skipped"]) == 1.0


def test_adamw_clipping():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = _params()
    state = adamw.init(params)
    grads = jax.tree_util.tree_map(lambda p: 1e6 * jnp.ones_like(p), params)
    new_p, _, m = adamw.update(grads, state, params, cfg)
    assert float(m["grad_norm"]) > 1e5
    delta = float(jnp.max(jnp.abs(new_p["w"] - params["w"])))
    assert delta < 1.0  # clipped update stays bounded


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lr1 = float(adamw.schedule(cfg, jnp.asarray(1)))
    lr10 = float(adamw.schedule(cfg, jnp.asarray(10)))
    lr100 = float(adamw.schedule(cfg, jnp.asarray(100)))
    assert lr1 < lr10
    assert abs(lr10 - 1.0) < 1e-5
    assert abs(lr100 - 0.1) < 1e-2


# ----------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "count": jnp.asarray(7)}
    mgr.save(5, state)
    assert mgr.latest_step() == 5
    restored, meta = mgr.restore(state)
    np.testing.assert_allclose(restored["params"]["w"],
                               np.arange(6.0).reshape(2, 3))
    assert meta["step"] == 5


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full(3, float(s))})
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2  # keep-K gc
    restored, meta = mgr.restore(state)
    assert meta["step"] == 4
    np.testing.assert_allclose(restored["x"], 4.0)


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, {"x": jnp.ones(4)})
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"x": jnp.ones((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore({"x": jnp.ones((3, 3))})


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore onto explicit (new-mesh) shardings."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = {"x": jnp.arange(8.0)}
    mgr.save(1, state)
    from repro.sharding.rules import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    sh = {"x": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))}
    restored, _ = mgr.restore(state, shardings=sh)
    assert restored["x"].sharding.is_equivalent_to(sh["x"], 1)


# ------------------------------------------------------------------ data --
def test_tokenstream_deterministic_and_indexable():
    s = TokenStream(1000, 4, 32, seed=3)
    a = s.batch_at(10)["tokens"]
    b = s.batch_at(10)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 33)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 1000
    it = iter(s)
    first = next(it)["tokens"]
    np.testing.assert_array_equal(first, s.batch_at(0)["tokens"])


def test_tokenstream_corrupt_every():
    s = TokenStream(100, 2, 16, corrupt_every=5)
    assert (s.batch_at(5)["tokens"] == 99).all()
    assert not (s.batch_at(4)["tokens"] == 99).all()


def test_prefetch_screen_drops_corrupt():
    # corruption starts after warmup AND after k > m^2: since eq (3)'s
    # variance absorbs the current sample, zeta <= (k+1)/(2k), so eq (6)
    # with m is untrippable until k > m^2 (see DESIGN.md §7) — an earlier
    # spike slips through and contaminates the stats.
    src = (TokenStream(100, 2, 16, corrupt_every=10).batch_at(i)
           for i in range(40))
    it = PrefetchIterator(src, depth=2,
                          screen=GuardConfig(m=3.0, warmup_steps=6,
                                             channels=2))
    batches = list(it)
    assert it.dropped >= 3  # corrupt batches screened out post-warmup
    assert all(not (b["tokens"] == 99).all() for b in batches)


def test_batch_stats_shape():
    s = batch_stats({"tokens": np.ones((2, 8), np.int32)})
    assert s.shape == (2,)


# ------------------------------------------------------- sharding rules --
def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import abstract_mesh, param_spec
    mesh = abstract_mesh((16, 16), ("data", "model"))
    # big 2D up-projection: FSDP in, TP out
    assert param_spec(mesh, "blocks_0/mlp/wi/w", (48, 8192, 22016)) == \
        P(None, "data", "model")
    # down-projection: contracting dim on model
    assert param_spec(mesh, "blocks_0/mlp/wo/w", (48, 22016, 8192)) == \
        P(None, "model", "data")
    # embedding: vocab on model
    assert param_spec(mesh, "embed/table", (128256, 4096)) == \
        P("model", "data")
    # experts: EP on E, FSDP on the ff dim (dispatch-intermediate
    # sharding — see rules.py)
    assert param_spec(mesh, "blocks_0/moe/wi", (48, 16, 6144, 10752)) == \
        P(None, "model", None, "data")
    assert param_spec(mesh, "blocks_0/moe/wo", (48, 16, 10752, 6144)) == \
        P(None, "model", "data", None)
    # experts: TP fallback when not divisible
    assert param_spec(mesh, "blocks_0/moe/wi", (32, 8, 4096, 14336)) == \
        P(None, None, "data", "model")
    # tiny arrays replicate
    assert param_spec(mesh, "final_norm/scale", (4096,)) == P()


def test_batch_and_cache_specs():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import abstract_mesh, batch_spec, cache_spec
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert batch_spec(mesh, 256) == P(("pod", "data"), None)
    assert batch_spec(mesh, 16) == P("data", None)
    # decode cache: batch shardable
    assert cache_spec(mesh, (32, 128, 32768, 8, 128)) == \
        P(None, ("pod", "data"), None, None, "model")
    # batch=1: context parallelism over sequence
    assert cache_spec(mesh, (13, 1, 524288, 4, 256)) == \
        P(None, None, "data", None, "model")
