"""StreamEngine: chunked-equals-full property suite + ragged slots.

Acceptance (ISSUE 2): for each backend, feeding a stream in random-sized
chunks through `StreamEngine` must reproduce the single-shot result
bit-for-bit (Q path) / to fp32 tolerance (float paths), including
`T % block_t != 0` remainders and mid-stream resets; per-channel `k` is
preserved end-to-end and a valid final state exists for every T.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import given_or_cases

from repro.engine import (StreamEngine, engine_init, engine_step,
                          list_backends)
from repro.fixedpoint import QFormat
from repro.kernels.ref import teda_ref

FMT = QFormat(32, 20)


def _x(t, c, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, c)).astype(np.float32)
    x[t // 2, : max(1, c // 2)] += 20.0  # make someone flag
    return x


def _mk(c, backend, block_t=32, **kw):
    return StreamEngine(c, backend, fmt=FMT, block_t=block_t, **kw)


def _split(x, seed):
    """Random ragged chunking of x along time (chunk lens >= 1)."""
    rng = np.random.default_rng(seed)
    t = x.shape[0]
    cuts, i = [], 0
    while i < t:
        i += int(rng.integers(1, max(2, t // 3)))
        cuts.append(min(i, t))
    return np.split(x, cuts[:-1], axis=0)


def _run_chunked(eng, parts):
    outs = [eng.process(p) for p in parts]
    return {k: np.concatenate([np.asarray(o[k]) for o in outs], 0)
            for k in outs[0]}


# ------------------------------------------------- chunked == full (all)
@pytest.mark.parametrize("backend", list_backends())
@given_or_cases(
    "t,c,seed", [(70, 3, 0), (129, 2, 1), (256, 5, 2), (37, 1, 3)],
    lambda st: dict(t=st.integers(2, 300), c=st.integers(1, 8),
                    seed=st.integers(0, 2 ** 16)),
    max_examples=6)
def test_chunked_equals_full(backend, t, c, seed):
    x = _x(t, c, seed)
    full = _mk(c, backend)
    chunked = _mk(c, backend)
    out_f = full.process(x)
    out_c = _run_chunked(chunked, _split(x, seed + 1))
    if backend == "pallas-q":  # quantized datapath: exact bits
        np.testing.assert_array_equal(np.asarray(out_f["ecc"]),
                                      out_c["ecc"])
        np.testing.assert_array_equal(np.asarray(full.state.mean),
                                      np.asarray(chunked.state.mean))
        np.testing.assert_array_equal(np.asarray(full.state.var),
                                      np.asarray(chunked.state.var))
    else:
        np.testing.assert_allclose(np.asarray(out_f["ecc"]), out_c["ecc"],
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(full.state.var),
                                   np.asarray(chunked.state.var),
                                   rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out_f["outlier"]),
                                  out_c["outlier"])
    # per-channel k preserved end-to-end, valid for every T
    assert full.samples_seen.tolist() == [t] * c
    assert chunked.samples_seen.tolist() == [t] * c


@pytest.mark.parametrize("backend", list_backends())
def test_remainder_chunks_match_oracle(backend):
    """T % block_t != 0 everywhere: 3 chunks of awkward lengths."""
    x = _x(70 + 33 + 5, 2, seed=7)
    eng = _mk(2, backend, block_t=64)
    out = _run_chunked(eng, [x[:70], x[70:103], x[103:]])
    ref = teda_ref(np.asarray(x, np.float32), 3.0)
    np.testing.assert_array_equal(out["outlier"], ref["outlier"])
    np.testing.assert_allclose(np.asarray(eng.state.k), 108.0)


# -------------------------------------------------------- ragged tenancy
@pytest.mark.parametrize("backend", list_backends())
def test_mid_stream_reset_recycles_slot(backend):
    """Resetting a slot mid-flight == a fresh stream on that slot."""
    c = 4
    xa, xb = _x(57, c, seed=11), _x(61, c, seed=12)
    eng = _mk(c, backend)
    eng.process(xa)
    eng.reset([2])
    out = eng.process(xb)

    fresh = _mk(c, backend)  # slot 2's post-reset oracle: xb alone
    out_fresh = fresh.process(xb)
    np.testing.assert_array_equal(np.asarray(out["outlier"])[:, 2],
                                  np.asarray(out_fresh["outlier"])[:, 2])
    # untouched slots carried on: k = 57 + 61, reset slot k = 61
    assert eng.samples_seen.tolist() == [118, 118, 61, 118]

    cont = _mk(c, backend)  # slot 0's oracle: the uninterrupted stream
    cont.process(np.concatenate([xa, xb], 0))
    if backend == "pallas-q":
        np.testing.assert_array_equal(np.asarray(eng.state.var)[0],
                                      np.asarray(cont.state.var)[0])
    else:
        np.testing.assert_allclose(np.asarray(eng.state.var)[0],
                                   np.asarray(cont.state.var)[0],
                                   rtol=1e-4)


@pytest.mark.parametrize("backend", list_backends())
def test_detached_slots_never_advance_or_flag(backend):
    c = 4
    eng = _mk(c, backend, auto_attach=False)
    eng.attach([0, 2])
    x = _x(40, c, seed=21)
    x[:, 1] += 50.0  # would flag loudly if slot 1 were live
    out = eng.process(x)
    assert not np.asarray(out["outlier"])[:, [1, 3]].any()
    assert eng.samples_seen.tolist() == [40, 0, 40, 0]
    assert eng.active_slots.tolist() == [0, 2]
    eng.detach([0])
    assert eng.active_slots.tolist() == [2]
    assert eng.samples_seen[0] == 0  # detach clears the tenant's state


def test_attach_n_free_slots():
    eng = StreamEngine(6, "scan", auto_attach=False)
    got = eng.attach(n=4)
    assert len(got) == 4
    with pytest.raises(ValueError):
        eng.attach(n=3)  # only 2 free


def test_attach_full_engine_raises_with_occupancy():
    """Regression (ISSUE 3): attach on a full engine must raise with
    the occupancy, not no-op via scatter's silent OOB-drop semantics."""
    eng = StreamEngine(3, "scan", auto_attach=False)
    eng.attach()  # grabs all free slots
    with pytest.raises(ValueError, match=r"3/3"):
        eng.attach()
    with pytest.raises(ValueError, match=r"3/3"):
        eng.attach(n=1)


def test_attach_occupied_slot_raises():
    """An explicit attach on a live tenant's slot must not clobber it."""
    eng = StreamEngine(4, "scan", auto_attach=False)
    eng.attach([1])
    eng.process(_x(10, 4, seed=61))
    with pytest.raises(ValueError, match=r"\[1\] already attached"):
        eng.attach([1, 2])
    assert eng.samples_seen[1] == 10  # tenant untouched by the failure
    eng.detach([1])
    eng.attach([1, 2])  # fine once freed


@pytest.mark.parametrize("backend", list_backends())
def test_per_slot_m_matches_scalar_engines(backend):
    """A mixed-m batch equals per-m scalar engines column for column.

    The m values are deliberately non-dyadic: the Q backend must
    quantize the per-slot m^2+1 ROM constants on the host (exactly),
    not through the float32 tracer."""
    c = 4
    x = _x(50, c, seed=71)
    mixed = _mk(c, backend)
    mixed.set_m([0, 1], 1.7)
    mixed.set_m([2, 3], 6.3)
    out = mixed.process(x)
    lo = _mk(c, backend, m=1.7).process(x)
    hi = _mk(c, backend, m=6.3).process(x)
    got = np.asarray(out["outlier"])
    np.testing.assert_array_equal(got[:, :2], np.asarray(lo["outlier"])[:, :2])
    np.testing.assert_array_equal(got[:, 2:], np.asarray(hi["outlier"])[:, 2:])
    # sensitivity ordering: the tighter threshold flags at least as often
    assert got[:, :2].sum() >= got[:, 2:].sum()
    if backend == "pallas-q":  # ecc is m-independent and stays bit-exact
        np.testing.assert_array_equal(np.asarray(out["ecc"]),
                                      np.asarray(lo["ecc"]))


def test_msq1_vector_matches_scalar_for_awkward_m():
    """Host quantization of per-slot m^2+1 is exact: a vector of any
    (non-dyadic) m yields the same Q bits as the scalar ROM path."""
    import numpy as np
    from repro.fixedpoint.teda_q import msq1_const
    for m in (2.3, 1.7, 3.0, 6.3):
        scalar = msq1_const(FMT, m)
        vec = np.asarray(msq1_const(FMT, np.full((5,), m, np.float64)))
        assert vec.tolist() == [scalar] * 5, m
    # integer input is taken as already-quantized
    assert int(msq1_const(FMT, jnp.int32(12345))) == 12345


def test_attach_sets_tenant_m_and_detach_restores_default():
    eng = StreamEngine(3, "scan", m=3.0, auto_attach=False)
    eng.attach([0], m=1.25)
    assert eng.slot_m.tolist() == [1.25, 3.0, 3.0]
    eng.detach([0])
    assert eng.slot_m.tolist() == [3.0, 3.0, 3.0]


def test_set_m_vector_is_positional():
    """Regression: a vector m must follow the caller's slot order (a
    mask-based assign silently re-sorted it), and bad slots raise."""
    eng = StreamEngine(4, "scan", m=3.0)
    eng.set_m([3, 1], [2.0, 5.0])
    assert eng.slot_m.tolist() == [3.0, 5.0, 3.0, 2.0]
    eng.set_m(None, 4.0)
    assert eng.slot_m.tolist() == [4.0] * 4
    eng.set_m(np.array([True, False, False, True]), 1.5)
    assert eng.slot_m.tolist() == [1.5, 4.0, 4.0, 1.5]
    with pytest.raises(IndexError):
        eng.set_m([4], 2.0)


@pytest.mark.parametrize("backend", list_backends())
def test_per_call_active_mask_suspends_without_detach(backend):
    """The scheduler's suspend: masked-out slots freeze but keep state."""
    c = 4
    xa, xb = _x(16, c, seed=81), _x(16, c, seed=82)
    eng = _mk(c, backend)
    eng.process(xa, active=[0, 1])
    out = eng.process(xb, active=[1])
    assert eng.samples_seen.tolist() == [16, 32, 0, 0]
    assert not np.asarray(out["outlier"])[:, [0, 2, 3]].any()
    # slot 1 advanced exactly like an unsuspended stream
    cont = _mk(c, backend)
    cont.process(xa)
    ref = cont.process(xb)
    np.testing.assert_array_equal(np.asarray(out["outlier"])[:, 1],
                                  np.asarray(ref["outlier"])[:, 1])


def test_per_channel_k_raggedness():
    """Slots attached at different times have honestly different k."""
    eng = StreamEngine(3, "pallas", block_t=32, auto_attach=False)
    eng.attach([0])
    eng.process(_x(20, 3, seed=31))
    eng.attach([1])
    eng.process(_x(25, 3, seed=32))
    assert eng.samples_seen.tolist() == [45, 25, 0]
    st = eng.teda_state()
    assert np.asarray(st.k).tolist() == [45, 25, 0]


# ------------------------------------------------------ functional core
def test_engine_step_matches_process():
    """The T=1 fast path agrees with chunked processing."""
    c = 3
    x = _x(30, c, seed=41)
    es = engine_init(c)
    flags = []
    for row in x:
        es, out = engine_step(es, jnp.asarray(row), 3.0)
        flags.append(np.asarray(out.outlier))
    eng = StreamEngine(c, "scan")
    ref = eng.process(x)
    np.testing.assert_array_equal(np.stack(flags), np.asarray(ref["outlier"]))
    np.testing.assert_allclose(np.asarray(es.var),
                               np.asarray(eng.state.var), rtol=1e-5)


def test_unknown_backend_raises():
    with pytest.raises(KeyError):
        StreamEngine(4, "fpga")


def test_pallas_q_requires_fmt():
    with pytest.raises(ValueError):
        StreamEngine(4, "pallas-q")


@pytest.mark.parametrize("backend", list_backends())
def test_sharded_fanout_single_device(backend):
    """mesh fan-out == plain processing (1-device mesh; the multi-device
    path is exercised by tests/test_distributed.py's forked runner)."""
    import jax
    from repro.sharding.rules import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    x = _x(48, 4, seed=51)
    plain = _mk(4, backend)
    sharded = _mk(4, backend, mesh=mesh)
    o1, o2 = plain.process(x), sharded.process(x)
    np.testing.assert_array_equal(np.asarray(o1["outlier"]),
                                  np.asarray(o2["outlier"]))
    del jax


def test_fanout_capacity_divisibility():
    from repro.sharding.rules import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    StreamEngine(4, "scan", mesh=mesh)  # divisible: fine
