"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement), plus a decode step
and a real optimizer update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, shape_specs
from repro.models import (encdec_decode_step, encdec_loss, init_cache,
                          init_encdec_cache, init_encdec_params,
                          init_lm_params, lm_decode_step, lm_forward,
                          lm_loss)

KEY = jax.random.PRNGKey(0)


def _setup(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "encdec":
        params = init_encdec_params(KEY, cfg)
        batch = {"src_emb": jax.random.normal(KEY, (2, 16, cfg.d_model)),
                 "tokens": jax.random.randint(KEY, (2, 33), 0, cfg.vocab)}
        loss_fn = encdec_loss
    else:
        params = init_lm_params(KEY, cfg)
        batch = {"tokens": jax.random.randint(KEY, (2, 33), 0, cfg.vocab)}
        loss_fn = lm_loss
    return cfg, params, batch, loss_fn


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg, params, batch, loss_fn = _setup(arch)
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch, cfg)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, arch
    # one SGD step must reduce nothing structurally (shape preservation)
    new = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                                 params, grads)
    shapes_ok = jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: a.shape == b.shape, params, new))
    assert shapes_ok


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_logits_shape(arch):
    cfg, params, batch, _ = _setup(arch)
    if cfg.family == "encdec":
        pytest.skip("encdec covered by loss test")
    logits, _ = lm_forward(params, batch["tokens"][:, :-1], cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg, params, _, _ = _setup(arch)
    tok = jnp.zeros((2,), jnp.int32)
    if cfg.family == "encdec":
        caches = init_encdec_cache(cfg, 2, 64, 16)
        logits, caches2 = encdec_decode_step(params, tok, jnp.int32(3),
                                             caches, cfg)
    else:
        caches = init_cache(cfg, 2, 64)
        logits, caches2 = lm_decode_step(params, tok, jnp.int32(3), caches,
                                         cfg)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache pytree structure preserved
    assert (jax.tree_util.tree_structure(caches)
            == jax.tree_util.tree_structure(caches2))


def test_shape_specs_cover_assignment():
    cells = sum(len(shape_specs(a)) for a in ARCHS)
    skipped = sum(1 for a in ARCHS
                  for _ in [0] if len(shape_specs(a)) == 3)
    assert cells + skipped == 40  # 10 archs x 4 shapes
    assert skipped == 5  # pure full-attention archs skip long_500k


def test_decode_prefix_consistency():
    """Decoding t tokens step-by-step == forward on the same prefix."""
    cfg = get_config("llama3_2_1b").reduced()
    params = init_lm_params(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    logits_full, _ = lm_forward(params, toks, cfg)
    caches = init_cache(cfg, 1, 16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, caches = lm_decode_step(params, toks[:, t], jnp.int32(t),
                                    caches, cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)  # (1, 8, vocab)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=5e-2, atol=5e-2)
    # verdict-level agreement: same argmax at every position
    np.testing.assert_array_equal(np.asarray(dec.argmax(-1)),
                                  np.asarray(logits_full.argmax(-1)))


def test_chunked_ce_equals_direct():
    """Flash-CE (chunked, recomputed logits) == direct CE, value & grad."""
    from repro.models import chunked_ce, init_lm_params, lm_backbone, lm_logits
    import dataclasses
    cfg = get_config("llama3_2_1b").reduced()
    params = init_lm_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 65), 0, cfg.vocab)
    inp, tgt = toks[:, :-1], toks[:, 1:]

    def ce(params, chunk):
        x, _ = lm_backbone(params, inp, cfg)
        return chunked_ce(lambda h: lm_logits(params, h, cfg), x, tgt,
                          chunk)

    v_direct, g_direct = jax.value_and_grad(ce)(params, 0)
    v_chunk, g_chunk = jax.value_and_grad(ce)(params, 16)
    np.testing.assert_allclose(float(v_direct), float(v_chunk), rtol=1e-5)
    # embedding grads accumulate per chunk -> f32 reassociation ~1e-2 rel
    for a, b in zip(jax.tree_util.tree_leaves(g_direct),
                    jax.tree_util.tree_leaves(g_chunk)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=2e-3)


def test_vocab_padding_masked():
    """Padded embedding rows never win the softmax (seamless 256206)."""
    from repro.models.common import vocab_padded
    cfg = get_config("seamless_m4t_medium").reduced(vocab=250)  # pads->256
    assert vocab_padded(cfg) == 256
    params = init_encdec_params(KEY, cfg)
    assert params["embed"]["table"].shape[0] == 256
    batch = {"src_emb": jax.random.normal(KEY, (2, 16, cfg.d_model)),
             "tokens": jax.random.randint(KEY, (2, 17), 0, 250)}
    loss, _ = encdec_loss(params, batch, cfg)
    assert jnp.isfinite(loss)
    # decode logits: padded tail is -inf so argmax < 250
    caches = init_encdec_cache(cfg, 2, 32, 16)
    logits, _ = encdec_decode_step(params, jnp.zeros((2,), jnp.int32),
                                   jnp.int32(0), caches, cfg)
    assert logits.shape == (2, 256)
    assert int(logits.argmax(-1).max()) < 250
    assert float(logits[:, 250:].max()) < -1e20


def test_outer_scan_matches_flat_scan():
    """sqrt-remat two-level scan == single-level scan numerically."""
    import dataclasses
    cfg = get_config("llama3_2_1b").reduced(n_layers=4)
    params = init_lm_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 33), 0, cfg.vocab)
    l1, _ = lm_loss(params, {"tokens": toks}, cfg)
    cfg2 = dataclasses.replace(cfg, outer_scan=2, remat=True)
    l2, _ = lm_loss(params, {"tokens": toks}, cfg2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_grad_accumulation_matches_full_batch():
    """k-micro accumulation == single-batch step (same update)."""
    from repro.launch.specs import make_train_step
    from repro.optim import adamw
    from repro.launch.specs import GUARD_CFG
    from repro.core.guard import guard_init
    cfg = get_config("llama3_2_1b").reduced()
    opt_cfg = adamw.AdamWConfig(clip_norm=None)  # clip is nonlinear in k
    params = init_lm_params(KEY, cfg)
    opt = adamw.init(params, opt_cfg)
    guard = guard_init(GUARD_CFG)
    batch = {"tokens": jax.random.randint(KEY, (8, 33), 0, cfg.vocab)}
    s1 = make_train_step(cfg, opt_cfg, accum_steps=1)
    s4 = make_train_step(cfg, opt_cfg, accum_steps=4)
    p1, _, _, m1 = s1(params, opt, guard, batch)
    p4, _, _, m4 = s4(params, opt, guard, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)
