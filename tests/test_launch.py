"""Launch-layer tests: mesh builders, cell specs, mini dry-run, train loop,
pipeline parallelism. Multi-device pieces run in subprocesses so the main
pytest process keeps its single CPU device."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.configs.registry import all_cells, get_config
from repro.launch.train import train

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sub(script: str, timeout=560):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_cell_enumeration():
    cells = list(all_cells())
    assert len(cells) == 40
    assert sum(1 for *_, skip in cells if skip) == 5


def test_train_loop_runs_and_improves():
    cfg = get_config("llama3.2-1b").reduced()
    _, hist, _ = train(cfg, steps=8, batch=4, seq=32, ckpt_dir=None,
                       log_every=100)
    assert len(hist) == 8
    assert all(jnp.isfinite(h["loss"]) for h in hist)


def test_train_checkpoint_resume(tmp_path):
    cfg = get_config("llama3.2-1b").reduced()
    train(cfg, steps=4, batch=2, seq=32, ckpt_dir=str(tmp_path),
          save_every=2)
    _, hist, _ = train(cfg, steps=6, batch=2, seq=32,
                       ckpt_dir=str(tmp_path), resume=True)
    assert len(hist) == 2  # resumed at step 4 of 6


@pytest.mark.slow
def test_mini_dryrun_all_kinds():
    """Lower+compile train/prefill/decode cells on an 8-device mesh with
    reduced configs — the dry-run machinery end-to-end (the production
    16x16 / 2x16x16 sweep runs via python -m repro.launch.dryrun)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs.registry import ShapeSpec, get_config
        from repro.launch.specs import build_cell
        from repro.launch.hlo_analysis import collective_stats, \
            cost_analysis_compat, roofline_terms
        from repro.sharding.rules import make_mesh_compat
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        for arch in ("mixtral_8x7b", "zamba2_2p7b", "gemma2_2b"):
            cfg = get_config(arch).reduced()
            for kind, b, s in (("train", 8, 64), ("prefill", 8, 64),
                               ("decode", 8, 64)):
                sp = ShapeSpec(f"mini_{kind}", s, b, kind)
                cell = build_cell(arch, sp, mesh, cfg)
                with mesh:
                    comp = jax.jit(
                        cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings,
                        donate_argnums=cell.donate_argnums,
                    ).lower(*cell.args).compile()
                cost = cost_analysis_compat(comp)
                assert float(cost.get("flops", 0)) > 0
                stats = collective_stats(comp.as_text())
                terms = roofline_terms(1e12, 1e9, stats["total_bytes"])
                assert terms["bottleneck"] in ("compute", "memory",
                                               "collective")
                print("OK", arch, kind)
        print("MINI_DRYRUN_OK")
    """)
    out = _sub(script)
    assert "MINI_DRYRUN_OK" in out


@pytest.mark.slow
def test_pipeline_parallel_4stage():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.pipeline import make_pipelined
        from repro.sharding.rules import make_mesh_compat
        mesh = make_mesh_compat((4,), ("pipe",))
        # 4 affine stages; reference = composed application
        ws = jnp.asarray([[2.0], [0.5], [3.0], [1.0]])  # (S, 1) scales
        def stage(w, x):
            return x * w[0]
        run = make_pipelined(mesh, stage, 4)
        x = jnp.arange(24.0).reshape(6, 4)  # 6 microbatches of 4
        out = run(ws, x)
        ref = x * 2.0 * 0.5 * 3.0 * 1.0
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6)
        print("PIPE_OK")
    """)
    out = _sub(script)
    assert "PIPE_OK" in out


def test_collective_parser():
    from repro.launch.hlo_analysis import collective_stats
    hlo = (
        "%ag = f32[16,1024]{1,0} all-gather(f32[1,1024]{1,0} %p), "
        "replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}\n"
        "%ar = (f32[128]{0}, f32[64]{0}) all-reduce(%a, %b), "
        "replica_groups=[2,8]<=[16]\n"
        "%cp = bf16[32,32]{1,0} collective-permute(%x), "
        "source_target_pairs={{0,1}}\n")
    st = collective_stats(hlo)
    ag = 16 * 1024 * 4 * (15 / 16)
    ar = (128 + 64) * 4 * 2 * (7 / 8)
    cp = 32 * 32 * 2
    assert abs(st["all-gather"] - ag) < 1
    assert abs(st["all-reduce"] - ar) < 1
    assert abs(st["collective-permute"] - cp) < 1
    assert st["all-gather_count"] == 1


@pytest.mark.slow
def test_teda_distributed_dryrun_both_meshes():
    """The paper's technique on the production meshes: compile +
    O(devices) collective traffic, independent of stream length."""
    script = textwrap.dedent("""
        from repro.launch.teda_dryrun import run
        a = run(False, 1 << 20, 4)
        b = run(True, 1 << 20, 4)
        assert a["devices"] == 256 and b["devices"] == 512
        for r in (a, b):
            assert r["collectives"]["total_bytes"] < 10_000  # O(D*N)
            assert r["collectives"]["all-gather_count"] == 3
        print("TEDA_DRYRUN_OK")
    """)
    out = _sub(script)
    assert "TEDA_DRYRUN_OK" in out
