"""Pallas TEDA kernel: shape/dtype sweeps + property tests vs ref.py."""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import given_or_cases


from repro.kernels.ops import teda_scan_tpu
from repro.kernels.ref import teda_ref


def _x(t, c, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(t, c)).astype(dtype)


def _check(x, m=3.0, block_t=64, state=None, k0=0, sum0=None, var0=None,
           rtol=5e-4):
    ref = teda_ref(np.asarray(x, np.float32), m, k0=k0, sum0=sum0, var0=var0)
    fin, out = teda_scan_tpu(jnp.asarray(x), m, state=state, block_t=block_t)
    np.testing.assert_allclose(np.asarray(out["mean"]), ref["mean"],
                               rtol=rtol, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["var"]), ref["var"],
                               rtol=rtol, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["ecc"]), ref["ecc"],
                               rtol=rtol, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out["outlier"]), ref["outlier"])
    return fin, out


# ----------------------------------------------------------- shape sweeps
@pytest.mark.parametrize("t", [8, 64, 100, 256, 1000])
@pytest.mark.parametrize("c", [1, 3, 128, 200])
def test_shapes(t, c):
    _check(_x(t, c, seed=t * 1000 + c))


@pytest.mark.parametrize("block_t", [8, 32, 64, 256, 512])
def test_block_sizes(block_t):
    """Chunking must not change results (carry correctness)."""
    x = _x(777, 5, seed=11)
    _check(x, block_t=block_t)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16, np.float16])
def test_dtypes(dtype):
    x = _x(256, 4, seed=12).astype(dtype)
    # low-precision inputs are up-cast in-kernel; compare vs f32 ref loosely
    ref = teda_ref(np.asarray(x, np.float32), 3.0)
    _, out = teda_scan_tpu(jnp.asarray(x), 3.0, block_t=64)
    np.testing.assert_allclose(np.asarray(out["ecc"]), ref["ecc"],
                               rtol=2e-2, atol=1e-3)


def test_state_carry_across_calls():
    """Two chunked kernel calls == one call (streaming restart)."""
    x = _x(512, 3, seed=13)
    full_fin, full = teda_scan_tpu(jnp.asarray(x), block_t=64)
    st1, _ = teda_scan_tpu(jnp.asarray(x[:256]), block_t=64)
    st2, out2 = teda_scan_tpu(jnp.asarray(x[256:]), state=st1, block_t=64)
    np.testing.assert_allclose(np.asarray(out2["ecc"]),
                               np.asarray(full["ecc"])[256:], rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(st2.var),
                               np.asarray(full_fin.var), rtol=1e-4)


def test_spike_detection_per_channel():
    x = _x(400, 4, seed=14)
    x[300:305, 2] += 25.0
    _, out = teda_scan_tpu(jnp.asarray(x), 3.0)
    flags = np.asarray(out["outlier"])
    assert flags[300:305, 2].any()
    assert not flags[300:305, [0, 1, 3]].any()


def test_padding_rows_do_not_leak():
    """T not a multiple of block_t: padded rows must not alter outputs."""
    x = _x(70, 2, seed=15)
    fin_a, out_a = teda_scan_tpu(jnp.asarray(x), block_t=64)
    fin_b, out_b = teda_scan_tpu(jnp.asarray(x), block_t=8)
    np.testing.assert_allclose(np.asarray(out_a["ecc"]),
                               np.asarray(out_b["ecc"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fin_a.var), np.asarray(fin_b.var),
                               rtol=1e-5)


# ------------------------------------------------------------- properties
@given_or_cases(
    "t,c,seed,m,block_t",
    [(2, 1, 0, 1.0, 8), (77, 3, 123, 3.0, 32), (300, 9, 7, 5.0, 128),
     (129, 2, 999, 2.5, 8)],
    lambda st: dict(t=st.integers(2, 300), c=st.integers(1, 9),
                    seed=st.integers(0, 2 ** 16), m=st.floats(1.0, 5.0),
                    block_t=st.sampled_from([8, 32, 128])),
    max_examples=20)
def test_property_kernel_matches_ref(t, c, seed, m, block_t):
    _check(_x(t, c, seed=seed), m=m, block_t=block_t)


@given_or_cases(
    "seed", [0, 123, 2 ** 16],
    lambda st: dict(seed=st.integers(0, 2 ** 16)),
    max_examples=10)
def test_property_outliers_subset_of_high_zeta(seed):
    """Verdict consistency: outlier ⇒ zeta > threshold (eq 6)."""
    x = _x(200, 3, seed=seed)
    x[150] += 30
    _, out = teda_scan_tpu(jnp.asarray(x), 3.0)
    fl = np.asarray(out["outlier"])
    margin = np.asarray(out["zeta"]) - np.asarray(out["threshold"])
    assert np.all(margin[fl] > 0)


def test_verdict_only_kernel_matches_full():
    from repro.kernels.ops import teda_scan_verdict
    x = _x(512, 5, seed=21)
    x[400:404, 2] += 20.0
    fin_full, full = teda_scan_tpu(jnp.asarray(x), 3.0, block_t=64)
    fin_v, slim = teda_scan_verdict(jnp.asarray(x), 3.0, block_t=64)
    np.testing.assert_allclose(np.asarray(slim["ecc"]),
                               np.asarray(full["ecc"]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(slim["outlier"]),
                                  np.asarray(full["outlier"]))
    assert fin_v is not None  # 512 % 64 == 0 -> state available
    np.testing.assert_allclose(np.asarray(fin_v.var),
                               np.asarray(fin_full.var), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fin_v.mean),
                               np.asarray(fin_full.mean), rtol=1e-5)


def test_verdict_only_matches_numpy_oracle():
    """Slim path vs teda_ref: ecc/verdicts/final state, int8 flag dtype.

    Covers the verdict_only=True kernel branch against the independent
    float64 oracle, not just the full-output kernel path.
    """
    from repro.kernels.ops import teda_scan_verdict
    from repro.kernels.teda_scan import teda_pallas_call

    x = _x(256, 3, seed=23)
    x[200:203, 1] += 18.0
    ref = teda_ref(np.asarray(x, np.float32), 3.0)
    fin, slim = teda_scan_verdict(jnp.asarray(x), 3.0, block_t=64)
    np.testing.assert_allclose(np.asarray(slim["ecc"]), ref["ecc"],
                               rtol=5e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(slim["outlier"]),
                                  ref["outlier"])
    # final carried state must equal the oracle's final-row statistics
    assert fin is not None  # 256 % 64 == 0
    np.testing.assert_allclose(np.asarray(fin.mean[:, 0]),
                               ref["mean"][-1], rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fin.var), ref["var"][-1],
                               rtol=5e-4, atol=1e-5)
    # the raw kernel emits an int8 flag (the 5B/sample HBM-write claim)
    xp = jnp.asarray(np.pad(x, ((0, 0), (0, 125))))
    scal = jnp.asarray([3.0], jnp.float32)
    vlen = jnp.full((1, 128), float(x.shape[0]), jnp.float32)
    zero = jnp.zeros((1, 128), jnp.float32)
    _, flag8, _, _, _ = teda_pallas_call(xp, scal, vlen, zero, zero, zero,
                                         block_t=64, interpret=True,
                                         verdict_only=True)
    assert flag8.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(flag8[:, :3]).astype(bool),
                                  ref["outlier"])


def test_verdict_only_final_state_when_padded():
    """T % block_t != 0: the kernel masks the padded tail in-kernel, so
    the slim path hands back an exact final state for every T."""
    from repro.kernels.ops import teda_scan_verdict
    x = _x(70, 2, seed=24)
    fin, slim = teda_scan_verdict(jnp.asarray(x), 3.0, block_t=64)
    ref = teda_ref(np.asarray(x, np.float32), 3.0)
    np.testing.assert_array_equal(np.asarray(slim["outlier"]),
                                  ref["outlier"])
    assert fin is not None
    np.testing.assert_allclose(np.asarray(fin.k), 70.0)
    np.testing.assert_allclose(np.asarray(fin.mean[:, 0]), ref["mean"][-1],
                               rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fin.var), ref["var"][-1],
                               rtol=5e-4, atol=1e-5)


def test_verdict_only_state_carry():
    from repro.kernels.ops import teda_scan_verdict
    x = _x(256, 3, seed=22)
    st1, _ = teda_scan_verdict(jnp.asarray(x[:128]), block_t=64)
    _, out2 = teda_scan_verdict(jnp.asarray(x[128:]), state=st1,
                                block_t=64)
    _, full = teda_scan_tpu(jnp.asarray(x), block_t=64)
    np.testing.assert_allclose(np.asarray(out2["ecc"]),
                               np.asarray(full["ecc"])[128:], rtol=1e-4)


# -------------------------------------------- ragged per-channel vlen
@given_or_cases(
    "t,c,seed,block_t",
    [(24, 3, 0, 8), (70, 4, 1, 32), (129, 2, 2, 64), (40, 5, 3, 8)],
    lambda st: dict(t=st.integers(2, 200), c=st.integers(1, 6),
                    seed=st.integers(0, 2 ** 16),
                    block_t=st.sampled_from([8, 32, 64])),
    max_examples=15)
def test_vlen_vector_matches_per_channel_ref(t, c, seed, block_t):
    """One ragged call == per-channel isolated prefixes vs teda_ref,
    covering vlen = 0, vlen = T and arbitrary remainders."""
    rng = np.random.default_rng(seed)
    x = _x(t, c, seed=seed)
    lens = rng.integers(0, t + 1, size=c).astype(np.int32)
    lens[rng.integers(0, c)] = 0
    lens[rng.integers(0, c)] = t
    fin, out = teda_scan_tpu(jnp.asarray(x), 3.0, valid_lens=lens,
                             block_t=block_t)
    flags = np.asarray(out["outlier"])
    assert not flags[np.arange(t)[:, None] >= lens[None, :]].any()
    np.testing.assert_array_equal(np.asarray(fin.k), lens)
    for ch in range(c):
        n = int(lens[ch])
        if n == 0:
            assert np.asarray(fin.var)[ch] == 0.0
            continue
        ref = teda_ref(np.asarray(x[:n, ch:ch + 1], np.float32), 3.0)
        np.testing.assert_allclose(np.asarray(out["ecc"])[:n, ch],
                                   ref["ecc"][:, 0], rtol=5e-4,
                                   atol=1e-5, err_msg=f"ch{ch}")
        np.testing.assert_array_equal(flags[:n, ch], ref["outlier"][:, 0],
                                      err_msg=f"ch{ch}")
        np.testing.assert_allclose(np.asarray(fin.mean)[ch, 0],
                                   ref["mean"][-1, 0], rtol=5e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(fin.var)[ch],
                                   ref["var"][-1, 0], rtol=5e-4,
                                   atol=1e-5)


def test_vlen_degenerate_vectors_match_scalar_path():
    """All-T vlen is bit-identical to the default call (same program,
    broadcast input); all-zeros returns the initial state untouched."""
    from repro.kernels.ops import teda_scan_verdict
    x = _x(100, 3, seed=25)
    for fn in (teda_scan_tpu, teda_scan_verdict):
        fin_a, out_a = fn(jnp.asarray(x), 3.0, block_t=32)
        fin_b, out_b = fn(jnp.asarray(x), 3.0, block_t=32,
                          valid_lens=np.full((3,), 100, np.int32))
        for key in out_a:
            np.testing.assert_array_equal(np.asarray(out_a[key]),
                                          np.asarray(out_b[key]), err_msg=key)
        np.testing.assert_array_equal(np.asarray(fin_a.mean),
                                      np.asarray(fin_b.mean))
        np.testing.assert_array_equal(np.asarray(fin_a.var),
                                      np.asarray(fin_b.var))
    fin_z, out_z = teda_scan_tpu(jnp.asarray(x), 3.0, block_t=32,
                                 valid_lens=np.zeros((3,), np.int32))
    assert np.asarray(fin_z.k).tolist() == [0.0] * 3
    assert np.asarray(fin_z.mean).tolist() == [[0.0]] * 3
    assert not np.asarray(out_z["outlier"]).any()


def test_vlen_state_carry_across_ragged_calls():
    """Two ragged calls chain exactly: each channel resumes from its
    own frozen prefix state."""
    x = _x(120, 2, seed=26)
    lens1 = np.array([50, 17], np.int32)
    st1, _ = teda_scan_tpu(jnp.asarray(x[:64]), 3.0, valid_lens=lens1,
                           block_t=32)
    take2 = np.array([30, 41], np.int32)
    x2 = np.zeros((64, 2), np.float32)
    for ch, (a, b) in enumerate(zip(lens1, lens1 + take2)):
        x2[: take2[ch], ch] = x[a:b, ch]
    st2, out2 = teda_scan_tpu(jnp.asarray(x2), 3.0, state=st1,
                              valid_lens=take2, block_t=32)
    np.testing.assert_array_equal(np.asarray(st2.k), lens1 + take2)
    for ch in range(2):
        n = int(lens1[ch] + take2[ch])
        ref = teda_ref(np.asarray(x[:n, ch:ch + 1], np.float32), 3.0)
        np.testing.assert_allclose(
            np.asarray(out2["ecc"])[: take2[ch], ch],
            ref["ecc"][lens1[ch]:, 0], rtol=5e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st2.var)[ch],
                                   ref["var"][-1, 0], rtol=5e-4, atol=1e-5)


def test_vlen_out_of_range_is_clamped():
    """Traced callers skip the engine's host bounds check, so the
    contract layer must clamp valid_lens to [0, T] — otherwise final k
    disagrees with the state the frozen carries actually hold."""
    from repro.core.scan import teda_scan
    x = _x(30, 2, seed=28)
    bad = np.array([100, -7], np.int32)     # > T and negative
    fin, out = teda_scan_tpu(jnp.asarray(x), 3.0, valid_lens=bad,
                             block_t=8)
    ref_fin, ref_out = teda_scan_tpu(jnp.asarray(x), 3.0,
                                     valid_lens=np.array([30, 0]),
                                     block_t=8)
    np.testing.assert_array_equal(np.asarray(fin.k),
                                  np.asarray(ref_fin.k))
    np.testing.assert_array_equal(np.asarray(fin.var),
                                  np.asarray(ref_fin.var))
    np.testing.assert_array_equal(np.asarray(out["outlier"]),
                                  np.asarray(ref_out["outlier"]))
    # the scan backend agrees (same clamp contract)
    sfin, _ = teda_scan(jnp.asarray(x[..., None]), 3.0, valid_lens=bad)
    np.testing.assert_array_equal(np.asarray(sfin.k), [30.0, 0.0])


def test_vlen_composes_with_per_slot_m():
    """Ragged lengths and per-slot sensitivities in one call: verdicts
    equal each channel's isolated run at its own m."""
    t, c = 60, 3
    x = _x(t, c, seed=27)
    x[10:14] += 12.0
    lens = np.array([60, 23, 0], np.int32)
    ms = np.array([1.5, 3.0, 6.0], np.float32)
    _, out = teda_scan_tpu(jnp.asarray(x), ms, valid_lens=lens, block_t=8)
    flags = np.asarray(out["outlier"])
    assert not flags[np.arange(t)[:, None] >= lens[None, :]].any()
    for ch in range(c):
        n = int(lens[ch])
        if not n:
            continue
        ref = teda_ref(np.asarray(x[:n, ch:ch + 1], np.float32),
                       float(ms[ch]))
        np.testing.assert_array_equal(flags[:n, ch], ref["outlier"][:, 0],
                                      err_msg=f"ch{ch}")
