"""Serving-gateway tests (`launch/serve.py`): priority classes through
`serve_streams`, the async loop at gateway level, the LM monitor demo,
and the CLI — the pieces the CI coverage gate holds at >= 80% for
`repro.launch.serve`.
"""
import numpy as np
import pytest

from repro.launch.serve import _demo_streams, main, serve, serve_streams


def _streams(n, history, live, seed=0, priority=None):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        h = rng.normal(size=(history,)).astype(np.float32)
        lv = rng.normal(size=(live,)).astype(np.float32)
        s = (f"t{i}", h, lv, None)
        if priority is not None:
            s = s + (priority(i),)
        out.append(s)
    return out


def test_serve_streams_priority_classes_and_telemetry():
    res = serve_streams(
        _streams(6, 12, 4, priority=lambda i: "latency" if i % 2
                 else "bulk"),
        backend="scan", buckets=(2, 4), chunk_t=8,
        class_weights={"latency": 4.0, "bulk": 1.0},
        arrivals_per_tick=3)
    assert res["requests"] == 6 and res["samples"] == 6 * 16
    assert set(res["classes"]) == {"latency", "bulk"}
    for cls in ("latency", "bulk"):
        assert res["classes"][cls]["completed"] == 3
        assert "queue_wait_ticks_p95" in res["classes"][cls]
    prios = {rid: pr["priority"] for rid, pr in res["per_request"].items()}
    assert prios["t1"] == "latency" and prios["t0"] == "bulk"
    # decode trickle ticks rode the short cached program
    assert res["short_ticks"] > 0
    assert all(len(key) == 2 for key in res["programs"])


def test_serve_streams_async_matches_sync_flags():
    streams = _streams(4, 10, 6, seed=3)
    streams = [(rid, h, lv * 4.0, 2.0) for rid, h, lv, _ in streams]
    kw = dict(backend="scan", buckets=(2, 4), chunk_t=8, collect=False)
    sync = serve_streams(streams, measure_latency=True, **kw)
    asyn = serve_streams(streams, measure_latency=False, **kw)
    assert sync["flagged"] == asyn["flagged"]
    for rid in sync["per_request"]:
        ps, pa = sync["per_request"][rid], asyn["per_request"][rid]
        assert (ps["samples"], ps["flags"]) == (pa["samples"],
                                                pa["flags"])


def test_serve_streams_rejects_duplicate_rids():
    s = _streams(1, 4, 0)
    with pytest.raises(ValueError, match="duplicate"):
        serve_streams(s + s, backend="scan", buckets=(2,))


def test_lm_serve_demo_tiny():
    """The LM monitor demo end-to-end on a reduced config: prompt
    telemetry replays as chunked prefill, decode telemetry rides the
    adaptive 1-sample lane, flags surface per request."""
    from repro.configs.registry import get_config
    cfg = get_config("llama3.2-1b").reduced()
    res = serve(cfg, batch=2, prompt_len=4, gen=3, backend="scan",
                chunk_t=4)
    assert res["tokens"].shape == (2, 3)
    assert res["monitor"]["ticks"] >= 3
    assert res["monitor"]["completed"] == 2 * 2  # batch x channels
    assert isinstance(res["flagged_requests"], list)
    assert res["prefill_tok_s"] > 0 and res["decode_tok_s"] > 0


def test_cli_streams_mode(capsys):
    main(["--mode", "streams", "--requests", "4", "--history", "16",
          "--live", "4", "--backend", "scan"])
    out = capsys.readouterr().out
    assert "[serve]" in out and "decode-short ticks" in out
    assert "class latency" in out and "class bulk" in out


def test_demo_streams_shapes():
    streams = _demo_streams(5, 8, 4)
    assert len(streams) == 5
    rid, h, lv, m, cls = streams[0]
    assert h.shape == (8,) and lv.shape == (4,)
    assert cls == "latency"                # every 4th tenant
    assert streams[1][4] == "bulk"
