"""Distributed (shard_map) TEDA — runs in a subprocess with 8 host devices.

The main pytest process must keep seeing 1 device (smoke tests), so the
multi-device check sets XLA_FLAGS in a child interpreter.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.distributed import distributed_teda
    from repro.core.teda import teda_numpy_loop

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(42)
    x = rng.normal(size=(1024, 4)).astype(np.float32)
    x[700:720] += 6.0
    ref = teda_numpy_loop(x, 3.0)
    fin, out = distributed_teda(jnp.asarray(x), 3.0, mesh)
    assert np.abs(np.asarray(out.ecc) - ref["ecc"]).max() < 1e-4
    assert (np.asarray(out.outlier) != ref["outlier"]).sum() == 0
    assert abs(float(fin.k) - 1024.0) < 1e-6
    assert np.abs(np.asarray(fin.mean) - ref["mean"]).max() < 1e-5
    assert ref["outlier"][700:720].sum() > 0
    print("DIST_OK")
""")


@pytest.mark.slow
def test_distributed_teda_8dev():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr
    assert "DIST_OK" in res.stdout
