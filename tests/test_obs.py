"""Acceptance suite for `repro.obs` (ISSUE 6 — observability).

The registry's label semantics and histogram bucket arithmetic are
pinned directly; the weighted nearest-rank quantile is pinned against
the scheduler's *old* exact sort-based percentile computation on a
fixed workload whose observations land on bucket edges; the tracer's
ring buffer must survive wraparound in order and export schema-valid
Chrome trace JSON; and the event bus must stream verdicts in
retirement order, bit-exact (Q path) with what `results()` returns
after the fact.  The drain-flush regression test closes the loop: a
bare `drain()` (no intervening `results()`/`telemetry()` reads) must
leave nothing in flight and all telemetry complete.
"""
import json

import numpy as np
import pytest

from repro.fixedpoint import QFormat
from repro.launch.batching import BatchingScheduler, Request
from repro.launch.serve import serve_streams
from repro.obs import (EventBus, LATENCY_MS_BUCKETS, MetricsRegistry,
                       NULL_TRACER, TickTracer, get_registry)

FMT = QFormat(32, 20)


# ----------------------------------------------------------- registry
def test_counter_and_gauge_label_semantics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "req", ("sched",))
    c.labels(sched="a").inc()
    c.labels(sched="a").inc(2)
    c.labels(sched="b").inc(5)
    # same label value -> the same child; different value -> distinct
    assert c.labels(sched="a").value == 3
    assert c.labels(sched="b").value == 5
    with pytest.raises(ValueError):
        c.labels(wrong="a")         # label names must match the axes
    with pytest.raises(ValueError):
        c.labels(sched="a").inc(-1)  # counters only go up
    g = reg.gauge("depth")           # label-free: family-level methods
    g.set(4)
    g.dec()
    assert g.value == 3
    with pytest.raises(ValueError):
        c.inc()  # family has label axes: must go through .labels()


def test_registry_get_or_create_and_conflicts():
    reg = MetricsRegistry()
    a = reg.counter("ticks_total", "t", ("sched",))
    assert reg.counter("ticks_total", "t", ("sched",)) is a
    with pytest.raises(ValueError):
        reg.gauge("ticks_total")                  # kind conflict
    with pytest.raises(ValueError):
        reg.counter("ticks_total", "t", ("pool",))  # label conflict
    h = reg.histogram("wall_ms", buckets=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("wall_ms", buckets=(1.0, 5.0))  # bucket conflict
    assert reg.histogram("wall_ms", buckets=(1.0, 2.0)) is h
    assert "wall_ms" in reg and reg.get("nope") is None


def test_histogram_bucket_edges_are_inclusive():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 2.0, 2.00001, 4.0, 99.0):
        h.observe(v)
    # le edges are inclusive (Prometheus): 1.0 lands in the 1.0 bucket
    assert dict((ub, c) for ub, c in h._default_child().buckets()) == {
        1.0: 2, 2.0: 3, 4.0: 5, float("inf"): 6}
    assert h.count == 6
    assert h.sum == pytest.approx(0.5 + 1.0 + 2.0 + 2.00001 + 4.0 + 99.0)
    with pytest.raises(ValueError):
        h.observe(1.0, weight=0)


def test_quantile_matches_old_exact_computation():
    """Regression (ISSUE 6 satellite): `stats()` percentiles moved from
    an O(n log n) re-sort of the call log to the O(1) running
    histogram.  On a fixed workload whose wall times land on bucket
    edges (the regime the bucket ladder is designed for), the
    histogram's weighted nearest-rank quantile must be *identical* to
    the old computation."""
    # (wall_s, retired) pairs exactly as the scheduler logged them;
    # wall_s * 1e3 lands on LATENCY_MS_BUCKETS edges, weights sum to 16
    calls = [(0.0001, 1), (0.001, 3), (0.0025, 4),
             (0.01, 6), (0.1, 2)]
    # the old BatchingScheduler.stats() body, verbatim
    walls = [c[0] for c in calls]
    weights = [max(c[1], 1) for c in calls]
    order = np.argsort(walls)
    w = np.asarray(weights, np.float64)[order]
    cum = np.cumsum(w) / w.sum()
    sw = np.asarray(walls)[order]

    def wpct(q):
        i = min(int(np.searchsorted(cum, q)), len(sw) - 1)
        return float(sw[i] * 1e3)

    reg = MetricsRegistry()
    h = reg.histogram("wall_ms", buckets=LATENCY_MS_BUCKETS)
    for wall, retired in calls:
        h.observe(wall * 1e3, weight=max(retired, 1))
    for q in (0.05, 0.25, 0.5, 0.75, 0.95, 1.0):
        assert h.quantile(q) == wpct(q), q


def test_prometheus_text_golden():
    reg = MetricsRegistry()
    reg.counter("sched_ticks_total", "scheduler ticks",
                ("sched",)).labels(sched="s0").inc(7)
    reg.gauge("pool_occupancy").set(3)
    h = reg.histogram("wall_ms", "wall", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(10.0, weight=2)
    assert reg.to_text() == """\
# TYPE pool_occupancy gauge
pool_occupancy 3
# HELP sched_ticks_total scheduler ticks
# TYPE sched_ticks_total counter
sched_ticks_total{sched="s0"} 7
# HELP wall_ms wall
# TYPE wall_ms histogram
wall_ms_bucket{le="1"} 1
wall_ms_bucket{le="10"} 3
wall_ms_bucket{le="+Inf"} 3
wall_ms_sum 20.5
wall_ms_count 3
"""


def test_snapshot_shape_is_json_ready():
    reg = MetricsRegistry()
    reg.counter("c", "", ("k",)).labels(k="x").inc()
    h = reg.histogram("h", buckets=(1.0,))
    h.observe(0.5)
    snap = reg.snapshot()
    json.dumps(snap)  # plain JSON, +Inf included (as the string "+Inf")
    assert snap["c"]["samples"] == [{"labels": {"k": "x"}, "value": 1.0}]
    hs = snap["h"]["samples"][0]
    assert (hs["count"], hs["p50"]) == (1.0, 1.0)
    assert hs["buckets"] == [[1.0, 1.0], ["+Inf", 1.0]]


# ------------------------------------------------------------- tracer
def test_tracer_ring_wraparound_keeps_order():
    tr = TickTracer(capacity=8)
    for i in range(20):
        tr.instant(f"ev{i}", i=i)
    assert len(tr) == 8
    assert tr.total == 20
    assert tr.dropped == 12
    names = [e["name"] for e in tr.events()]
    assert names == [f"ev{i}" for i in range(12, 20)]  # oldest first
    ts = [e["ts"] for e in tr.events()]
    assert ts == sorted(ts)


def test_chrome_trace_schema():
    tr = TickTracer(capacity=64)
    with tr.span("dispatch", device=True, tick=1, t=8):
        pass
    tr.instant("pool.resize", frm=4, to=8)
    doc = tr.to_chrome_trace()
    json.loads(json.dumps(doc))  # valid JSON end to end
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["otherData"] == {"recorded": 2, "dropped": 0}
    evs = doc["traceEvents"]
    assert [e["ts"] for e in evs] == sorted(e["ts"] for e in evs)
    span = next(e for e in evs if e["name"] == "dispatch")
    assert span["ph"] == "X" and span["dur"] >= 0
    assert {"pid", "tid", "ts"} <= set(span)
    assert span["args"] == {"tick": 1, "t": 8}
    inst = next(e for e in evs if e["name"] == "pool.resize")
    assert inst["ph"] == "i" and "dur" not in inst


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("anything", tick=1):
        pass
    assert NULL_TRACER.instant("x") is None
    assert NULL_TRACER.events() == []
    assert NULL_TRACER.to_chrome_trace()["traceEvents"] == []


# ---------------------------------------------------------- event bus
def test_event_bus_pubsub_and_drop_oldest():
    bus = EventBus()
    assert not bus.active
    assert bus.publish("done", 0, "r0") is None  # silent path: no-op
    sub = bus.subscribe(maxlen=3)
    assert bus.active
    for i in range(5):
        bus.publish("admitted", i, f"r{i}", slot=i)
    evs = sub.poll()
    assert [e.rid for e in evs] == ["r2", "r3", "r4"]  # oldest dropped
    assert sub.dropped == 2
    assert [e.seq for e in evs] == sorted(e.seq for e in evs)
    assert evs[0].data == {"slot": 2}
    assert sub.poll() == []  # drained
    sub.close()
    bus.publish("done", 9, "rX")
    assert sub.poll() == [] and not bus.active


def test_event_bus_attach_callback_and_iter():
    bus = EventBus()
    seen = []
    cb = bus.attach(seen.append)
    with bus.subscribe() as sub:
        bus.publish("a", 1)
        bus.publish("b", 2)
        assert [e.kind for e in sub] == ["a", "b"]
    assert [e.kind for e in seen] == ["a", "b"]
    bus.detach(cb)
    bus.publish("c", 3)
    assert len(seen) == 2


# ----------------------------------------- scheduler/pool integration
def _run_workload(sched, specs, feed_steps=True):
    """Submit, trickle-feed, close and drain a {rid: (hist, live)} mix."""
    for rid, (h, live) in specs.items():
        assert sched.submit(Request(rid, h, m=2.5))
    fed = {rid: 0 for rid in specs}
    for _ in range(200):
        for rid, (h, live) in specs.items():
            take = min(1, len(live) - fed[rid])
            if take and rid in sched.stats_by_rid:
                sched.feed(rid, live[fed[rid]:fed[rid] + 1])
                fed[rid] += 1
            if fed[rid] == len(live) and rid not in sched._finished \
                    and rid in sched.runs and not sched.runs[rid].req.closed:
                sched.close(rid)
        sched.step()
        if sched.completed == len(specs):
            break
    else:
        raise AssertionError("workload did not drain")


def _specs(n=4, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n):
        h = rng.normal(size=(int(rng.integers(4, 20)),)).astype(np.float32)
        lv = rng.normal(size=(int(rng.integers(1, 6)),)).astype(np.float32)
        lv[len(lv) // 2] += 12.0  # guarantee some flags
        out[f"r{i}"] = (h, lv)
    return out


def test_event_stream_matches_results_bit_exact():
    """The event-bus ordering contract (Q path): concatenating a
    request's `chunk_retired` outlier payloads in seq order reproduces
    `results()` bit-for-bit, and the streamed flag counts sum to the
    request's telemetry."""
    specs = _specs(4, seed=3)
    sched = BatchingScheduler("pallas-q", fmt=FMT, buckets=(2, 4),
                              chunk_t=4, collect=True)
    sub = sched.subscribe()
    _run_workload(sched, specs)
    evs = sub.poll()
    assert [e.seq for e in evs] == sorted(e.seq for e in evs)
    kinds = {e.kind for e in evs}
    assert {"admitted", "chunk_retired", "done"} <= kinds
    for rid in specs:
        chunks = [e for e in evs
                  if e.kind == "chunk_retired" and e.rid == rid]
        streamed = np.concatenate([e.data["outlier"] for e in chunks])
        res = sched.results(rid)
        np.testing.assert_array_equal(streamed, res["outlier"],
                                      err_msg=rid)
        np.testing.assert_array_equal(
            np.concatenate([e.data["ecc"] for e in chunks]),
            res["ecc"], err_msg=rid)
        st = sched.telemetry(rid)
        assert sum(e.data["flags"] for e in chunks) == st.flags
        assert sum(e.data["n"] for e in chunks) == st.samples
        done = next(e for e in evs if e.kind == "done" and e.rid == rid)
        assert done.data["samples"] == st.samples
        assert done.data["flags"] == st.flags
    # chunk_retired events stream at retirement: each request's first
    # chunk event precedes its done event in publish order
    for rid in specs:
        seqs = [e.seq for e in evs if e.rid == rid]
        done_seq = next(e.seq for e in evs
                        if e.kind == "done" and e.rid == rid)
        assert done_seq == max(seqs)


def test_trace_spans_reconcile_with_metrics():
    """dispatch spans == retire spans == the calls counter, and the
    dispatched sample total equals the samples-retired counter — the
    trace and the registry tell one story."""
    specs = _specs(3, seed=5)
    tr = TickTracer(capacity=4096)
    sched = BatchingScheduler("scan", fmt=FMT, buckets=(2, 4),
                              chunk_t=4, tracer=tr, measure_latency=True)
    _run_workload(sched, specs)
    evs = tr.events()
    dispatch = [e for e in evs if e["name"] == "dispatch"]
    retire = [e for e in evs if e["name"] == "retire"]
    calls = int(sched._c_calls.value)
    assert len(dispatch) == len(retire) == calls > 0
    assert (sum(e["args"]["samples"] for e in dispatch)
            == int(sched._c_samples.value)
            == sum(len(h) + len(lv) for h, lv in specs.values()))
    admits = [e for e in evs if e["name"] == "admit"]
    assert len(admits) == len(specs)
    # registry totals match the stats() view
    s = sched.stats()
    assert s["ticks"] == sched.tick_no
    assert s["completed"] == len(specs)
    assert s["chunk_latency"]["calls"] == len(sched.call_log)


def test_drain_flushes_everything_without_reads():
    """Regression (ISSUE 6 satellite): a bare `drain()` — no
    `results()`/`telemetry()` reads forcing syncs first — must leave
    zero in-flight calls and complete telemetry: every sample
    accounted in the per-request stats, the call log, and the
    registry."""
    specs = _specs(4, seed=11)
    sched = BatchingScheduler("scan", fmt=FMT, buckets=(2, 4),
                              chunk_t=4, measure_latency=False)
    for rid, (h, lv) in specs.items():
        assert sched.submit(
            Request(rid, np.concatenate([h, lv]), m=2.5, closed=True))
    sched.drain()
    assert not sched._inflight
    assert sched.stats()["inflight_calls"] == 0
    assert int(sched._g_inflight.value) == 0
    total = sum(len(h) + len(lv) for h, lv in specs.values())
    assert int(sched._c_samples.value) == total
    assert sum(c["retired"] for c in sched.call_log) == total
    for rid, (h, lv) in specs.items():
        st = sched.stats_by_rid[rid]
        assert st.samples == len(h) + len(lv)
        assert st.done_tick is not None
        assert sum(n for _, n in st.chunk_latency_s) == st.samples
    # flags fetched by the final flush are accounted, not lost
    assert int(sched._c_flags.value) == sum(
        sched.stats_by_rid[rid].flags for rid in specs)


def test_scheduler_stats_reads_registry():
    """Counters behind tick_no/completed/rejected/short_ticks are
    registry instruments; two schedulers with private registries never
    mix values, and an injected shared registry keeps them apart by
    the instance label."""
    shared = MetricsRegistry()
    a = BatchingScheduler("scan", fmt=FMT, buckets=(2,), chunk_t=4,
                          registry=shared, name="A")
    b = BatchingScheduler("scan", fmt=FMT, buckets=(2,), chunk_t=4,
                          registry=shared, name="B")
    a.submit(Request("r0", np.zeros(6, np.float32), closed=True))
    a.drain()
    assert (a.completed, b.completed) == (1, 0)
    fam = shared.get("sched_completed_total")
    assert fam.labels(sched="A").value == 1
    assert fam.labels(sched="B").value == 0
    text = shared.to_text()
    assert 'sched_completed_total{sched="A"} 1' in text
    # pool + engine series share the registry, prefixed by owner name
    assert 'pool_occupancy{pool="A/pool"} 0' in text
    assert get_registry() is get_registry()  # process-global singleton


def test_serve_streams_on_event_and_metrics():
    rng = np.random.default_rng(2)
    streams = [(f"t{i}", rng.normal(size=10).astype(np.float32),
                rng.normal(size=3).astype(np.float32), 2.5)
               for i in range(3)]
    seen = []
    res = serve_streams(streams, backend="scan", buckets=(2, 4),
                        chunk_t=4, queue_limit=4,
                        on_event=seen.append)
    assert res["requests"] == 3
    done = [e for e in seen if e.kind == "done"]
    assert sorted(e.rid for e in done) == ["t0", "t1", "t2"]
    assert [e.seq for e in seen] == sorted(e.seq for e in seen)
    snap = res["metrics"]
    comp = snap["sched_completed_total"]["samples"][0]
    assert comp["value"] == 3.0
    assert "sched_call_wall_ms" in snap
    assert snap["sched_call_wall_ms"]["samples"][0]["count"] > 0
    json.dumps(snap)
