"""Fused detector-ensemble conformance + serving integration (ISSUE 8).

The fused K-detector Pallas kernel must agree with the composed
per-detector `lax.scan` oracles (`ensemble_ref`) on EVERY flag — dense,
ragged vlens (including forced 0 and T), across chunk boundaries, and
across `block_c` channel strips — and per-slot detector *selection*
must be indistinguishable from running the smaller ensemble: a masked
slot's bits/vote/state equal the single-detector run bit-for-bit
(selection gates flags and vote only; the shared prefix-sum fabric
always advances).  Above the kernel, the suite pins the serving stack:
`StreamEngine.attach(detectors=..., vote=...)`, pool resize carrying
the aux block and per-slot detector config across buckets, the
scheduler's per-detector flag accounting, and the gateway's 7-tuple
streams.  The `slow`-marked sweeps run the full-width K x C grid
(multiple block_c strips) on the main-branch ensemble-full CI job.
"""
import numpy as np
import pytest

from conftest import given_or_cases

from repro.detectors import DEFAULT_DETECTORS, vote_threshold
from repro.detectors.ensemble import ensemble_init, ensemble_ref, ensemble_scan
from repro.engine import SlotPool, StreamEngine, list_backends
from repro.engine.backends import get_backend
from repro.launch.batching import BatchingScheduler, Request
from repro.launch.serve import serve_streams

# every ensemble subset the conformance matrix cares about: each member
# alone (the CI detector x pallas legs key on these ids), a pair, and
# the full fused ensemble
DSETS = [("teda",), ("rde",), ("zscore",), ("teda", "rde"),
         ("teda", "rde", "zscore")]
_IDS = ["+".join(d) for d in DSETS]


def _spiky(rng, t, c, every=7):
    x = rng.normal(size=(t, c)).astype(np.float32)
    x[::every] += 20.0  # unambiguous outliers, far from any threshold
    return x


def _ragged_lens(rng, t, c):
    lens = rng.integers(0, t + 1, size=c).astype(np.int32)
    lens[0] = 0  # forced full suspend
    lens[-1] = t  # forced full chunk
    return lens


def _kernel(x, detectors, **kw):
    kw.setdefault("block_t", 8)
    kw.setdefault("interpret", True)
    return ensemble_scan(x, 3.0, detectors=detectors, **kw)


# --------------------------------------------- kernel vs scan oracles
@pytest.mark.parametrize("detectors", DSETS, ids=_IDS)
@given_or_cases(
    "t,c,seed,ragged", [(16, 4, 0, False), (24, 3, 1, True),
                        (9, 5, 2, True)],
    lambda st: dict(t=st.integers(2, 24), c=st.integers(1, 6),
                    seed=st.integers(0, 2 ** 16), ragged=st.booleans()),
    max_examples=3)
def test_kernel_matches_oracle(detectors, t, c, seed, ragged):
    rng = np.random.default_rng(seed)
    x = _spiky(rng, t, c)
    lens = _ragged_lens(rng, t, c) if ragged else None
    fin, out = _kernel(x, detectors, valid_lens=lens)
    ref = ensemble_ref(x, 3.0, detectors=detectors, valid_lens=lens)
    np.testing.assert_array_equal(np.asarray(out["det_flags"]),
                                  np.asarray(ref["det_flags"]))
    np.testing.assert_array_equal(np.asarray(out["vote"]),
                                  np.asarray(ref["vote"]))
    want_k = np.full((c,), t) if lens is None else lens
    np.testing.assert_array_equal(np.asarray(fin.k),
                                  want_k.astype(np.float32))


def test_chunked_carry_equals_full_run():
    """Carrying EnsembleState across chunk boundaries reproduces the
    single-shot flags exactly (separated data); the float aux rows
    match to reassociation rounding, like the TEDA float path."""
    rng = np.random.default_rng(5)
    t, c, cut = 24, 4, 11
    x = _spiky(rng, t, c)
    _, full = _kernel(x, DEFAULT_DETECTORS)
    st, out_a = _kernel(x[:cut], DEFAULT_DETECTORS)
    fin, out_b = _kernel(x[cut:], DEFAULT_DETECTORS, state=st)
    for key in ("det_flags", "vote"):
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(out_a[key]),
                            np.asarray(out_b[key])]),
            np.asarray(full[key]), err_msg=key)
    fin_full, _ = _kernel(x, DEFAULT_DETECTORS)  # jit-cached re-run
    np.testing.assert_array_equal(np.asarray(fin.k),
                                  np.asarray(fin_full.k))
    np.testing.assert_allclose(np.asarray(fin.aux),
                               np.asarray(fin_full.aux),
                               rtol=1e-5, atol=1e-6)


def test_block_c_strip_invariance():
    """Channel strips are independent grid blocks: splitting the padded
    width into two block_c strips is bit-identical to one strip."""
    rng = np.random.default_rng(6)
    t, c = 12, 130  # pads to 256 lanes: block_c=128 -> 2 strips
    x = _spiky(rng, t, c)
    lens = _ragged_lens(rng, t, c)
    fa, a = _kernel(x, DEFAULT_DETECTORS, valid_lens=lens, block_c=128)
    fb, b = _kernel(x, DEFAULT_DETECTORS, valid_lens=lens, block_c=256)
    np.testing.assert_array_equal(np.asarray(a["det_flags"]),
                                  np.asarray(b["det_flags"]))
    np.testing.assert_array_equal(np.asarray(a["vote"]),
                                  np.asarray(b["vote"]))
    np.testing.assert_array_equal(np.asarray(fa.k), np.asarray(fb.k))
    np.testing.assert_array_equal(np.asarray(fa.aux), np.asarray(fb.aux))


@pytest.mark.parametrize("d,det", list(enumerate(DEFAULT_DETECTORS)),
                         ids=list(DEFAULT_DETECTORS))
def test_selection_mask_equals_single_detector(d, det):
    """Zero-weighting all but one member of the K=3 ensemble is
    bit-identical to running the K=1 ensemble of that member: same
    flags (re-based to bit d), same vote, same advanced state."""
    rng = np.random.default_rng(7)
    t, c = 16, 4
    x = _spiky(rng, t, c)
    sel = np.zeros((3, c), np.float32)
    sel[d] = 1.0
    fm, masked = _kernel(x, DEFAULT_DETECTORS, sel=sel)
    fs, single = _kernel(x, (det,))
    np.testing.assert_array_equal(
        np.asarray(masked["det_flags"]),
        np.asarray(single["det_flags"]) << d,
        err_msg=f"{det} masked-slot flags (bit {d})")
    np.testing.assert_array_equal(np.asarray(masked["vote"]),
                                  np.asarray(single["vote"]))
    # the sample counter always advances; the aux rows are NOT compared
    # here — the K=1 kernel only advances the fabric rows its member
    # reads, while selection within one ensemble never touches state
    # (test_selection_mask_leaves_state_untouched pins that)
    np.testing.assert_array_equal(np.asarray(fm.k), np.asarray(fs.k))


def test_selection_mask_leaves_state_untouched():
    """Within one ensemble, runtime selection weights gate flags and
    vote only: any sel advances k and every aux row identically."""
    rng = np.random.default_rng(15)
    t, c = 16, 4
    x = _spiky(rng, t, c)
    sel = np.zeros((3, c), np.float32)
    sel[1] = 1.0  # rde-only selection, same K=3 ensemble
    fm, _ = _kernel(x, DEFAULT_DETECTORS, sel=sel)
    ff, _ = _kernel(x, DEFAULT_DETECTORS)
    np.testing.assert_array_equal(np.asarray(fm.k), np.asarray(ff.k))
    np.testing.assert_array_equal(np.asarray(fm.aux), np.asarray(ff.aux))


def test_vote_matches_host_recompute_weighted():
    """The kernel's fused verdict equals recomputing the weighted vote
    on host from its own detector bits — float32, detector order."""
    rng = np.random.default_rng(8)
    t, c = 20, 5
    x = _spiky(rng, t, c, every=5)
    w = np.asarray([1.0, 0.5, 2.0], np.float32)
    sel = np.broadcast_to(w[:, None], (3, c)).astype(np.float32)
    thr = np.full((c,), vote_threshold("majority", w), np.float32)
    _, out = _kernel(x, DEFAULT_DETECTORS, sel=sel, thr=thr)
    bits = np.asarray(out["det_flags"])
    votew = np.zeros((t, c), np.float32)
    for d in range(3):
        votew = votew + ((bits >> d) & 1).astype(np.float32) * sel[d]
    np.testing.assert_array_equal(np.asarray(out["vote"]),
                                  votew >= thr[None, :])


def test_teda_lane_bitidentical_to_pallas_backend():
    """The ensemble's TEDA member reuses the TEDA kernel's arithmetic:
    a teda-only ensemble engine flags bit-identically to the standalone
    "pallas" backend at equal block_t, chunk by chunk."""
    rng = np.random.default_rng(9)
    c = 4
    x = _spiky(rng, 32, c)
    ep = StreamEngine(c, "pallas", m=3.0, block_t=8, interpret=True)
    ee = StreamEngine(c, "ensemble", m=3.0, detectors=("teda",),
                      block_t=8, interpret=True)
    for lo in range(0, 32, 8):
        chunk = x[lo:lo + 8]
        op = ep.process(chunk)
        oe = ee.process(chunk)
        np.testing.assert_array_equal(
            np.asarray(oe["outlier"]), np.asarray(op["outlier"]),
            err_msg=f"chunk at {lo}")
        np.testing.assert_array_equal(
            np.asarray(oe["det_flags"]).astype(bool),
            np.asarray(op["outlier"]))


# --------------------------------------------------- kernel guards
def test_ensemble_scan_rejects_bad_args():
    x = np.zeros((4, 2), np.float32)
    with pytest.raises(ValueError, match="non-empty unique subset"):
        ensemble_scan(x, detectors=())
    with pytest.raises(ValueError, match="non-empty unique subset"):
        ensemble_scan(x, detectors=("teda", "teda"))
    with pytest.raises(ValueError, match="non-empty unique subset"):
        ensemble_scan(x, detectors=("teda", "lof"))
    with pytest.raises(ValueError, match="state.aux"):
        ensemble_scan(x, state=ensemble_init(2, window=4), window=8)


def test_backend_registry_and_validation():
    be = get_backend("ensemble")
    assert be.detectors == DEFAULT_DETECTORS
    assert be.aux_rows == 17  # 2 * DEFAULT_WINDOW + 1
    assert be.default_threshold == 1.5  # majority of 3 unit weights
    # a different detection algorithm, not a TEDA executor: resolvable,
    # but not in the TEDA conformance-parametrized listing
    assert "ensemble" not in list_backends()
    assert "ensemble" in list_backends(all=True)
    with pytest.raises(ValueError, match="unknown detectors"):
        get_backend("ensemble", weights={"lof": 2.0})
    with pytest.raises(ValueError, match="one entry per detector"):
        get_backend("ensemble", weights=[1.0, 2.0])
    with pytest.raises(ValueError, match="must be positive"):
        get_backend("ensemble", weights=[1.0, 0.0, 1.0])
    with pytest.raises(ValueError, match="vote"):
        get_backend("ensemble", vote="quorum")
    with pytest.raises(ValueError, match="aux"):
        z = np.zeros((2,), np.float32)
        be.process(np.zeros((4, 2), np.float32), z, z, z)


# ------------------------------------------------ engine integration
def test_engine_slot_selection_matches_isolated_rde():
    """set_detectors([s], detectors=("rde",)) makes slot s report RDE
    alone — bit 1 of the member order, vote == the RDE flag — exactly
    as if the channel ran an rde-only ensemble; untouched slots keep
    the full ensemble."""
    rng = np.random.default_rng(10)
    c, t = 4, 16
    x = _spiky(rng, t, c)
    eng = StreamEngine(c, "ensemble", m=3.0, block_t=8, interpret=True)
    eng.set_detectors([2], detectors=("rde",), vote="any")
    cfg = eng.detector_config(2)
    assert cfg["detectors"] == ("rde",)
    assert cfg["threshold"] == 1.0
    out = eng.process(x)
    bits = np.asarray(out["det_flags"])
    ref_full = ensemble_ref(x, 3.0)
    ref_rde = ensemble_ref(x[:, 2:3], 3.0, detectors=("rde",))
    np.testing.assert_array_equal(
        bits[:, 2], np.asarray(ref_rde["det_flags"])[:, 0] << 1)
    np.testing.assert_array_equal(np.asarray(out["outlier"])[:, 2],
                                  np.asarray(ref_rde["vote"])[:, 0])
    for s in (0, 1, 3):  # unselected slots: the full default ensemble
        np.testing.assert_array_equal(
            bits[:, s], np.asarray(ref_full["det_flags"])[:, s])


def test_engine_attach_detach_detector_lifecycle():
    eng = StreamEngine(2, "ensemble", m=3.0, block_t=8, interpret=True,
                      auto_attach=False)
    eng.attach(n=2, detectors=("zscore",), vote="all")
    assert eng.detector_config(0)["detectors"] == ("zscore",)
    assert eng.detector_config(1)["detectors"] == ("zscore",)
    eng.detach([0])  # recycled slots revert to the full ensemble
    assert eng.detector_config(0)["detectors"] == DEFAULT_DETECTORS
    assert eng.detector_config(1)["detectors"] == ("zscore",)
    with pytest.raises(ValueError, match="subset"):
        eng.set_detectors([1], detectors=("iforest",))
    with pytest.raises(ValueError, match="vote"):
        eng.set_detectors([1], vote="plurality")


def test_engine_guards_non_ensemble_and_mesh():
    scan_eng = StreamEngine(2, "scan")
    with pytest.raises(ValueError, match="detector"):
        scan_eng.set_detectors([0], detectors=("rde",))
    with pytest.raises(ValueError, match="detector"):
        scan_eng.detector_config(0)
    with pytest.raises(ValueError, match="mesh"):
        StreamEngine(2, "ensemble", mesh=object())


# -------------------------------------------------- pool integration
def test_pool_resize_carries_aux_and_detector_config():
    """Growing through the bucket ladder must migrate the aux block and
    the per-slot detector selection: an rde-only tenant acquired before
    the resize keeps flagging exactly like an isolated rde run of its
    whole stream, across the boundary."""
    rng = np.random.default_rng(11)
    pool = SlotPool("ensemble", buckets=(2, 4), m=3.0, block_t=8,
                    interpret=True)
    s0 = int(pool.acquire(1, detectors=("rde",), vote="any")[0])
    x1 = _spiky(rng, 16, pool.capacity)
    out1 = pool.process(x1)
    bits1 = np.asarray(out1["det_flags"])[:, s0]
    pool.acquire(2)  # 3 live slots: forces the 2 -> 4 bucket
    assert pool.capacity == 4 and pool.resizes == 1
    assert pool.engine.detector_config(s0)["detectors"] == ("rde",)
    x2 = _spiky(rng, 16, pool.capacity)
    x2[:, s0] = _spiky(rng, 16, 1)[:, 0]
    out2 = pool.process(x2)
    bits2 = np.asarray(out2["det_flags"])[:, s0]
    stream = np.concatenate([x1[:, s0:s0 + 1], x2[:, s0:s0 + 1]])
    ref = ensemble_ref(stream, 3.0, detectors=("rde",))
    np.testing.assert_array_equal(
        np.concatenate([bits1, bits2]),
        np.asarray(ref["det_flags"])[:, 0] << 1,
        err_msg="rde-only tenant across the pool resize")


# --------------------------------------------- scheduler + gateway
def _history(rng, n, spike_at=None):
    h = rng.normal(size=(n,)).astype(np.float32)
    if spike_at is not None:
        h[spike_at] += 25.0
    return h


def test_scheduler_per_detector_flag_accounting():
    rng = np.random.default_rng(12)
    sched = BatchingScheduler("ensemble", buckets=(2, 4), chunk_t=8,
                              block_t=8, interpret=True)
    sched.submit(Request("a", _history(rng, 20, spike_at=15),
                         detectors=("teda", "rde"), vote="any"))
    sched.submit(Request("b", _history(rng, 12)))
    sched.close("a")
    sched.close("b")
    sched.drain()
    st = sched.stats_by_rid["a"]
    assert st.det_flags, "the spike must flag at least one member"
    assert set(st.det_flags) <= {"teda", "rde"}, \
        "zscore is unselected on this slot: its flags must be masked"
    totals = sched.stats()["detector_flags"]
    agg = {}
    for r in sched.stats_by_rid.values():
        for det, n in r.det_flags.items():
            agg[det] = agg.get(det, 0) + n
    assert {d: n for d, n in totals.items() if n} == agg


def test_serve_streams_seven_tuple_and_per_request_flags():
    rng = np.random.default_rng(13)
    streams = [
        ("a", _history(rng, 16, spike_at=12), _history(rng, 4), None,
         "default", ("rde",), "any"),
        ("b", _history(rng, 10), _history(rng, 6, spike_at=3), None),
    ]
    res = serve_streams(streams, backend="ensemble", buckets=(2, 4),
                        chunk_t=8, block_t=8, interpret=True)
    assert res["requests"] == 2
    fa = res["per_request"]["a"]["det_flags"]
    assert fa.get("rde", 0) >= 1, "the history spike must flag RDE"
    assert set(fa) == {"rde"}, \
        "detectors=('rde',) masks every other member's flags"
    assert res["per_request"]["b"]["det_flags"].get("rde", 0) >= 1
    assert res["per_request"]["a"]["samples"] == 20


# ------------------------------------------- full-width slow sweeps
@pytest.mark.slow
@pytest.mark.parametrize("detectors", DSETS, ids=_IDS)
def test_full_width_ragged_sweep(detectors):
    """Serving-width conformance: 260 channels (three 128-lane strips
    at block_c=128), ragged vlens, every ensemble subset — kernel
    flags and vote exact vs the composed oracles."""
    rng = np.random.default_rng(14)
    t, c = 48, 260
    x = _spiky(rng, t, c, every=5)
    lens = _ragged_lens(rng, t, c)
    fin, out = ensemble_scan(x, 3.0, detectors=detectors,
                             valid_lens=lens, block_t=16, block_c=128,
                             interpret=True)
    ref = ensemble_ref(x, 3.0, detectors=detectors, valid_lens=lens)
    np.testing.assert_array_equal(np.asarray(out["det_flags"]),
                                  np.asarray(ref["det_flags"]))
    np.testing.assert_array_equal(np.asarray(out["vote"]),
                                  np.asarray(ref["vote"]))
    np.testing.assert_array_equal(np.asarray(fin.k),
                                  lens.astype(np.float32))
