"""Detector-oracle conformance suite (ISSUE 8).

The per-detector pure-JAX `lax.scan` oracles (`repro.detectors`) are
the reference semantics the fused ensemble kernel is held to
(tests/test_ensemble.py); this module pins the oracles themselves:

  * chunk-exactness — feeding a stream in arbitrary chunk sizes with
    carried state reproduces the single-shot run bit-for-bit (the
    oracles are step-recursive, so chunk boundaries cannot round);
  * ragged valid_lens — each channel freezes after its own prefix,
    bit-exact with running the prefix alone;
  * detector semantics — RDE's biased-variance Cauchy density, the
    z-score window forgetting old regimes, and the TEDA adapter
    matching `core.scan.teda_scan` exactly;
  * the vote-threshold / aux-layout helpers the serving stack uses.
"""
import numpy as np
import pytest

from conftest import given_or_cases

from repro.detectors import (DEFAULT_DETECTORS, DETECTORS, aux_rows,
                             vote_threshold)
from repro.detectors.rde import rde_scan
from repro.detectors.teda import teda_detector_scan
from repro.detectors.zscore import zscore_init, zscore_scan


def _scan(name, x, m=3.0, state=None, valid_lens=None, window=4):
    if name == "zscore":
        if state is None:
            state = zscore_init(x.shape[1], window)
        return zscore_scan(x, m, state, valid_lens=valid_lens)
    return DETECTORS[name](x, m, state, valid_lens=valid_lens)


def _spiky(rng, t, c, every=7):
    x = rng.normal(size=(t, c)).astype(np.float32)
    x[::every] += 20.0  # unambiguous outliers, far from any threshold
    return x


# ------------------------------------------------- chunked == full
@pytest.mark.parametrize("detector", DEFAULT_DETECTORS)
@given_or_cases(
    "t,c,cut,seed", [(12, 3, 5, 0), (16, 2, 7, 1), (9, 4, 1, 2),
                     (20, 1, 13, 3)],
    lambda st: dict(t=st.integers(2, 24), c=st.integers(1, 5),
                    cut=st.integers(1, 23), seed=st.integers(0, 2 ** 16)),
    max_examples=12)
def test_chunked_equals_full(detector, t, c, cut, seed):
    cut = min(cut, t - 1)
    rng = np.random.default_rng(seed)
    x = _spiky(rng, t, c)
    _, full = _scan(detector, x)
    st, out_a = _scan(detector, x[:cut])
    _, out_b = _scan(detector, x[cut:], state=st)
    for key in ("outlier", "score"):
        got = np.concatenate([np.asarray(out_a[key]),
                              np.asarray(out_b[key])])
        want = np.asarray(full[key])
        if detector == "teda" and key == "score":
            # the TEDA oracle is an associative scan: a chunk boundary
            # reassociates the float32 reduction, so its eccentricity
            # matches to rounding (the repo-wide documented tolerance);
            # the step-recursive rde/zscore oracles are bit-exact
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        else:
            np.testing.assert_array_equal(
                got, want,
                err_msg=f"{detector}/{key} chunk boundary at {cut}")


# ------------------------------------------- ragged == isolated
@pytest.mark.parametrize("detector", DEFAULT_DETECTORS)
@given_or_cases(
    "t,c,seed", [(10, 3, 0), (8, 4, 1), (16, 2, 2)],
    lambda st: dict(t=st.integers(2, 16), c=st.integers(2, 5),
                    seed=st.integers(0, 2 ** 16)),
    max_examples=8)
def test_ragged_equals_isolated(detector, t, c, seed):
    rng = np.random.default_rng(seed)
    x = _spiky(rng, t, c)
    lens = rng.integers(0, t + 1, size=c).astype(np.int32)
    lens[0] = 0  # forced full suspend
    lens[-1] = t  # forced full chunk
    fin, out = _scan(detector, x, valid_lens=lens)
    ol = np.asarray(out["outlier"])
    assert not ol[np.arange(t)[:, None] >= lens[None, :]].any(), \
        "flag beyond the valid prefix"
    for s in range(c):
        n = int(lens[s])
        if n == 0:
            assert int(np.asarray(fin.k)[s]) == 0
            continue
        fin_i, ref = _scan(detector, x[:n, s:s + 1])
        np.testing.assert_array_equal(
            ol[:n, s], np.asarray(ref["outlier"])[:, 0],
            err_msg=f"{detector} slot {s} vlen {n}")
        np.testing.assert_array_equal(
            np.asarray(fin.k)[s], np.asarray(fin_i.k)[0])


# ------------------------------------------------- teda adapter
def test_teda_adapter_matches_core_scan():
    from repro.core.scan import teda_scan

    rng = np.random.default_rng(0)
    x = _spiky(rng, 24, 3)
    fin, out = teda_detector_scan(x, 2.5)
    ref_fin, ref = teda_scan(x[..., None], 2.5)
    np.testing.assert_array_equal(np.asarray(out["outlier"]),
                                  np.asarray(ref.outlier))
    np.testing.assert_array_equal(np.asarray(out["score"]),
                                  np.asarray(ref.ecc))
    np.testing.assert_array_equal(np.asarray(fin.k),
                                  np.asarray(ref_fin.k))


# ------------------------------------------------- rde semantics
def test_rde_flags_spike_and_scores_density():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(40, 1)).astype(np.float32)
    x[30, 0] += 25.0
    _, out = rde_scan(x, 3.0)
    ol = np.asarray(out["outlier"])[:, 0]
    assert ol[30], "RDE must flag the injected spike"
    assert not ol[:2].any(), "k < 2 must never flag (cold start)"
    score = np.asarray(out["score"])[:, 0]
    assert (score >= 0).all() and (score <= 1.0).all(), \
        "Cauchy density lies in [0, 1]"
    # the spike's density is far below a typical inlier's
    assert score[30] < 0.1 < score[29]


def test_rde_constant_stream_never_flags():
    x = np.full((16, 2), 3.25, np.float32)
    _, out = rde_scan(x, 3.0)
    assert not np.asarray(out["outlier"]).any()


# ------------------------------------------------- zscore semantics
def test_zscore_window_forgets_old_regime():
    """After a level shift ages out of the window, the windowed
    detector treats the new level as normal while continuing to flag
    genuine spikes against the *recent* statistics.

    The window must satisfy W - 1 > m^2: the current sample sits inside
    its own window, so the attainable z^2 is capped at W - 1 — with
    W = 16 and m = 3 a lone spike scores z^2 = 15 > 9 and flags."""
    rng = np.random.default_rng(2)
    w = 16
    a = rng.normal(0.0, 0.1, size=(24, 1)).astype(np.float32)
    b = rng.normal(50.0, 0.1, size=(28, 1)).astype(np.float32)
    b[24, 0] += 30.0  # spike vs the *new* regime
    x = np.concatenate([a, b])
    st = zscore_init(1, w)
    _, out = zscore_scan(x, 3.0, st)
    ol = np.asarray(out["outlier"])[:, 0]
    # once the window is fully inside regime b, plain b samples pass
    assert not ol[24 + w: 24 + 24].any(), \
        "windowed stats must adapt to the new level"
    assert ol[24 + 24], "spike vs recent window must still flag"


def test_zscore_state_ring_width_wins_over_window_kwarg():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(12, 2)).astype(np.float32)
    st = zscore_init(2, 4)
    fin, _ = zscore_scan(x, 3.0, st, window=16)  # kwarg ignored
    assert fin.ring.shape == (4, 2)


# ------------------------------------------------- helpers / config
def test_aux_rows_layout():
    assert aux_rows(8) == 17
    assert aux_rows(1) == 3
    with pytest.raises(ValueError):
        aux_rows(0)


def test_vote_threshold_modes():
    w = np.ones(3, np.float32)
    assert vote_threshold("any", w) == 1.0
    assert vote_threshold("majority", w) == 1.5
    assert vote_threshold("all", w) == 3.0
    assert vote_threshold(0.5, w) == 1.5
    # zero-weight (unselected) members drop out of every mode
    assert vote_threshold("all", np.array([1.0, 0.0, 1.0])) == 2.0
    assert vote_threshold("any", np.array([0.5, 0.0, 2.0])) == 0.5


@pytest.mark.parametrize("bad", ["quorum", 0.0, 1.5, -0.25, None, True])
def test_vote_threshold_rejects(bad):
    with pytest.raises(ValueError):
        vote_threshold(bad, np.ones(2, np.float32))
