"""TEDAGuard: training-loop anomaly guard + straggler detector."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (GuardConfig, StragglerDetector, apply_guard,
                        guard_init, guard_step)


def _run_guard(metric_stream, cfg):
    gs = guard_init(cfg)
    skips = []
    for row in metric_stream:
        gs, verdict = guard_step(gs, jnp.asarray(row, jnp.float32), cfg)
        skips.append(bool(verdict.skip))
    return gs, np.asarray(skips)


def test_guard_skips_loss_spike():
    rng = np.random.default_rng(0)
    loss = 2.0 + 0.05 * rng.normal(size=100)
    gnorm = 1.0 + 0.02 * rng.normal(size=100)
    loss[70] = 40.0  # corrupt batch
    gs, skips = _run_guard(np.stack([loss, gnorm], -1),
                           GuardConfig(m=3.0, warmup_steps=20))
    assert skips[70]
    assert skips[:20].sum() == 0  # warmup never skips
    assert int(gs.skipped) == skips.sum()


def test_guard_nan_always_skips():
    rng = np.random.default_rng(1)
    loss = 2.0 + 0.05 * rng.normal(size=50)
    loss[40] = np.nan
    gnorm = np.ones(50)
    _, skips = _run_guard(np.stack([loss, gnorm], -1),
                          GuardConfig(m=3.0, warmup_steps=10))
    assert skips[40]


def test_exclude_outliers_keeps_spike_train_detectable():
    """A run of spikes: exclusion prevents stat contamination."""
    rng = np.random.default_rng(2)
    loss = 2.0 + 0.05 * rng.normal(size=120)
    loss[80:100] = 30.0
    gnorm = np.ones(120)
    stream = np.stack([loss, gnorm], -1)
    _, sk_ex = _run_guard(stream, GuardConfig(m=3.0, warmup_steps=20,
                                              exclude_outliers=True))
    assert sk_ex[80:100].sum() >= 18  # nearly every spike caught


def test_apply_guard_masks_pytree():
    old = {"w": jnp.zeros(3), "b": jnp.zeros(())}
    new = {"w": jnp.ones(3), "b": jnp.ones(())}
    kept = apply_guard(jnp.asarray(True), new, old)
    np.testing.assert_allclose(kept["w"], 0.0)
    taken = apply_guard(jnp.asarray(False), new, old)
    np.testing.assert_allclose(taken["w"], 1.0)


def test_guard_step_is_jittable():
    cfg = GuardConfig()
    gs = guard_init(cfg)
    f = jax.jit(lambda s, m: guard_step(s, m, cfg))
    gs2, v = f(gs, jnp.asarray([1.0, 2.0]))
    assert gs2.teda.k.shape == (2,)
    assert v.skip.dtype == bool


def test_straggler_detector():
    det = StragglerDetector(m=3.0, warmup=10)
    rng = np.random.default_rng(3)
    trips = [det.check(1.0 + 0.01 * rng.normal()) for _ in range(50)]
    assert not any(trips)
    assert det.check(5.0)  # straggling step
