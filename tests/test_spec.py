"""The declarative detector-state fabric: `StateSpec` layouts, the
non-moment ensemble members ("hst", "teda-q") against their pure-JAX
oracles, per-detector score streams, the Q-format vote lane, and the
bit-exact opaque-region migration contract across bucket resizes and
shard moves.

Exactness tiers (the kernel conformance methodology):

  * hst / teda-q flags, scores and aux regions: EXACT equality — their
    lanes are small-integer f32 counts and int32 Q arithmetic, so the
    kernel must reproduce the oracle bit-for-bit.
  * moment-member (teda/rde/zscore) flags: EXACT on well-separated
    data (the PR 8 contract).
  * moment-member *scores*: allclose at ~5e-3 — `s2/k - mean^2` is
    catastrophically cancelling at small k, and XLA makes different
    fma-fusion choices in the kernel vs the oracle graph, so one-ULP
    input differences legitimately move the density by ~0.3%.

Opaque aux comparisons use int32 views: the teda-q regions are int32
payloads bitcast into the f32 block, and some payloads alias f32 NaN
patterns (NaN != NaN would fail a float compare on bit-identical
state).
"""
import numpy as np
import pytest

from conftest import given_or_cases
from repro.detectors import (DEFAULT_DETECTORS, MOMENT_MEMBERS, aux_rows,
                             ensemble_spec)
from repro.detectors.ensemble import (ensemble_init, ensemble_ref,
                                      ensemble_scan)
from repro.detectors.hst import hst_init, hst_leaf, hst_scan
from repro.detectors.spec import (HST_LEAVES, HST_RANGE, Region, StateSpec,
                                  f32_to_i32_bits, i32_to_f32_bits,
                                  member_regions)
from repro.detectors.teda_q import member_msq1, teda_q_member_scan
from repro.engine import ShardedPool, SlotPool
from repro.fixedpoint import QFormat
from repro.fixedpoint.teda_q import teda_q_scan_chan
from repro.launch.serve import serve_streams

FMT = QFormat(16, 8)
ALL = ("teda", "rde", "zscore", "hst", "teda-q")
KW = dict(block_t=8, interpret=True)


def _bits(aux):
    """Raw element bits of an aux block (NaN-safe exact comparison)."""
    return np.asarray(aux).view(np.int32)


def _stream(t, c, seed=0, burst=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, c)).astype(np.float32)
    if burst is not None:
        x[burst] += 9.0
    return x


# ---------------------------------------------------------- StateSpec
def test_spec_moment_only_keeps_historical_layout():
    spec = ensemble_spec(DEFAULT_DETECTORS, 8)
    assert spec.rows == 17 == aux_rows(8) == aux_rows(8, DEFAULT_DETECTORS)
    assert spec.names() == ("moment:s", "moment:s2", "moment:var")
    assert spec.offset("moment:s2") == 8
    assert spec.slc("moment:var") == slice(16, 17)
    assert all(r.tag == "f32" for r in spec.regions)


def test_spec_appends_opaque_regions_in_detector_order():
    spec = ensemble_spec(ALL, 8)
    # 17 moment + (8+8+1) hst + 2 teda-q
    assert spec.rows == 36 == aux_rows(8, ALL)
    assert spec.offset("hst:ref") == 17
    assert spec.offset("hst:phase") == 33
    assert spec.region("teda-q:mean").tag == "i32"
    assert spec.has("hst:cur") and not spec.has("nope")
    # swapping detector order moves the opaque groups with it
    rev = ensemble_spec(("teda-q", "hst"), 8)
    assert rev.offset("teda-q:mean") == 17
    assert rev.offset("hst:ref") == 19


def test_spec_validation_and_errors():
    with pytest.raises(ValueError, match="window must be >= 1"):
        ensemble_spec(ALL, 0)
    with pytest.raises(KeyError, match="unknown ensemble member"):
        member_regions("isolation-forest", 8)
    assert member_regions("teda", 8) == ()
    spec = ensemble_spec(ALL, 8)
    with pytest.raises(KeyError, match="no region 'nope'"):
        spec.offset("nope")
    with pytest.raises(ValueError, match="state.aux must be"):
        spec.validate_aux(np.zeros((17, 4), np.float32), 4)
    assert spec.init_aux(4).shape == (36, 4)


def test_bitcast_roundtrip_preserves_every_payload():
    # includes payloads that alias f32 NaN/denormal patterns
    payload = np.asarray([0, 1, -46, 2**31 - 1, -2**31, 0x7FC00000],
                         np.int32)
    f = i32_to_f32_bits(payload)
    np.testing.assert_array_equal(np.asarray(f32_to_i32_bits(f)), payload)


def test_spec_is_hashable_and_static():
    a = ensemble_spec(ALL, 8)
    assert a == ensemble_spec(ALL, 8)
    assert hash(a) == hash(ensemble_spec(ALL, 8))
    assert a != ensemble_spec(ALL, 4)
    assert isinstance(a.regions[0], Region) and isinstance(a, StateSpec)


# ------------------------------------------------------- HST oracle
def test_hst_leaf_binning():
    lo, hi = HST_RANGE
    x = np.asarray([lo - 10, lo, 0.0, hi - 1e-3, hi + 10], np.float32)
    leaves = np.asarray(hst_leaf(x))
    assert leaves[0] == 0 and leaves[1] == 0
    assert leaves[2] == HST_LEAVES // 2
    assert leaves[3] == HST_LEAVES - 1 and leaves[4] == HST_LEAVES - 1


def test_hst_oracle_window_flip_and_flags():
    # window=2 -> epoch length 2*HST_LEAVES=16.  A constant stream
    # fills one leaf; after the flip the reference mass is warm and a
    # far-off sample lands in an empty leaf -> score 0 -> flag.
    w, t = 2, 16
    x = np.zeros((t, 1), np.float32)
    st, out = hst_scan(x, 3.0, hst_init(1), window=w)
    assert not np.asarray(out["outlier"]).any()  # cold reference
    ref = np.asarray(st.ref)[:, 0]
    assert ref[int(hst_leaf(np.float32(0.0)))] == t  # flipped epoch mass
    assert np.asarray(st.cur).sum() == 0 and np.asarray(st.phase)[0] == 0
    nxt = np.asarray([[0.0], [3.9]], np.float32)
    st2, out2 = hst_scan(nxt, 3.0, st, window=w)
    o = np.asarray(out2["outlier"])[:, 0]
    s = np.asarray(out2["score"])[:, 0]
    assert s[0] == t and not o[0]     # dense leaf: mass 16, no flag
    assert s[1] == 0.0 and o[1]       # empty leaf: score 0 < window/m


def test_hst_oracle_chunked_carry_and_ragged_freeze():
    x = _stream(48, 3, seed=3)
    st1, o1 = hst_scan(x, 3.0, hst_init(3), window=2)
    st = hst_init(3)
    parts = []
    for i in range(0, 48, 16):
        st, o = hst_scan(x[i:i + 16], 3.0, st, window=2)
        parts.append(np.asarray(o["score"]))
    np.testing.assert_array_equal(np.concatenate(parts),
                                  np.asarray(o1["score"]))
    np.testing.assert_array_equal(np.asarray(st.ref), np.asarray(st1.ref))
    # vlen=0 freezes a channel exactly at its carried state
    stf, of = hst_scan(x, 3.0, st1, window=2, valid_lens=[48, 0, 7])
    np.testing.assert_array_equal(np.asarray(stf.ref)[:, 1],
                                  np.asarray(st1.ref)[:, 1])
    assert not np.asarray(of["outlier"])[:, 1].any()
    assert (np.asarray(of["score"])[7:, 2] == 0).all()


# ------------------------------------------ HST kernel conformance
def test_hst_kernel_exact_dense_and_ragged():
    t, c = 64, 4
    x = _stream(t, c, seed=0, burst=(40, 1))
    for vl in (None, [64, 17, 0, 33]):
        _, out = ensemble_scan(x, 3.0, detectors=("hst",),
                               valid_lens=vl, **KW)
        ref = ensemble_ref(x, 3.0, detectors=("hst",), valid_lens=vl)
        np.testing.assert_array_equal(np.asarray(out["det_flags"]),
                                      np.asarray(ref["det_flags"]))
        np.testing.assert_array_equal(  # EXACT, not allclose
            np.asarray(out["scores"][0]),
            np.asarray(ref["per_score"]["hst"]))


def test_hst_kernel_chunked_carry_bit_exact():
    t, c = 64, 4
    x = _stream(t, c, seed=1)
    st1, o1 = ensemble_scan(x, 3.0, detectors=("hst",), **KW)
    st = ensemble_init(c, detectors=("hst",))
    flags = []
    for i in range(0, t, 16):
        st, o = ensemble_scan(x[i:i + 16], 3.0, st,
                              detectors=("hst",), **KW)
        flags.append(np.asarray(o["det_flags"]))
    np.testing.assert_array_equal(np.concatenate(flags),
                                  np.asarray(o1["det_flags"]))
    np.testing.assert_array_equal(_bits(st.aux), _bits(st1.aux))
    np.testing.assert_array_equal(np.asarray(st.k), np.asarray(st1.k))


def test_hst_kernel_block_c_strip_invariance():
    t, c = 32, 256
    x = _stream(t, c, seed=2)
    vl = np.random.default_rng(2).integers(0, t + 1, c)
    st1, o1 = ensemble_scan(x, 3.0, detectors=("hst",), valid_lens=vl,
                            block_t=16, interpret=True)
    st2, o2 = ensemble_scan(x, 3.0, detectors=("hst",), valid_lens=vl,
                            block_t=16, block_c=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1["det_flags"]),
                                  np.asarray(o2["det_flags"]))
    np.testing.assert_array_equal(np.asarray(o1["scores"]),
                                  np.asarray(o2["scores"]))
    np.testing.assert_array_equal(_bits(st1.aux), _bits(st2.aux))


# ---------------------------------------------------- teda-q member
def test_tedaq_oracle_matches_fixedpoint_scan_chan():
    """The member oracle replays `_q_step_u` exactly — on a dense
    stream its registers and flags must equal the established
    fixed-point reference scan bit-for-bit."""
    x = _stream(48, 1, seed=4)
    (kf, meanf, varf), ref = teda_q_scan_chan(x, FMT, m=3.0)
    st, out = teda_q_member_scan(x, FMT, 3.0)
    np.testing.assert_array_equal(np.asarray(out["outlier"]),
                                  np.asarray(ref["outlier"]))
    np.testing.assert_array_equal(np.asarray(out["ecc"]),
                                  np.asarray(ref["ecc"]))
    np.testing.assert_array_equal(np.asarray(st.mean), np.asarray(meanf))
    np.testing.assert_array_equal(np.asarray(st.var), np.asarray(varf))


def test_tedaq_member_msq1_is_float32_path():
    m = np.float32(3.0)
    assert int(member_msq1(FMT, m)) == int(FMT.quantize(m * m + 1.0))


def test_tedaq_kernel_bit_exact_dense_ragged_chunked():
    t, c = 64, 4
    x = _stream(t, c, seed=5, burst=(40, 2))
    dets = ("teda-q",)
    for vl in (None, [64, 17, 0, 33]):
        _, out = ensemble_scan(x, 3.0, detectors=dets, fmt=FMT,
                               valid_lens=vl, **KW)
        ref = ensemble_ref(x, 3.0, detectors=dets, fmt=FMT,
                           valid_lens=vl)
        np.testing.assert_array_equal(np.asarray(out["det_flags"]),
                                      np.asarray(ref["det_flags"]))
        np.testing.assert_array_equal(  # dequantized ecc: EXACT
            np.asarray(out["scores"][0]),
            np.asarray(ref["per_score"]["teda-q"]))
    # chunked carry: opaque int32 registers ride the aux bit-exactly
    st1, o1 = ensemble_scan(x, 3.0, detectors=dets, fmt=FMT, **KW)
    st = ensemble_init(c, detectors=dets)
    for i in range(0, t, 16):
        st, _ = ensemble_scan(x[i:i + 16], 3.0, st, detectors=dets,
                              fmt=FMT, **KW)
    np.testing.assert_array_equal(_bits(st.aux), _bits(st1.aux))
    # the carried registers equal the oracle's final registers
    spec = ensemble_spec(dets, 8)
    stq, _ = teda_q_member_scan(x, FMT, 3.0)
    np.testing.assert_array_equal(
        _bits(st1.aux)[spec.slc("teda-q:mean")][0], np.asarray(stq.mean))
    np.testing.assert_array_equal(
        _bits(st1.aux)[spec.slc("teda-q:var")][0], np.asarray(stq.var))


def test_tedaq_requires_fmt():
    with pytest.raises(ValueError, match="teda-q ensemble member needs "
                                         "fmt=QFormat"):
        ensemble_scan(_stream(8, 2), 3.0, detectors=("teda", "teda-q"),
                      **KW)


# -------------------------------------------------- fused ensemble
def test_full_ensemble_flags_and_scores_conform():
    t, c = 64, 4
    x = _stream(t, c, seed=6, burst=(40, 1))
    vl = [64, 17, 0, 33]
    _, out = ensemble_scan(x, 3.0, detectors=ALL, fmt=FMT,
                           valid_lens=vl, **KW)
    ref = ensemble_ref(x, 3.0, detectors=ALL, fmt=FMT, valid_lens=vl)
    np.testing.assert_array_equal(np.asarray(out["det_flags"]),
                                  np.asarray(ref["det_flags"]))
    np.testing.assert_array_equal(np.asarray(out["vote"]),
                                  np.asarray(ref["vote"]))
    assert out["scores"].shape == (len(ALL), t, c)
    for d, name in enumerate(ALL):
        ker = np.asarray(out["scores"][d])
        exp = np.asarray(ref["per_score"][name])
        if name in MOMENT_MEMBERS:
            np.testing.assert_allclose(ker, exp, rtol=5e-3, atol=5e-3,
                                       err_msg=name)
        else:
            np.testing.assert_array_equal(ker, exp, err_msg=name)
    # invalid rows are zeroed in every stream
    assert (np.asarray(out["scores"])[:, :, 2] == 0).all()
    assert (np.asarray(out["scores"])[:, 17:, 1] == 0).all()


def test_moment_only_aux_identical_to_historical_shape():
    x = _stream(32, 4, seed=7)
    st, out = ensemble_scan(x, 3.0, detectors=DEFAULT_DETECTORS, **KW)
    assert st.aux.shape == (17, 4)
    assert out["scores"].shape == (3, 32, 4)


def test_q_vote_lane_host_recomputable_bit_exact():
    """The teda-q member's flag enters the same f32 detector-order
    weight accumulation as every other member: the fused vote must be
    reproducible on host from the emitted bitmask alone."""
    t, c = 64, 8
    x = _stream(t, c, seed=8, burst=(30, 3))
    w = np.asarray([1.0, 0.5, 1.0, 0.25, 2.0], np.float32)
    sel = np.broadcast_to(w[:, None], (5, c))
    thr = np.full((c,), 2.0, np.float32)
    _, out = ensemble_scan(x, 3.0, detectors=ALL, fmt=FMT, sel=sel,
                           thr=thr, **KW)
    bits = np.asarray(out["det_flags"])
    votew = np.zeros((t, c), np.float32)
    for d in range(len(ALL)):
        flag = ((bits >> d) & 1).astype(np.float32)
        votew = (votew + flag * w[d]).astype(np.float32)  # f32 order
    np.testing.assert_array_equal(np.asarray(out["vote"]), votew >= thr)
    assert bits.any()  # the burst actually flagged someone


@pytest.mark.slow
def test_q_vote_sweep_formats_and_seeds():
    """Slow sweep: the Q-vote lane stays host-recomputable and
    oracle-exact across word lengths and streams."""
    for fmt in (QFormat(16, 8), QFormat(24, 12), QFormat(32, 20)):
        for seed in range(3):
            x = _stream(96, 4, seed=seed, burst=(50, seed % 4))
            _, out = ensemble_scan(x, 3.0, detectors=ALL, fmt=fmt, **KW)
            ref = ensemble_ref(x, 3.0, detectors=ALL, fmt=fmt)
            np.testing.assert_array_equal(
                np.asarray(out["det_flags"]),
                np.asarray(ref["det_flags"]),
                err_msg=f"fmt={fmt} seed={seed}")
            np.testing.assert_array_equal(np.asarray(out["vote"]),
                                          np.asarray(ref["vote"]))
            np.testing.assert_array_equal(
                np.asarray(out["scores"][ALL.index("teda-q")]),
                np.asarray(ref["per_score"]["teda-q"]))


# ------------------------------------------------ migration contract
def _feed_pool(pool, rid, samples):
    """One ragged chunk to a sharded pool touching only `rid`'s slot."""
    s, slot = pool.lookup(rid)
    cap = pool.shard_capacity(s)
    chunk = np.zeros((len(samples), cap), np.float32)
    vl = np.zeros((cap,), np.int32)
    chunk[:, slot] = samples
    vl[slot] = len(samples)
    out = pool.process_shard(s, chunk, valid_lens=vl)
    return (np.asarray(out["outlier"])[:, slot],
            np.asarray(out["scores"])[:, :, slot])


@given_or_cases(
    "seed", [(0,), (1,), (2,)],
    lambda st: {"seed": st.integers(0, 99)}, max_examples=6)
def test_bucket_resize_carries_opaque_state_bits(seed):
    """Growing the bucket ladder re-pads the aux block as raw element
    bits: a mid-window hst/teda-q tenant sees identical verdicts and
    scores to a twin pool that never resized."""
    opts = dict(detectors=ALL, fmt=FMT, block_t=8, interpret=True)
    grow = SlotPool("ensemble", buckets=(2, 4), **opts)
    flat = SlotPool("ensemble", buckets=(4,), **opts)
    x = _stream(40, 1, seed=seed, burst=(33, 0))[:, 0]
    for pool in (grow, flat):
        pool.acquire(2, m=2.5)

    def feed(pool, samples):
        cap = pool.capacity
        chunk = np.zeros((len(samples), cap), np.float32)
        vl = np.zeros((cap,), np.int32)
        chunk[:, 0] = samples
        vl[0] = len(samples)
        out = pool.process(chunk, valid_lens=vl)
        return (np.asarray(out["outlier"])[:, 0],
                np.asarray(out["scores"])[:, :, 0])

    feed(grow, x[:20]), feed(flat, x[:20])     # warm, mid-epoch
    pre = _bits(grow.engine.state.aux)[:, :2].copy()
    grow.acquire(1)                            # 2 -> 4 bucket resize
    assert grow.capacity == 4
    np.testing.assert_array_equal(
        _bits(grow.engine.state.aux)[:, :2], pre)  # raw bits survived
    o_g, s_g = feed(grow, x[20:])
    o_f, s_f = feed(flat, x[20:])
    np.testing.assert_array_equal(o_g, o_f)
    np.testing.assert_array_equal(s_g, s_f)    # scores too, bit-for-bit
    assert o_g.any()                           # the burst flagged


@given_or_cases(
    "seed", [(0,), (1,), (2,)],
    lambda st: {"seed": st.integers(0, 99)}, max_examples=6)
def test_shard_migration_carries_opaque_state_bits(seed):
    """`ShardedPool.migrate` moves the full StateSpec column — moment
    tails, hst tables, bitcast Q registers — as raw bits; the stream's
    post-move verdicts and score streams equal the unmigrated twin's."""
    opts = dict(shards=2, buckets=(2, 4), detectors=ALL, fmt=FMT,
                block_t=8, interpret=True)
    moved = ShardedPool("ensemble", **opts)
    still = ShardedPool("ensemble", **opts)
    x = _stream(40, 1, seed=seed, burst=(33, 0))[:, 0]
    for pool in (moved, still):
        pool.acquire("a", m=2.5)
    _feed_pool(moved, "a", x[:20]), _feed_pool(still, "a", x[:20])
    src_s, src_slot = moved.lookup("a")
    eng = moved.pools[src_s].engine
    pre = _bits(eng.state.aux)[:, src_slot].copy()
    assert pre[17:].any()                      # opaque regions are warm
    dst = 1 - src_s
    new_slot = moved.migrate("a", dst)
    np.testing.assert_array_equal(
        _bits(moved.pools[dst].engine.state.aux)[:, new_slot], pre)
    o_m, s_m = _feed_pool(moved, "a", x[20:])
    o_s, s_s = _feed_pool(still, "a", x[20:])
    np.testing.assert_array_equal(o_m, o_s)
    np.testing.assert_array_equal(s_m, s_s)
    assert o_m.any()


# ----------------------------------------------- score streams e2e
def test_score_streams_reach_gateway_telemetry():
    """Per-request `det_scores` arrive end-to-end: kernel -> engine ->
    pool -> scheduler chunk_retired events -> gateway per-request
    telemetry, as per-detector means over retired samples."""
    rng = np.random.default_rng(9)
    streams = [(f"t{i}", rng.normal(size=(24,)).astype(np.float32),
                rng.normal(size=(8,)).astype(np.float32), 3.0)
               for i in range(3)]
    events = []
    res = serve_streams(
        streams, backend="ensemble", chunk_t=16, interpret=True,
        measure_latency=True, detectors=ALL, fmt=FMT, window=8,
        on_event=events.append)
    for rid, pr in res["per_request"].items():
        assert set(pr["det_scores"]) == set(ALL)
        assert pr["samples"] == 32
        # teda eccentricity and rde density are strictly positive on
        # normal data; the mean must reflect that
        assert pr["det_scores"]["teda"] > 0
        assert pr["det_scores"]["rde"] > 0
        assert all(np.isfinite(v) for v in pr["det_scores"].values())
    retired = [e for e in events if e.kind == "chunk_retired"]
    assert retired and all("det_scores" in e.data for e in retired)
    # the telemetry mean is exactly the event-stream sum / samples
    for rid, pr in res["per_request"].items():
        sums = {}
        for e in retired:
            if e.rid == rid:
                for d, s in e.data["det_scores"].items():
                    sums[d] = sums.get(d, 0.0) + s
        for d in ALL:
            assert pr["det_scores"][d] == pytest.approx(
                sums[d] / pr["samples"])


def test_engine_scores_zeroed_on_inactive_slots():
    from repro.engine import StreamEngine
    eng = StreamEngine(4, "ensemble", m=3.0, detectors=("teda", "rde"),
                       block_t=8, interpret=True, auto_attach=False)
    eng.attach([0, 2])
    out = eng.process(_stream(16, 4, seed=10))
    sc = np.asarray(out["scores"])
    assert sc.shape == (2, 16, 4)
    assert (sc[:, :, [1, 3]] == 0).all()
    assert sc[:, :, [0, 2]].any()
