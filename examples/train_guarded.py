"""End-to-end guarded training: corrupt batches are skipped, training
survives a simulated crash, and resumes from the checkpoint.

    PYTHONPATH=src python examples/train_guarded.py             # tiny, fast
    PYTHONPATH=src python examples/train_guarded.py --scale small --steps 300
        # ~100M-parameter class, a few hundred steps (the deliverable-(b)
        # configuration; needs a few CPU-hours here, minutes on a real pod)
"""
import argparse
import shutil
import tempfile

from repro.configs.registry import get_config
from repro.core.guard import GuardConfig
from repro.launch.train import train

GUARD = GuardConfig(m=3.0, warmup_steps=8, channels=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cfg = cfg.reduced() if args.scale == "tiny" else cfg.reduced(
        n_layers=8, d_model=768, n_heads=12, n_kv=4, head_dim=64,
        d_ff=3072 if cfg.d_ff else 0, vocab=32768)

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        half = args.steps // 2
        print(f"=== phase 1: train to step {half} with corrupt batches every 7 steps "
              f" (TEDA guard active) ===")
        train(cfg, half, args.batch, args.seq, ckpt,
              corrupt_every=7, save_every=max(half // 2, 1),
              guard_cfg=GUARD)

        print("=== simulated crash; phase 2: resume from checkpoint ===")
        _, hist, stats = train(cfg, args.steps, args.batch, args.seq,
                               ckpt, resume=True, corrupt_every=7,
                               save_every=args.steps, guard_cfg=GUARD)
        first, last = hist[0]["loss"], hist[-1]["loss"]
        print(f"loss {first:.3f} -> {last:.3f}; guard skipped "
              f"{stats['skipped']} corrupt steps")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
