"""Serving example: batched decode with TEDA stream monitoring.

    PYTHONPATH=src python examples/serve_monitored.py
"""
from repro.configs.registry import get_config
from repro.launch.serve import serve


def main():
    cfg = get_config("qwen2-7b").reduced()
    res = serve(cfg, batch=4, prompt_len=24, gen=24)
    print(f"prefill: {res['prefill_tok_s']:.1f} tok/s, "
          f"decode: {res['decode_tok_s']:.1f} tok/s")
    print(f"TEDA-flagged requests: {res['flagged_requests']}")
    assert res["tokens"].shape == (4, 24)
    print("OK")


if __name__ == "__main__":
    main()
