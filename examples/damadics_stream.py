"""Figures 6-7 analog: DAMADICS fault detection with eccentricity curves.

Reproduces the paper's validation: TEDA (m = 3) over actuator telemetry
with injected faults; the normalized eccentricity crosses the 5/k
threshold inside the fault window. ASCII-plots the curves.

    PYTHONPATH=src python examples/damadics_stream.py [--item 0]
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import teda_scan
from repro.data.damadics import detection_report, make_benchmark


def ascii_plot(y, thr, flags, width=72, height=12, title=""):
    n = len(y)
    step = max(1, n // width)
    ys = y[::step][:width]
    ts = thr[::step][:width]
    fs = flags[::step][:width]
    top = max(float(np.max(ys)), float(np.max(ts))) * 1.05 + 1e-9
    rows = []
    for r in range(height, 0, -1):
        lo, hi = top * (r - 1) / height, top * r / height
        line = ""
        for i in range(len(ys)):
            if lo <= ys[i] < hi:
                line += "!" if fs[i] else "*"
            elif lo <= ts[i] < hi:
                line += "-"
            else:
                line += " "
        rows.append(line)
    print(title)
    print("\n".join(rows))
    print("*" + " eccentricity  " + "-" + " threshold 5/k  "
          + "!" + " outlier")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--item", type=int, default=0,
                    help="Table-2 fault item (0-6)")
    args = ap.parse_args()

    x, w = make_benchmark(args.item)
    lo = max(0, w.start - 20000)
    hi = min(len(x), w.stop + 2000)
    seg = jnp.asarray(x[lo:hi])
    print(f"fault item {args.item + 1}: type {w.kind}, window "
          f"[{w.start}, {w.stop}) of {len(x)} samples; scoring "
          f"[{lo}, {hi})")

    _, out = teda_scan(seg, m=3.0)
    zeta = np.asarray(out.zeta)
    thr = np.asarray(out.threshold)
    flags = np.asarray(out.outlier)

    shifted = type(w)(w.kind, w.start - lo, w.stop - lo)
    rep = detection_report(flags, shifted)
    print(f"hit={bool(rep['hit'])} latency={int(rep['latency_samples'])} "
          f"samples, false alarm rate={rep['false_alarm_rate']:.5f}")

    view = slice(max(0, shifted.start - 2000), shifted.stop + 1000)
    ascii_plot(zeta[view], thr[view], flags[view],
               title=f"normalized eccentricity vs 5/k (m=3), fault "
                     f"{w.kind}")


if __name__ == "__main__":
    main()
