"""Quickstart: TEDA streaming anomaly detection in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import teda_scan, teda_stream
from repro.kernels.ops import teda_scan_tpu

# a 2-channel stream with an anomaly burst at t in [600, 620)
rng = np.random.default_rng(0)
x = rng.normal(size=(1000, 2)).astype(np.float32)
x[600:620] += 6.0

# 1) paper-faithful sequential TEDA (Algorithm 1, m = 3)
state, out = teda_stream(jnp.asarray(x), m=3.0)
hits = np.flatnonzero(np.asarray(out.outlier))
print(f"sequential TEDA: {len(hits)} outliers, first at k={hits[0] + 1}")

# 2) parallel (associative-scan) form — same verdicts, log-depth
_, out_par = teda_scan(jnp.asarray(x), m=3.0)
assert (np.asarray(out_par.outlier) == np.asarray(out.outlier)).all()
print("associative-scan form: identical verdicts")

# 3) the Pallas TPU kernel (interpret mode on CPU), 128 channels at once.
# Smooth telemetry + small noise (pure white noise would trip Chebyshev's
# loose bound ~0.3%/sample on every channel — the paper's streams are
# smooth industrial signals).
base = rng.uniform(-1, 1, size=(1, 128))
xc = (base + 0.05 * rng.normal(size=(1000, 128))).astype(np.float32)
xc[500:510, 7] += 2.0
final, outs = teda_scan_tpu(jnp.asarray(xc), m=5.0)
ch_hits = np.flatnonzero(np.asarray(outs["outlier"]).any(axis=0))
print(f"pallas kernel: anomalous channels = {ch_hits.tolist()}")
assert ch_hits.tolist() == [7]

# 4) streaming restart: state carries across calls
st1, _ = teda_stream(jnp.asarray(x[:500]))
st2, out2 = teda_stream(jnp.asarray(x[500:]), state=st1)
assert bool(out2.outlier[100:120].any())  # the burst is still caught
print("stateful restart: burst detected across call boundary")

# 5) TEDA data clouds (TEDAClass-style evolving classifier): three
# sequential operating regimes -> three clouds, no parameters but m
from repro.core import clouds_run
regimes = np.concatenate([
    rng.normal(size=(40, 2)) * 0.1 + [0, 0],
    rng.normal(size=(40, 2)) * 0.1 + [4, 4],
    rng.normal(size=(40, 2)) * 0.1 + [-4, 4]]).astype(np.float32)
cstate, members = clouds_run(jnp.asarray(regimes), capacity=8, m=3.0)
print(f"data clouds discovered: {int(cstate.n_active)} (expected 3)")
