"""StreamEngine quickstart: stateful multi-stream TEDA with ragged slots.

One engine, 8 tenant slots, chunks arriving at arbitrary lengths; slot 5
is recycled for a new tenant mid-flight.  Swap `backend=` between
"scan" / "pallas" / "pallas-q" — the streaming contract is identical.

    PYTHONPATH=src python examples/quickstart_engine.py
"""
import numpy as np

from repro.engine import StreamEngine
from repro.fixedpoint import QFormat

rng = np.random.default_rng(0)
C = 8


def make_chunk(t):
    x = rng.normal(size=(t, C)).astype(np.float32)
    return x


eng = StreamEngine(capacity=C, backend="pallas", m=4.0, block_t=64)

# --- chunks of whatever length the gateway hands us -------------------
for t in (37, 128, 9):
    out = eng.process(make_chunk(t))
print(f"after 174 samples: per-slot k = {eng.samples_seen.tolist()}")

# --- slot 5: old tenant leaves, new tenant arrives mid-flight ---------
eng.reset([5])

# --- the new tenant misbehaves ----------------------------------------
chunk = make_chunk(60)
chunk[40:44, 5] += 25.0  # anomaly burst on slot 5 only
out = eng.process(chunk)
flags = np.asarray(out["outlier"])
print(f"slot 5 flagged at rows {np.flatnonzero(flags[:, 5]).tolist()}; "
      f"other slots flagged: {bool(flags[:, :5].any() or flags[:, 6:].any())}")
print(f"ragged per-slot k = {eng.samples_seen.tolist()}")

# --- same stream, bit-accurate FPGA datapath --------------------------
eng_q = StreamEngine(capacity=C, backend="pallas-q", m=4.0, fmt=QFormat(32, 20),
                     block_t=64)
out_q = eng_q.process(chunk)
agree = (np.asarray(out_q["outlier"]) == flags).mean()
print(f"Q11.20 kernel verdict agreement on this chunk: {agree:.3f}")
print("OK")
