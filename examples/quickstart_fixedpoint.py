"""Quickstart: float vs bit-accurate fixed-point TEDA on DAMADICS.

The paper's FPGA runs TEDA in fixed-point; this demo shows the repo's
Q-format emulation reproducing the float pipeline's verdicts — and
degrading gracefully as the word length shrinks, which is the trade-off
the hardware designer sweeps before synthesis.

    PYTHONPATH=src python examples/quickstart_fixedpoint.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.teda import teda_stream
from repro.data.damadics import make_benchmark
from repro.fixedpoint import QFormat, teda_q_stream, teda_q_scan_chan
from repro.kernels.ops import teda_q_scan_tpu

# A DAMADICS-style window around the Table-2 item-7 fault (f17 offset)
x, w = make_benchmark(6, t_len=40000)
seg = x[w.start - 1500:w.stop + 500]  # 2-channel stream, fault inside

# 1) float32 reference verdicts (Algorithm 1, m = 3)
_, out_f = teda_stream(jnp.asarray(seg), m=3.0)
flags_f = np.asarray(out_f.outlier)
print(f"float32 TEDA: {int(flags_f.sum())} outlier samples")

# 2) bit-accurate Q11.20 (WL=32) — the synthesis-ready word length
fmt32 = QFormat(32, 20)
_, out_q = teda_q_stream(jnp.asarray(seg), fmt32, m=3.0)
flags_q = np.asarray(out_q.outlier)
agree = float((flags_q == flags_f).mean())
print(f"{fmt32.label()}: {int(flags_q.sum())} outliers, "
      f"verdict agreement {agree:.2%}")
assert agree >= 0.99  # the acceptance bar for the bit-accurate datapath

# 3) a skinny 16-bit datapath: cheaper LUTs, coarser eccentricity
fmt16 = QFormat(16, 10)
_, out_16 = teda_q_stream(jnp.asarray(seg), fmt16, m=3.0)
agree16 = float((np.asarray(out_16.outlier) == flags_f).mean())
print(f"{fmt16.label()}: verdict agreement {agree16:.2%} "
      f"(resolution {fmt16.resolution:.2e})")

# 4) the integer Pallas kernel (interpret mode on CPU) is bit-exact
# with the pure-JAX Q scan — same per-row step function by construction
rng = np.random.default_rng(0)
xc = rng.normal(size=(256, 4)).astype(np.float32)
xc[200:204, 1] += 8.0
_, out_kern = teda_q_scan_tpu(jnp.asarray(xc), fmt32, m=3.0, block_t=64)
_, out_scan = teda_q_scan_chan(jnp.asarray(xc), fmt32, m=3.0)
assert (np.asarray(out_kern["ecc"]) == np.asarray(out_scan["ecc"])).all()
assert (np.asarray(out_kern["outlier"])
        == np.asarray(out_scan["outlier"])).all()
print("pallas integer kernel: bit-exact with the Q-format lax.scan")
