"""Bit-accurate simulation analog: word-length sweep vs float64 oracle.

The paper validates its FPGA datapath with a bit-accurate fixed-point
simulation.  This benchmark regenerates that study for the repo: for
each QFormat (WL in {16, 24, 32}, FL swept) it runs the integer TEDA
datapath over a DAMADICS fault stream and a synthetic spike stream and
reports eccentricity error + outlier-verdict agreement against the
float64 software oracle.

  PYTHONPATH=src python -m benchmarks.bench_bitaccurate \
      [--t-len 3000] [--out experiments/bitaccurate/sweep.json]

Prints ``name,us_per_call,derived`` CSV rows (the run.py harness
format) and writes the full sweep as JSON.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.data.damadics import make_benchmark
from repro.fixedpoint.analysis import DEFAULT_FORMATS, wordlength_sweep


def damadics_stream(t_len: int = 3000) -> np.ndarray:
    """A window of Table-2 item 7 (f17 offset fault) covering the fault."""
    x, w = make_benchmark(6, t_len=40000)
    # center the whole fault window inside the t_len slice
    lo = max(0, w.start - max(t_len - (w.stop - w.start), 0) // 2)
    return x[lo:lo + t_len]


def synthetic_stream(t_len: int = 3000) -> np.ndarray:
    rng = np.random.default_rng(42)
    x = rng.normal(size=(t_len, 2)).astype(np.float32)
    x[t_len // 2:t_len // 2 + 12] += 6.0
    x[3 * t_len // 4:3 * t_len // 4 + 5, 0] += 9.0
    return x


def run(t_len: int = 3000, m: float = 3.0):
    streams = {
        "damadics_f17": damadics_stream(t_len),
        "synthetic": synthetic_stream(t_len),
    }
    report = {"m": m, "t_len": t_len, "streams": []}
    for name, x in streams.items():
        rows = wordlength_sweep(x, DEFAULT_FORMATS, m)
        report["streams"].append({"name": name, "t_len": int(len(x)),
                                  "formats": rows})
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--t-len", type=int, default=3000)
    ap.add_argument("--m", type=float, default=3.0)
    ap.add_argument("--out", default="experiments/bitaccurate/sweep.json")
    args, _ = ap.parse_known_args()

    report = run(args.t_len, args.m)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print("name,us_per_call,derived")
    for stream in report["streams"]:
        for r in stream["formats"]:
            print(f"bitaccurate/{stream['name']}_wl{r['word_len']}"
                  f"_fl{r['frac_len']},0,"
                  f"agree={r['verdict_agreement']:.5f}"
                  f"|max_err={r['max_abs_err_ecc']:.3e}"
                  f"|mean_err={r['mean_abs_err_ecc']:.3e}"
                  f"|missed={r['missed']}|spurious={r['spurious']}")


if __name__ == "__main__":
    main()
