"""Table 3 analog: resource occupation of the TEDA compute graph.

FPGA LUT/DSP/register counts have no TPU meaning (DESIGN.md §2); the
TPU-native occupation metrics are the compiled graph's op census, flops,
bytes, and the Pallas kernel's VMEM working set vs the 128 MiB/core
budget — reported per TEDA form.
"""
from __future__ import annotations

import collections
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scan import teda_scan
from repro.core.teda import teda_stream

VMEM_BYTES = 128 * 1024 * 1024  # v5e VMEM per core


def graph_census(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    cost = comp.cost_analysis() or {}
    txt = comp.as_text()
    ops = collections.Counter(
        m.group(1) for m in re.finditer(r"= \S+ ([a-z][\w-]*)\(", txt))
    interesting = {k: v for k, v in ops.items() if k in (
        "multiply", "add", "subtract", "divide", "rsqrt", "exponential",
        "compare", "select", "while", "fusion", "dot")}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "ops": dict(interesting),
        "n_ops_total": sum(ops.values()),
    }


def kernel_vmem(block_t: int = 256, channels: int = 128) -> dict:
    """Static VMEM budget of the Pallas kernel (per BlockSpec tiling)."""
    in_block = block_t * channels * 4
    out_blocks = 4 * block_t * channels * 4
    scratch = 2 * channels * 4
    # doubling-scan temporaries: ~2 live copies of (block_t, C) f32 x 2
    temps = 4 * block_t * channels * 4
    total = in_block + out_blocks + scratch + temps
    return {"vmem_bytes": total, "vmem_frac": total / VMEM_BYTES,
            "block_t": block_t, "channels": channels}


def run(t_len: int = 4096):
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(t_len, 2)).astype(np.float32))
    rows = {}
    rows["lax_scan"] = graph_census(
        lambda v: teda_stream(v, 3.0)[1].ecc, x)
    rows["assoc_scan"] = graph_census(
        lambda v: teda_scan(v, 3.0)[1].ecc, x)
    rows["pallas_kernel_vmem"] = kernel_vmem()
    return rows


def main():
    print("name,us_per_call,derived")
    for name, r in run().items():
        if "vmem_bytes" in r:
            print(f"occupation/{name},0,"
                  f"vmem={r['vmem_bytes']}B|{r['vmem_frac']*100:.2f}%of_vmem"
                  f"|block_t={r['block_t']}x{r['channels']}ch")
        else:
            print(f"occupation/{name},0,"
                  f"flops={r['flops']:.0f}|bytes={r['bytes']:.0f}"
                  f"|hlo_ops={r['n_ops_total']}")


if __name__ == "__main__":
    main()
