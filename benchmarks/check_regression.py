"""CI perf-regression gate: compare bench JSON against committed baselines.

The runtime-vs-efficacy trade-off is a measured quantity (Choudhary et
al., arXiv 1710.04735) — so CI enforces it instead of only checking
correctness.  For every baseline under `benchmarks/baselines/`, the
same-named file in `--current` is loaded, rows are matched on their
identity keys (backend, chunk_t / offered_load, ...), and the gate
fails when `samples_per_s` drops more than `--threshold` (default 25%)
below the committed number for any row.  Malformed or empty bench JSON
is itself a failure (exit 2): an empty rows list must never read as
"no regression".

    PYTHONPATH=src python benchmarks/run.py --only engine  --smoke --out-dir out
    PYTHONPATH=src python benchmarks/run.py --only serving --smoke --out-dir out
    python benchmarks/check_regression.py --current out \
        --explain out/regression_report.md

`--explain PATH` writes a markdown evidence report — one table per
bench file (configuration | baseline | current | ratio | verdict)
plus, per row, the `repro.obs` metrics summary the current run
embedded — written on the pass path too, so every CI run leaves an
auditable artifact, not just the red ones.

Refresh the committed baselines after an intentional perf change with
`--update` (runs the same validation, then copies current -> baselines).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import shutil
import sys

# row fields that identify a configuration (everything else is measured)
ID_KEYS = ("bench", "backend", "chunk_t", "decode_t", "offered_load",
           "shape", "channels", "block_t", "block_c", "outputs",
           "pipeline_depth", "detector", "ensemble_k", "vote",
           "shards", "window", "state_rows")
METRIC = "samples_per_s"


class MalformedBench(ValueError):
    pass


def validate_doc(doc, name: str = "bench") -> list:
    """Shape-check one bench JSON doc; returns its rows.

    Raises MalformedBench on anything a silently-green gate could hide
    behind: no rows, rows missing the metric, non-finite or
    non-positive samples/s.
    """
    if not isinstance(doc, dict) or not isinstance(doc.get("rows"), list):
        raise MalformedBench(f"{name}: not a bench doc (no rows list)")
    rows = doc["rows"]
    if not rows:
        raise MalformedBench(f"{name}: empty rows — benchmark ran nothing")
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or "backend" not in row:
            raise MalformedBench(f"{name} row {i}: missing backend")
        v = row.get(METRIC)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            raise MalformedBench(
                f"{name} row {i} ({row.get('backend')}): bad {METRIC}={v!r}")
    return rows


def load_doc(path: pathlib.Path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise MalformedBench(f"{path}: unreadable JSON ({e})") from None


def row_id(doc, row) -> tuple:
    keys = {"bench": doc.get("bench")}
    keys.update({k: row[k] for k in ID_KEYS if k in row})
    return tuple(sorted(keys.items()))


def compare(baseline_path: pathlib.Path, current_path: pathlib.Path,
            threshold: float) -> list:
    """Returns a list of result dicts, one per matched row."""
    base_doc = load_doc(baseline_path)
    cur_doc = load_doc(current_path)
    base = {row_id(base_doc, r): r
            for r in validate_doc(base_doc, str(baseline_path))}
    cur = {row_id(cur_doc, r): r
           for r in validate_doc(cur_doc, str(current_path))}
    missing = sorted(set(base) - set(cur))
    if missing:
        raise MalformedBench(
            f"{current_path}: missing {len(missing)} baseline rows, "
            f"first: {dict(missing[0])}")
    results = []
    for rid, b in sorted(base.items()):
        c = cur[rid]
        ratio = c[METRIC] / b[METRIC]
        results.append({
            "id": dict(rid), "baseline": b[METRIC], "current": c[METRIC],
            "ratio": ratio, "ok": ratio >= 1.0 - threshold,
            "metrics": c.get("metrics")})
    return results


# ------------------------------------------------------ evidence report
def _ident_str(ident: dict) -> str:
    return ", ".join(f"{k}={v}" for k, v in ident.items()
                     if k != "bench")


def _metrics_lines(snap: dict) -> list:
    """Flatten an embedded metrics summary into exposition-ish lines."""
    lines = []
    for name in sorted(snap):
        fam = snap[name]
        for s in fam.get("samples", []):
            lbl = ",".join(f'{k}="{v}"' for k, v in
                           sorted(s.get("labels", {}).items()))
            sfx = f"{{{lbl}}}" if lbl else ""
            if fam.get("type") == "histogram":
                lines.append(
                    f"{name}{sfx} count={s['count']:g} sum={s['sum']:.6g}"
                    f" p50={s['p50']:.6g} p95={s['p95']:.6g}")
            else:
                lines.append(f"{name}{sfx} {s['value']:g}")
    return lines


def write_explain(path, sections, threshold: float) -> None:
    """Markdown evidence report: per-row baseline-vs-current verdicts
    plus each current row's embedded `repro.obs` metrics summary."""
    any_rows = any(s["results"] for s in sections)
    failed = (any(s["error"] for s in sections)
              or any(not r["ok"] for s in sections for r in s["results"]))
    lines = [
        "# Perf-regression gate evidence",
        "",
        f"- metric: `{METRIC}` (higher is better)",
        f"- gate: current/baseline ratio >= {1.0 - threshold:.2f}",
        f"- verdict: **{'FAIL' if failed or not any_rows else 'PASS'}**",
        "",
    ]
    for sec in sections:
        lines += [f"## {sec['name']}", ""]
        if sec["error"]:
            lines += [f"**MALFORMED / MISSING:** {sec['error']}", ""]
            continue
        # detector-matrix benches get one table per detector (rows
        # without a detector key share the trailing group) so the
        # conformance grid reads as a grid, not an interleaved list
        groups: dict = {}
        for r in sec["results"]:
            groups.setdefault(r["id"].get("detector"), []).append(r)
        for det in sorted(groups, key=lambda d: (d is None, d)):
            if len(groups) > 1 and det is not None:
                lines += [f"### detector: {det}", ""]
            lines += ["| configuration | baseline | current | ratio "
                      "| verdict |",
                      "|---|---:|---:|---:|---|"]
            for r in groups[det]:
                verdict = "ok" if r["ok"] else "**FAIL**"
                lines.append(
                    f"| {_ident_str(r['id'])} | {r['baseline']:.1f} "
                    f"| {r['current']:.1f} | {r['ratio']:.3f} "
                    f"| {verdict} |")
            lines.append("")
        for r in sec["results"]:
            if not r.get("metrics"):
                continue
            lines += [f"<details><summary>metrics evidence: "
                      f"{_ident_str(r['id'])}</summary>", "", "```"]
            lines += _metrics_lines(r["metrics"])
            lines += ["```", "", "</details>", ""]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory of committed baseline JSON files")
    ap.add_argument("--current", required=True,
                    help="directory of freshly produced bench JSON")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional samples/s regression")
    ap.add_argument("--update", action="store_true",
                    help="validate, then copy current over the baselines")
    ap.add_argument("--explain", default=None, metavar="PATH",
                    help="write a markdown evidence report here "
                         "(written on pass and fail alike)")
    args = ap.parse_args(argv)

    bdir = pathlib.Path(args.baselines)
    cdir = pathlib.Path(args.current)
    baselines = sorted(bdir.glob("*.json"))
    if not baselines:
        print(f"[regression] no baselines under {bdir}", file=sys.stderr)
        return 2

    if args.update:
        for bpath in baselines:
            cpath = cdir / bpath.name
            validate_doc(load_doc(cpath), str(cpath))
            shutil.copy(cpath, bpath)
            print(f"[regression] updated {bpath} from {cpath}")
        return 0

    failed, malformed = False, None
    sections = []
    for bpath in baselines:
        cpath = cdir / bpath.name
        if not cpath.exists():
            print(f"[regression] FAIL {bpath.name}: {cpath} not produced",
                  file=sys.stderr)
            failed = True
            sections.append({"name": bpath.name,
                             "error": f"{cpath} not produced",
                             "results": []})
            continue
        try:
            results = compare(bpath, cpath, args.threshold)
        except MalformedBench as e:
            if args.explain is None:
                raise
            malformed = malformed or e
            sections.append({"name": bpath.name, "error": str(e),
                             "results": []})
            continue
        sections.append({"name": bpath.name, "error": None,
                         "results": results})
        for res in results:
            tag = "ok  " if res["ok"] else "FAIL"
            ident = {k: v for k, v in res["id"].items() if k != "bench"}
            print(f"[regression] {tag} {bpath.name} {ident}: "
                  f"{res['current']:.0f} vs baseline {res['baseline']:.0f} "
                  f"samples/s (x{res['ratio']:.2f})")
            failed = failed or not res["ok"]
    if args.explain:
        write_explain(args.explain, sections, args.threshold)
        print(f"[regression] evidence report: {args.explain}")
    if malformed is not None:
        raise malformed
    if failed:
        print(f"[regression] FAILED: >{args.threshold:.0%} samples/s "
              "regression (or missing rows); if intentional, refresh "
              "baselines with --update", file=sys.stderr)
        return 1
    print("[regression] all benchmarks within threshold")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except MalformedBench as e:
        print(f"[regression] MALFORMED: {e}", file=sys.stderr)
        sys.exit(2)
