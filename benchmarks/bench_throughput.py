"""Table 4 analog: TEDA processing time / throughput (samples per second).

The paper reports t_c = 138 ns, initial delay 3*t_c, throughput 7.2 MSPS
for the FPGA pipeline. We report, on this host:

  * python_loop      — the paper's software baseline (Table 5 row 1)
  * lax_scan         — paper-faithful recurrence (the pipeline analog)
  * associative_scan — beyond-paper parallel form (core/scan.py)
  * pallas_interpret — the TPU kernel executed in interpret mode
                       (functional on CPU; its real target is TPU)

Each row: wall time per call, ns per sample, throughput in MSPS, plus the
"initial delay" analog = jit compile time. Batched-channel rows show the
throughput scaling the paper gets from replicating TEDA modules
("multiple TEDA modules in parallel", paper §5.2.1).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scan import teda_scan
from repro.core.teda import teda_numpy_loop, teda_stream
from repro.kernels.ops import teda_scan_tpu


def _time(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(t_len: int = 16384, channels: int = 128, reps: int = 5):
    rng = np.random.default_rng(0)
    x_mv = jnp.asarray(rng.normal(size=(t_len, 2)).astype(np.float32))
    x_ch = jnp.asarray(
        rng.normal(size=(t_len, channels)).astype(np.float32))
    rows = []

    # python loop (samples = t_len) — the software platform
    small = np.asarray(x_mv[:2048])
    t0 = time.perf_counter()
    teda_numpy_loop(small, 3.0)
    t_loop = (time.perf_counter() - t0) / 2048 * t_len
    rows.append(("python_loop", t_loop, t_len, 0.0))

    # paper-faithful lax.scan
    f_scan = jax.jit(lambda v: teda_stream(v, 3.0)[1].ecc)
    tc0 = time.perf_counter()
    jax.block_until_ready(f_scan(x_mv))
    delay_scan = time.perf_counter() - tc0
    rows.append(("lax_scan", _time(f_scan, x_mv, reps=reps), t_len,
                 delay_scan))

    # beyond-paper associative scan
    f_assoc = jax.jit(lambda v: teda_scan(v, 3.0)[1].ecc)
    tc0 = time.perf_counter()
    jax.block_until_ready(f_assoc(x_mv))
    delay_assoc = time.perf_counter() - tc0
    rows.append(("assoc_scan", _time(f_assoc, x_mv, reps=reps), t_len,
                 delay_assoc))

    # multichannel (the "parallel TEDA modules" scaling row)
    f_assoc_ch = jax.jit(
        lambda v: teda_scan(v[..., None], 3.0)[1].ecc)
    jax.block_until_ready(f_assoc_ch(x_ch))
    rows.append((f"assoc_scan_x{channels}ch",
                 _time(f_assoc_ch, x_ch, reps=reps),
                 t_len * channels, 0.0))

    # pallas kernel (interpret mode on CPU)
    f_pallas = lambda v: teda_scan_tpu(v, 3.0, block_t=512)[1]["ecc"]
    jax.block_until_ready(f_pallas(x_ch))
    rows.append((f"pallas_interpret_x{channels}ch",
                 _time(f_pallas, x_ch, reps=max(2, reps // 2)),
                 t_len * channels, 0.0))

    out = []
    for name, wall, samples, delay in rows:
        ns_per = wall / samples * 1e9
        msps = samples / wall / 1e6
        out.append({
            "name": name, "wall_s": wall, "samples": samples,
            "ns_per_sample": ns_per, "throughput_msps": msps,
            "initial_delay_s": delay,
        })
    return out


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"throughput/{r['name']},{r['wall_s'] * 1e6:.1f},"
              f"{r['throughput_msps']:.3f}MSPS|"
              f"{r['ns_per_sample']:.1f}ns_per_sample|"
              f"delay={r['initial_delay_s']:.3f}s")


if __name__ == "__main__":
    main()
