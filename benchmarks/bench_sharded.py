"""Sharded-pool scaling bench: samples/s at shards ∈ {1, 2, 4}.

Measures what `engine/sharded.py` buys and what it costs: a fixed
stream population is served through a `ShardedPool` at increasing
shard counts (shape "uniform" — the scaling-efficiency rows: perfect
sharding holds samples/s flat as K grows on one host, and splits the
work K ways on K real devices), plus a "storm" shape that migrates a
stream between shards every chunk mid-run — the worst-case rebalancer
cadence — so the migration path's host-sync cost is a measured number
next to the steady-state rows.

Rows carry `shards` (a `check_regression.py` identity key) and
`samples_per_s` (the gated metric); uniform rows also carry
`scaling_efficiency` — their throughput relative to the same
backend's shards=1 row.  Runs on whatever devices jax sees: CI gates
on the single-device CPU numbers; `REPRO_VIRTUAL_DEVICES=8` exercises
the same code over a split host.

    PYTHONPATH=src python benchmarks/bench_sharded.py
    PYTHONPATH=src python benchmarks/bench_sharded.py --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.engine import ShardedPool, list_backends
from repro.fixedpoint import QFormat

SHARD_COUNTS = (1, 2, 4)


def _serve_chunks(pool, rids, data, t, storm: bool) -> int:
    """Feed every chunk through the pool's shards; returns migrations
    executed.  Each chunk's outlier plane is fetched to host — the
    same consume cadence the scheduler has — so reps are comparable
    across shard counts."""
    chunks = data.shape[0] // t
    moved = 0
    for c in range(chunks):
        if storm and c and pool.n_shards > 1:
            rid = rids[c % len(rids)]
            src = pool.lookup(rid)[0]
            pool.migrate(rid, (src + 1) % pool.n_shards)
            moved += 1
        by_shard = {}
        for j, rid in enumerate(rids):
            s, slot = pool.lookup(rid)
            by_shard.setdefault(s, []).append((slot, j))
        for s, members in sorted(by_shard.items()):
            cap = pool.shard_capacity(s)
            x = np.zeros((t, cap), np.float32)
            vl = np.zeros((cap,), np.int32)
            for slot, j in members:
                x[:, slot] = data[c * t:(c + 1) * t, j]
                vl[slot] = t
            out = pool.process_shard(s, x, valid_lens=vl)
            np.asarray(out["outlier"])  # host fetch = consume point
    return moved


def bench_one(backend: str, shards: int, *, n_streams: int,
              chunks: int, t: int, buckets, fmt, interpret,
              shape: str = "uniform", reps: int = 2) -> dict:
    rng = np.random.default_rng(0)
    data = rng.normal(size=(chunks * t, n_streams)).astype(np.float32)
    best = None
    moved = 0
    for _ in range(reps):
        # shards=1 is the reference row and still a ShardedPool: the
        # scaling ratios isolate the fan-out, not the wrapper overhead
        pool = ShardedPool(backend, shards=shards, buckets=buckets,
                           fmt=fmt, interpret=interpret)
        rids = [f"s{i}" for i in range(n_streams)]
        for rid in rids:
            pool.acquire(rid)
        # untimed warmup chunk per shard: compiles out of the timing
        _serve_chunks(pool, rids, data[:t], t, storm=False)
        t0 = time.perf_counter()
        moved = _serve_chunks(pool, rids, data, t,
                              storm=(shape == "storm"))
        wall = time.perf_counter() - t0
        samples = chunks * t * n_streams
        row = {"backend": backend, "shards": shards, "shape": shape,
               "streams": n_streams, "samples": samples,
               "wall_s": wall, "samples_per_s": samples / wall,
               "migrations": moved}
        if best is None or row["samples_per_s"] > best["samples_per_s"]:
            best = row
    return best


def run(backends, shard_counts, *, n_streams, chunks, t, buckets,
        wl=32, fl=20, interpret=None, reps=2):
    fmt = QFormat(wl, fl)
    rows = []
    for backend in backends:
        base = None
        for shards in shard_counts:
            row = bench_one(backend, shards, n_streams=n_streams,
                            chunks=chunks, t=t, buckets=buckets,
                            fmt=fmt, interpret=interpret, reps=reps)
            if shards == 1:
                base = row["samples_per_s"]
            if base:
                row["scaling_efficiency"] = row["samples_per_s"] / base
            rows.append(row)
        # migration storm at the widest shard count: every chunk moves
        # one stream — the worst rebalancer cadence
        rows.append(bench_one(
            backend, max(shard_counts), n_streams=n_streams,
            chunks=chunks, t=t, buckets=buckets, fmt=fmt,
            interpret=interpret, shape="storm", reps=reps))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=32)
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--chunk-t", type=int, default=64)
    ap.add_argument("--shards", default="1,2,4",
                    help="comma-separated shard counts")
    ap.add_argument("--backends", default=",".join(list_backends()))
    ap.add_argument("--buckets", default="8,16,32,64")
    ap.add_argument("--wl", type=int, default=32)
    ap.add_argument("--fl", type=int, default=20)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + interpret mode (CI perf gate)")
    args = ap.parse_args(argv)

    if args.smoke:
        backends = ["scan"]
        shard_counts = (1, 2, 4)
        # single-bucket-reachable sizing: storm migrations stay inside
        # the 4-slot bucket, so the gated row measures migration's
        # host-sync cost, not bucket-resize recompiles (too noisy for
        # the 25% gate)
        n_streams, chunks, t, buckets = 8, 64, 32, (4, 8)
        interpret, reps = True, 3
    else:
        backends = [b for b in args.backends.split(",") if b]
        shard_counts = tuple(int(s) for s in args.shards.split(","))
        n_streams, chunks, t = args.streams, args.chunks, args.chunk_t
        buckets = tuple(int(s) for s in args.buckets.split(","))
        interpret, reps = None, 2

    rows = run(backends, shard_counts, n_streams=n_streams,
               chunks=chunks, t=t, buckets=buckets, wl=args.wl,
               fl=args.fl, interpret=interpret, reps=reps)
    doc = {"bench": "sharded_scaling", "smoke": bool(args.smoke),
           "rows": rows}
    text = json.dumps(doc, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return doc


if __name__ == "__main__":
    main()
