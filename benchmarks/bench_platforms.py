"""Table 5 analog: platform comparison (speedup over the software loop).

The paper compares its FPGA (138 ns/sample) against Python on three
hosts (435 ms, 39.2 ms, 23.1 ms *per sample*). We reproduce the
comparison shape on this host: the plain Python loop is the software
baseline, and each accelerated form gets a speedup column. The TPU
kernel's projected row uses the roofline bound from the dry-run machinery
(VPU-limited streaming, see EXPERIMENTS.md §Perf/TEDA) since no TPU is
attached here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scan import teda_scan
from repro.core.teda import teda_numpy_loop, teda_stream


def run(t_len: int = 8192):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(t_len, 2)).astype(np.float32)
    xj = jnp.asarray(x)

    t0 = time.perf_counter()
    teda_numpy_loop(x, 3.0)
    base = time.perf_counter() - t0

    rows = [("python_loop", base, 1.0)]
    for name, fn in [
        ("jax_lax_scan", jax.jit(lambda v: teda_stream(v, 3.0)[1].ecc)),
        ("jax_assoc_scan", jax.jit(lambda v: teda_scan(v, 3.0)[1].ecc)),
    ]:
        jax.block_until_ready(fn(xj))  # compile
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(xj))
            ts.append(time.perf_counter() - t0)
        w = float(np.median(ts))
        rows.append((name, w, base / w))

    # projected TPU row: C channels * 8 sublanes retire per VPU cycle at
    # ~940 MHz; TEDA is ~40 flops/sample -> VPU-bound estimate. Kept
    # clearly labeled as a projection, not a measurement.
    vpu_lanes = 8 * 128
    cycles_per_sample = 40 / 4  # ~4 f32 ALUs deep per lane-cycle
    proj = cycles_per_sample / (vpu_lanes * 0.94e9) * t_len
    rows.append(("tpu_v5e_projected", proj, base / proj))
    return [{"name": n, "wall_s": w, "speedup_vs_python": s,
             "per_sample_ns": w / t_len * 1e9} for n, w, s in rows]


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"platforms/{r['name']},{r['wall_s'] * 1e6:.1f},"
              f"speedup={r['speedup_vs_python']:.1f}x|"
              f"{r['per_sample_ns']:.1f}ns_per_sample")


if __name__ == "__main__":
    main()
