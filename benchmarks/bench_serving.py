"""Continuous-batching serving bench: offered load x backend.

Measures the serving layer the way Choudhary et al. (arXiv 1710.04735)
measure detectors — runtime as a first-class quantity next to efficacy:
tenant streams (history replayed as chunked prefill + a live decode
trickle) are offered to `launch.serve.serve_streams` at a fixed arrival
rate, and the gateway's sustained requests/s, samples/s, per-chunk
latency percentiles, queue waits and backpressure events are recorded
per backend.

Three load shapes per backend x offered load:

  * "uniform" — every tenant has the same history/live split;
  * "mixed"   — alternating prefill-heavy tenants (double-length
    history with an odd remainder tail, no live feed; admission class
    "bulk") and decode-phase tenants (near-empty history,
    double-length live feed; class "latency").  This is the shape the
    fused ragged (chunk_t, C) program exists for — both kinds of slot
    retire their own sample count in one call (ISSUE 4) — and now
    also the weighted-admission shape: bulk prefills admit at 1/4 the
    latency class's weight (ISSUE 5), with per-class queue waits in
    the row;
  * "decode"  — every tenant is decode-phase (tiny history, long live
    trickle), so after the first ticks every call retires <= 1 sample
    per slot: the adaptive-chunk fast path (ISSUE 5), where ticks ride
    the short cached (decode_t, C) program instead of the full chunk.
    The row's `short_ticks` counts those; `samples_per_s` on this row
    is what the CI regression gate guards for the fast path.

Emits a JSON table (one row per backend x offered load x shape); each
row carries `vs_paper_fpga` — its samples/s as a fraction of the
paper's 7.2 MSPS FPGA line (Table 5), the north-star ratio — and
embeds a `metrics` summary of the run's `repro.obs` registry
snapshot (counters/gauges verbatim, histograms as count/sum/p50/p95)
— the evidence trail `check_regression.py --explain` cites.  With
`--trace PATH` every run records into one shared `TickTracer` and the
Chrome trace-event JSON lands at PATH (open in Perfetto / about:tracing).

    PYTHONPATH=src python benchmarks/bench_serving.py
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
        --trace trace.json                               # CI: tiny
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.engine import list_backends
from repro.fixedpoint import QFormat
from repro.launch.serve import serve_streams
from repro.obs import TickTracer


CLASS_WEIGHTS = {"latency": 4.0, "bulk": 1.0}
PAPER_FPGA_MSPS = 7.2  # Table 5, sustained MSPS of the FPGA pipeline


def summarize_snapshot(snap: dict) -> dict:
    """Compact a registry snapshot for embedding in a bench row:
    counters/gauges keep every series, histograms drop the bucket
    vectors (count/sum/p50/p95 stay)."""
    out = {}
    for name, fam in snap.items():
        samples = []
        for s in fam["samples"]:
            if fam["type"] == "histogram":
                samples.append({k: s[k] for k in
                                ("labels", "count", "sum", "p50", "p95")})
            else:
                samples.append(s)
        out[name] = {"type": fam["type"], "samples": samples}
    return out


def make_streams(n: int, history: int, live: int, seed: int = 0,
                 shape: str = "uniform"):
    """Synthetic tenant mix: drifting means, per-tenant sensitivity,
    an anomaly burst on every third stream.  `shape="mixed"` alternates
    prefill-heavy ("bulk") and decode-phase ("latency") tenants;
    `shape="decode"` makes every tenant decode-phase (see module
    docs)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        cls = "default"
        if shape == "mixed" and i % 2 == 0:
            h_i, l_i = 2 * history + 3, 0     # prefill-heavy, ragged tail
            cls = "bulk"
        elif shape == "mixed":
            h_i, l_i = 3, 2 * live            # decode-phase
            cls = "latency"
        elif shape == "decode":
            h_i, l_i = 2, 2 * live            # decode trickle only
        else:
            h_i, l_i = history, live
        h = rng.normal(loc=i * 0.1, size=(h_i,)).astype(np.float32)
        lv = rng.normal(loc=i * 0.1, size=(l_i,)).astype(np.float32)
        if l_i and i % 3 == 0:
            lv[l_i // 2] += 15.0
        out.append((f"tenant-{i}", h, lv, 2.0 + (i % 3), cls))
    return out


def bench_one(backend: str, offered_load: int, *, n_requests: int,
              history: int, live: int, chunk_t: int, decode_t: int,
              buckets, queue_limit: int, fmt: QFormat, interpret,
              shape: str = "uniform", reps: int = 2,
              tracer=None) -> dict:
    # each rep builds a fresh scheduler (compiles included); report the
    # best rep so the row reflects the machine, not one-off jitter
    runs = [serve_streams(
        make_streams(n_requests, history, live, shape=shape),
        backend=backend, buckets=buckets, chunk_t=chunk_t,
        decode_t=decode_t, fmt=fmt, interpret=interpret,
        queue_limit=queue_limit, class_weights=dict(CLASS_WEIGHTS),
        arrivals_per_tick=offered_load, measure_latency=True,
        tracer=tracer)
        for _ in range(reps)]
    res = max(runs, key=lambda r: r["samples_per_s"])
    lat = res["chunk_latency"]
    classes = {
        cls: {"completed": c.get("completed", 0),
              "queue_wait_ticks_p95": c.get("queue_wait_ticks_p95", 0.0),
              "latency_ticks_p95": c.get("latency_ticks_p95", 0.0)}
        for cls, c in res["classes"].items()}
    return {
        "backend": backend,
        "offered_load": offered_load,
        "shape": shape,
        "decode_t": decode_t,
        "requests": res["requests"],
        "samples": res["samples"],
        "wall_s": res["wall_s"],
        "ticks": res["ticks"],
        "requests_per_s": res["requests_per_s"],
        "samples_per_s": res["samples_per_s"],
        "vs_paper_fpga": res["samples_per_s"] / 1e6 / PAPER_FPGA_MSPS,
        "chunk_lat_p50_ms": lat.get("p50_ms", 0.0),
        "chunk_lat_p95_ms": lat.get("p95_ms", 0.0),
        "queue_wait_ticks_p95": res["queue_wait_ticks_p95"],
        "rejected_submits": res["rejected_submits"],
        "short_ticks": res["short_ticks"],
        "programs": len(res["programs"]),
        "classes": classes,
        "pool_resizes": res["pool"]["resizes"],
        "flagged": len(res["flagged"]),
        "metrics": summarize_snapshot(res["metrics"]),
    }


def run(backends, loads, *, n_requests, history, live, chunk_t, buckets,
        queue_limit, decode_t=1, wl=32, fl=20, interpret=None, reps=2,
        shapes=("uniform", "mixed", "decode"), tracer=None):
    fmt = QFormat(wl, fl)
    rows = []
    for backend in backends:
        for load in loads:
            for shape in shapes:
                rows.append(bench_one(
                    backend, load, n_requests=n_requests,
                    history=history, live=live, chunk_t=chunk_t,
                    decode_t=decode_t, buckets=buckets,
                    queue_limit=queue_limit, fmt=fmt,
                    interpret=interpret, shape=shape, reps=reps,
                    tracer=tracer))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--history", type=int, default=1024)
    ap.add_argument("--live", type=int, default=128)
    ap.add_argument("--chunk-t", type=int, default=128)
    ap.add_argument("--decode-t", type=int, default=1,
                    help="short program length for decode-only ticks")
    ap.add_argument("--loads", default="2,8,32",
                    help="comma-separated arrivals per tick")
    ap.add_argument("--shapes", default="uniform,mixed,decode",
                    help="comma-separated load shapes "
                         "(uniform, mixed, decode)")
    ap.add_argument("--backends", default=",".join(list_backends()))
    ap.add_argument("--buckets", default="8,16,32,64")
    ap.add_argument("--queue-limit", type=int, default=16)
    ap.add_argument("--wl", type=int, default=32)
    ap.add_argument("--fl", type=int, default=20)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record every run into one TickTracer and "
                         "dump Chrome trace-event JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + interpret mode (CI perf gate)")
    args = ap.parse_args(argv)

    if args.smoke:
        n_requests, history, live, chunk_t = 6, 24, 6, 8
        loads, buckets, queue_limit = [2, 6], (4, 8), 4
        shapes, interpret = ("uniform", "mixed", "decode"), True
        decode_t = 1
    else:
        n_requests, history = args.requests, args.history
        live, chunk_t = args.live, args.chunk_t
        decode_t = args.decode_t
        loads = [int(s) for s in args.loads.split(",")]
        shapes = tuple(s for s in args.shapes.split(",") if s)
        buckets = tuple(int(s) for s in args.buckets.split(","))
        queue_limit = args.queue_limit
        interpret = None
    backends = [b for b in args.backends.split(",") if b]
    tracer = TickTracer() if args.trace else None

    rows = run(backends, loads, n_requests=n_requests, history=history,
               live=live, chunk_t=chunk_t, decode_t=decode_t,
               buckets=buckets, queue_limit=queue_limit, wl=args.wl,
               fl=args.fl, interpret=interpret, shapes=shapes,
               tracer=tracer)
    doc = {"bench": "serving_throughput", "smoke": bool(args.smoke),
           "paper_fpga_msps": PAPER_FPGA_MSPS, "rows": rows}
    text = json.dumps(doc, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if tracer is not None:
        tracer.dump(args.trace)
        print(f"[bench_serving] wrote {len(tracer)} trace events "
              f"({tracer.dropped} dropped) to {args.trace}")
    return doc


if __name__ == "__main__":
    main()
