"""Benchmark harness: one function per paper table.

CSV benchmarks print ``name,us_per_call,derived``. Tables:
  Table 2 / Figs 6-7  -> bench_detection  (fault detection validation)
  Table 3             -> bench_occupation (graph/VMEM occupation)
  Table 4             -> bench_throughput (processing time / SPS)
  Table 5             -> bench_platforms  (speedup vs software loop)
  Bit-accurate sim    -> bench_bitaccurate (Q-format word-length sweep)

JSON benchmarks (the Table-5 serving analogs) emit a samples/s table
that `check_regression.py` gates in CI:
  engine      -> bench_engine      (StreamEngine samples/s vs chunk x backend)
  serving     -> bench_serving     (continuous batching vs offered load)
  kernel_grid -> bench_kernel_grid (block_c x block_t x output contract
                                    at wide C — the 7.2 MSPS push)
  ensemble    -> bench_ensemble    (fused K-detector kernel vs the
                                    single-detector engine: the
                                    composability overhead)

Their output is validated here — empty or malformed rows exit nonzero,
so the CI perf gate can never silently pass on a benchmark that ran
nothing.  ``--only NAME`` (a name, or a comma-separated list of names)
runs a subset; unknown names exit nonzero listing the valid ones.
``--smoke`` and ``--out-dir`` forward to the JSON benchmarks.

``--only roofline`` emits the *analytic* TEDA-kernel roofline
(``roofline.py --teda``): no samples/s measurement, so it gets its own
structural validation here instead of ``validate_doc``.  The
measured-dry-run §Roofline tables (EXPERIMENTS.md) are still produced
by ``python -m repro.launch.dryrun`` + ``benchmarks/roofline.py`` (they
need the 512-device environment and are cached under experiments/).
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import traceback

# make sibling bench modules importable however run.py is invoked
# (python benchmarks/run.py, python -m benchmarks.run, from CI)
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

CSV_BENCHES = ("detection", "occupation", "throughput", "platforms",
               "bitaccurate")
JSON_BENCHES = ("engine", "serving", "kernel_grid", "ensemble",
                "sharded")
ANALYTIC_BENCHES = ("roofline",)


def _run_csv(name: str) -> bool:
    import importlib

    # import inside the runner: one broken benchmark (or its deps)
    # must not keep the others from running
    try:
        mod = importlib.import_module(f"bench_{name}")
        mod.main()
        sys.stdout.flush()
        return True
    except Exception:
        traceback.print_exc()
        return False


def _run_json(name: str, smoke: bool, out_dir) -> bool:
    import importlib

    from check_regression import MalformedBench, validate_doc

    argv = []
    if smoke:
        argv.append("--smoke")
    if out_dir is not None:
        out_dir = pathlib.Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = "_smoke" if smoke else ""
        argv += ["--out", str(out_dir / f"bench_{name}{suffix}.json")]
    try:
        mod = importlib.import_module(f"bench_{name}")
        doc = mod.main(argv)
        validate_doc(doc, f"bench_{name}")
        sys.stdout.flush()
        return True
    except MalformedBench as e:
        print(f"bench_{name}: malformed output: {e}", file=sys.stderr)
        return False
    except Exception:
        traceback.print_exc()
        return False


def _run_roofline(smoke: bool, out_dir) -> bool:
    """Analytic TEDA roofline: rows carry ceilings, not measurements,
    so validate_doc (which demands samples_per_s) does not apply —
    check the structure that downstream readers rely on instead."""
    import importlib

    argv = ["--teda"]
    if smoke:
        argv.append("--smoke")
    if out_dir is not None:
        out_dir = pathlib.Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = "_smoke" if smoke else ""
        argv += ["--out", str(out_dir / f"roofline_teda{suffix}.json")]
    try:
        mod = importlib.import_module("roofline")
        doc = mod.main(argv)
        rows = doc.get("rows") or []
        if not rows:
            raise ValueError("no rows")
        for r in rows:
            ceiling = r.get("ceiling_msps")
            if not (isinstance(ceiling, (int, float)) and ceiling > 0):
                raise ValueError(f"bad ceiling_msps in row {r!r}")
            if not all(k in r for k in ("kernel", "outputs",
                                        "hbm_bytes_per_sample",
                                        "vmem_tile_bytes", "vmem_fits",
                                        "vs_paper_fpga")):
                raise ValueError(f"missing keys in row {r!r}")
        sys.stdout.flush()
        return True
    except Exception:
        traceback.print_exc()
        return False


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a subset: a benchmark name or a "
                         "comma-separated list of names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the JSON benchmarks (CI)")
    ap.add_argument("--out-dir", default=None,
                    help="write JSON benchmark output here")
    ap.add_argument("--all", action="store_true",
                    help="also run the JSON benchmarks at full scale "
                         "(default: CSV benches only; JSON benches are "
                         "heavy off-TPU unless --smoke)")
    args = ap.parse_args(argv)

    valid = CSV_BENCHES + JSON_BENCHES + ANALYTIC_BENCHES
    if args.only:
        # a name or a comma-separated list; unknown names must exit
        # nonzero *listing the valid set* — argparse choices= would,
        # but could not take the list form
        names = tuple(n.strip() for n in args.only.split(",") if n.strip())
        unknown = [n for n in names if n not in valid]
        if unknown or not names:
            raise SystemExit(
                f"--only: unknown benchmark(s) {unknown or args.only!r}; "
                f"valid names: {', '.join(valid)}")
    else:
        names = CSV_BENCHES + (JSON_BENCHES if args.all else ())
    failed = []
    for name in names:
        if name in ANALYTIC_BENCHES:
            ok = _run_roofline(args.smoke, args.out_dir)
        elif name in JSON_BENCHES:
            ok = _run_json(name, args.smoke, args.out_dir)
        else:
            ok = _run_csv(name)
        if not ok:
            failed.append(f"bench_{name}")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
