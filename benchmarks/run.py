"""Benchmark harness: one function per paper table.

Prints ``name,us_per_call,derived`` CSV. Tables:
  Table 2 / Figs 6-7  -> bench_detection  (fault detection validation)
  Table 3             -> bench_occupation (graph/VMEM occupation)
  Table 4             -> bench_throughput (processing time / SPS)
  Table 5             -> bench_platforms  (speedup vs software loop)

The roofline/dry-run tables (EXPERIMENTS.md §Roofline) are produced by
``python -m repro.launch.dryrun`` + ``benchmarks/roofline.py`` (they need
the 512-device environment and are cached under experiments/).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_detection, bench_occupation,
                            bench_platforms, bench_throughput)
    failed = []
    for mod in (bench_detection, bench_occupation, bench_throughput,
                bench_platforms):
        try:
            mod.main()
            sys.stdout.flush()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
