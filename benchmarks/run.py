"""Benchmark harness: one function per paper table.

Prints ``name,us_per_call,derived`` CSV. Tables:
  Table 2 / Figs 6-7  -> bench_detection  (fault detection validation)
  Table 3             -> bench_occupation (graph/VMEM occupation)
  Table 4             -> bench_throughput (processing time / SPS)
  Table 5             -> bench_platforms  (speedup vs software loop)
  Bit-accurate sim    -> bench_bitaccurate (Q-format word-length sweep)

``bench_engine`` (StreamEngine samples/s vs chunk size x backend, the
Table-5 serving analog) emits JSON rather than this CSV — run it
standalone; CI runs ``bench_engine.py --smoke`` as its rot guard.

The roofline/dry-run tables (EXPERIMENTS.md §Roofline) are produced by
``python -m repro.launch.dryrun`` + ``benchmarks/roofline.py`` (they need
the 512-device environment and are cached under experiments/).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    import importlib

    failed = []
    for name in ("bench_detection", "bench_occupation",
                 "bench_throughput", "bench_platforms",
                 "bench_bitaccurate"):
        # import inside the loop: one broken benchmark (or its deps)
        # must not keep the others from running
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            sys.stdout.flush()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
