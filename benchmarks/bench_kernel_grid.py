"""Kernel-grid throughput: block_c x block_t x backend at wide C.

The raw-speed push toward the paper's 7.2 MSPS line (Table 5) happens
at the kernel grid: this benchmark drives `StreamEngine.process` at a
*wide* channel capacity — where the paper's occupation-vs-throughput
argument actually bites — and sweeps the two grid knobs plus the
output contract:

  * `block_c`   — channel-block width of the 2-D (channel-block, time)
                  grid; 0 = one strip spanning all lanes (the 1-D-grid
                  behavior).  On multi-core TPUs strips scale across
                  cores; in CPU interpret mode extra strips only add
                  grid steps, so the committed smoke numbers are the
                  *honest* floor, not the hardware story.
  * `block_t`   — time-block (sublane) depth of each grid step.
  * `outputs`   — "verdict" is the serving hot path (slim ecc+flag
                  kernel outputs, no host-side threshold re-derivation);
                  "full" is the complete (T, C) trajectory contract.
                  The verdict/full ratio (`speedups_verdict_vs_full`)
                  is a slim-contract diagnostic only — the PR 7 speedup
                  evidence is the committed baseline rows themselves:
                  the divider rescheduling in the Q kernel (see
                  kernels/qdiv.py) lifted *both* contracts well past
                  the PR 6 baseline at the same smoke config (measured
                  ~2.7 MSPS at PR 6 vs ~8 MSPS single-strip / ~18 MSPS
                  block_c=128 here, same machine, back to back), gated
                  per-row by check_regression.py.

Rows carry samples/s + `vs_paper_fpga` (the 7.2 MSPS ratio), identified
by (backend, channels, chunk_t, block_t, block_c, outputs).

    PYTHONPATH=src python benchmarks/bench_kernel_grid.py
    PYTHONPATH=src python benchmarks/bench_kernel_grid.py --smoke  # CI
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.engine import StreamEngine
from repro.fixedpoint import QFormat

PAPER_FPGA_MSPS = 7.2  # Table 5, sustained MSPS of the pipeline


def bench_one(backend: str, channels: int, chunk_t: int, total_t: int,
              *, fmt: QFormat, block_t: int, block_c: int, outputs: str,
              interpret, reps: int = 3):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(total_t, channels)).astype(np.float32)
    chunks = [x[i:i + chunk_t] for i in range(0, total_t, chunk_t)]
    opts = {}
    if backend == "pallas-q":
        opts["verdict"] = outputs == "verdict"
    eng = StreamEngine(channels, backend, m=3.0, fmt=fmt,
                       block_t=block_t, block_c=block_c or None,
                       interpret=interpret, **opts)

    def run():
        eng.reset()  # keeps the jit cache warm across reps
        out = None
        for c in chunks:
            out = eng.process(c)
        jax.block_until_ready(out["ecc"])

    t0 = time.perf_counter()
    run()  # compile + warm caches
    compile_s = time.perf_counter() - t0

    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        walls.append(time.perf_counter() - t0)
    wall = float(np.median(walls))
    samples = total_t * channels
    assert int(eng.samples_seen[0]) == total_t
    assert len(eng.program_shapes) == 1, "one grid program per config"
    return {
        "backend": backend,
        "channels": channels,
        "chunk_t": chunk_t,
        "block_t": block_t,
        "block_c": block_c,
        "outputs": outputs,
        "samples": samples,
        "wall_s": wall,
        "samples_per_s": samples / wall,
        "throughput_msps": samples / wall / 1e6,
        "vs_paper_fpga": samples / wall / 1e6 / PAPER_FPGA_MSPS,
        "compile_s": compile_s,
    }


def _configs(backends, block_cs):
    """(backend, block_c, outputs) sweep: the Q path A/Bs its output
    contract (full == the PR 6 engine path), the float path is already
    verdict-only in the engine."""
    for backend in backends:
        for bc in block_cs:
            if backend == "pallas-q":
                yield backend, bc, "full"
                yield backend, bc, "verdict"
            else:
                yield backend, bc, "verdict"


def run(channels: int, chunk_t: int, total_t: int, backends, block_cs,
        *, wl: int = 32, fl: int = 20, block_t: int = 256,
        interpret=None, reps: int = 3):
    fmt = QFormat(wl, fl)
    bt = min(block_t, max(8, chunk_t))
    rows = []
    for backend, bc, outputs in _configs(backends, block_cs):
        rows.append(bench_one(backend, channels, chunk_t, total_t,
                              fmt=fmt, block_t=bt, block_c=bc,
                              outputs=outputs, interpret=interpret,
                              reps=reps))
    return rows


def _speedups(rows):
    """verdict/full samples-per-s ratio per (backend, block_c) pair —
    the committed hot-path-vs-PR-6 evidence."""
    full = {(r["backend"], r["block_c"]): r["samples_per_s"]
            for r in rows if r["outputs"] == "full"}
    out = {}
    for r in rows:
        key = (r["backend"], r["block_c"])
        if r["outputs"] == "verdict" and key in full:
            out[f"{key[0]}/block_c={key[1]}"] = (
                r["samples_per_s"] / full[key])
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", type=int, default=1024)
    ap.add_argument("--total-t", type=int, default=4096)
    ap.add_argument("--chunk-t", type=int, default=512)
    ap.add_argument("--block-t", type=int, default=256)
    ap.add_argument("--block-cs", default="0,128,256,512",
                    help="comma-separated channel-block widths "
                         "(0 = one strip)")
    ap.add_argument("--backends", default="pallas,pallas-q")
    ap.add_argument("--wl", type=int, default=32)
    ap.add_argument("--fl", type=int, default=20)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shapes: wide-C (256) but short streams, "
                         "interpret mode")
    args = ap.parse_args(argv)

    if args.smoke:
        # wide-C is the point of this bench (the acceptance row is a
        # C >= 256 pallas-q config), but streams stay short enough for
        # the CI runner; each timed interval is tens of ms so the
        # regression gate beats timer noise
        channels, total_t, chunk_t = 256, 512, 256
        block_cs, reps, interpret = [0, 128], 3, True
    else:
        channels, total_t = args.channels, args.total_t
        chunk_t = args.chunk_t
        block_cs = [int(s) for s in args.block_cs.split(",")]
        reps, interpret = args.reps, None
    backends = [b for b in args.backends.split(",") if b]

    rows = run(channels, chunk_t, total_t, backends, block_cs,
               wl=args.wl, fl=args.fl, block_t=args.block_t,
               interpret=interpret, reps=reps)
    doc = {"bench": "kernel_grid", "smoke": bool(args.smoke),
           "paper_fpga_msps": PAPER_FPGA_MSPS,
           "speedups_verdict_vs_full": _speedups(rows), "rows": rows}
    text = json.dumps(doc, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return doc


if __name__ == "__main__":
    main()
