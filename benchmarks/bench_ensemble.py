"""Fused detector-ensemble throughput: the composability overhead.

The fSEAD line of work composes several streaming detectors behind one
serving interface; the cost question is what the fused K-detector
kernel pays over a single-detector engine.  This benchmark measures
`StreamEngine(backend="ensemble")` samples/s for each ensemble member
alone (K=1) and for the fused moment ensemble (K=3, majority vote) on
the same stream, and reports the K=3 overhead factor — single-detector
samples/s over fused samples/s (1.0 = free composability; the CI gate
asserts it stays under `MAX_K3_OVERHEAD`, since the fused kernel
shares the prefix-sum fabric across members and should never cost
anywhere near K times a single detector).  The non-moment members
("hst", the Q-format "teda-q" lane) and the full K=5 ensemble get
informational rows — their opaque-region lanes run sequential row
loops, so they price differently and sit outside the K=3 gate.

Every row carries the `window` and `state_rows` (the ensemble
`StateSpec`'s per-channel aux rows) ID columns, so baselines keyed on
an old state layout never silently compare against a new one.

Emits a JSON table (one row per detector selection x chunk size):

    PYTHONPATH=src python benchmarks/bench_ensemble.py
    PYTHONPATH=src python benchmarks/bench_ensemble.py --smoke  # CI: tiny
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.detectors import DEFAULT_DETECTORS, DEFAULT_WINDOW, ensemble_spec
from repro.engine import StreamEngine
from repro.fixedpoint import QFormat

#: detector selections beyond the gated K=3 moment ensemble: the
#: non-moment members alone, then every member fused (informational)
EXTRA_SELECTIONS = (("hst",), ("teda-q",),
                    DEFAULT_DETECTORS + ("hst", "teda-q"))
#: the Q-format of the "teda-q" member's datapath in these rows
BENCH_FMT = QFormat(32, 20)

# acceptance ceiling for the fused-vs-single overhead factor: the K=3
# ensemble must stay cheaper than 2.5x a single detector per sample
MAX_K3_OVERHEAD = 2.5


def bench_one(detectors, channels: int, chunk_t: int, total_t: int, *,
              vote: str = "majority", block_t: int, interpret,
              reps: int = 3):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(total_t, channels)).astype(np.float32)
    chunks = [x[i:i + chunk_t] for i in range(0, total_t, chunk_t)]
    detectors = tuple(detectors)
    fmt = BENCH_FMT if "teda-q" in detectors else None
    eng = StreamEngine(channels, "ensemble", m=3.0,
                       detectors=detectors, vote=vote, fmt=fmt,
                       block_t=block_t, interpret=interpret)

    def run():
        eng.reset()  # mid-flight slot recycle; keeps the jit cache warm
        out = None
        for c in chunks:
            out = eng.process(c)
        jax.block_until_ready(out["outlier"])

    t0 = time.perf_counter()
    run()  # compile + warm caches
    compile_s = time.perf_counter() - t0

    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        walls.append(time.perf_counter() - t0)
    # best-of-N: the least-interfered run estimates the kernel's cost;
    # medians under host load spikes flake the 25% regression gate
    wall = float(np.min(walls))
    samples = total_t * channels
    assert int(eng.samples_seen[0]) == total_t
    return {
        "backend": "ensemble",
        "detector": "+".join(detectors),
        "ensemble_k": len(detectors),
        "vote": vote,
        "window": DEFAULT_WINDOW,
        "state_rows": ensemble_spec(detectors, DEFAULT_WINDOW).rows,
        "chunk_t": chunk_t,
        "channels": channels,
        "samples": samples,
        "wall_s": wall,
        "samples_per_s": samples / wall,
        "compile_s": compile_s,
    }


def run(channels: int, chunk_sizes, total_t: int, *, block_t: int = 256,
        interpret=None, reps: int = 3):
    rows = []
    for chunk_t in chunk_sizes:
        bt = min(block_t, max(8, chunk_t))
        singles = []
        for det in DEFAULT_DETECTORS:
            row = bench_one((det,), channels, chunk_t, total_t,
                            block_t=bt, interpret=interpret, reps=reps)
            singles.append(row["samples_per_s"])
            rows.append(row)
        fused = bench_one(DEFAULT_DETECTORS, channels, chunk_t, total_t,
                          block_t=bt, interpret=interpret, reps=reps)
        # overhead vs the mean single detector: one noisy single-run
        # outlier must not swing the acceptance ratio
        fused["overhead_vs_single"] = (
            float(np.mean(singles)) / fused["samples_per_s"])
        rows.append(fused)
        # informational rows: the opaque-region members and the full
        # fused ensemble (their sequential lanes sit outside the K=3
        # composability gate)
        for sel in EXTRA_SELECTIONS:
            rows.append(bench_one(sel, channels, chunk_t, total_t,
                                  block_t=bt, interpret=interpret,
                                  reps=reps))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", type=int, default=128)
    ap.add_argument("--total-t", type=int, default=16384)
    ap.add_argument("--chunks", default="256,1024",
                    help="comma-separated chunk lengths")
    ap.add_argument("--block-t", type=int, default=256)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + interpret mode (CI rot guard)")
    args = ap.parse_args(argv)

    if args.smoke:
        # big enough that each timed interval is tens of ms (best of
        # 5 reps): the regression gate compares samples/s against a
        # committed baseline, so the measurement must beat timer noise
        channels, total_t, chunks, reps = 8, 2048, [32], 5
        interpret = True
    else:
        channels, total_t, reps = args.channels, args.total_t, args.reps
        chunks = [int(s) for s in args.chunks.split(",")]
        interpret = None

    rows = run(channels, chunks, total_t, block_t=args.block_t,
               interpret=interpret, reps=reps)
    worst = max(r["overhead_vs_single"] for r in rows
                if "overhead_vs_single" in r)
    doc = {"bench": "ensemble_throughput", "smoke": bool(args.smoke),
           "max_k3_overhead": MAX_K3_OVERHEAD,
           "worst_k3_overhead": worst, "rows": rows}
    text = json.dumps(doc, indent=2)
    print(text)
    if worst >= MAX_K3_OVERHEAD:
        raise SystemExit(
            f"fused K={len(DEFAULT_DETECTORS)} ensemble overhead "
            f"x{worst:.2f} vs single detector exceeds the "
            f"x{MAX_K3_OVERHEAD} acceptance ceiling")
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return doc


if __name__ == "__main__":
    main()
