"""StreamEngine sustained throughput: samples/s vs chunk size x backend.

The paper's Table 5 reports 7.2 MSPS sustained for the FPGA pipeline
(t_c = 138 ns).  This benchmark measures the engine analog: a long
(T, C) stream fed through `StreamEngine.process` in fixed-size chunks —
the serving pattern, where chunk size trades verdict latency against
dispatch overhead — for every registered backend.

Emits a JSON table (one row per backend x chunk size):

    PYTHONPATH=src python benchmarks/bench_engine.py
    PYTHONPATH=src python benchmarks/bench_engine.py --smoke   # CI: tiny
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.engine import StreamEngine, list_backends
from repro.fixedpoint import QFormat

PAPER_FPGA_MSPS = 7.2  # Table 5, sustained MSPS of the pipeline


def bench_one(backend: str, channels: int, chunk_t: int, total_t: int,
              *, fmt: QFormat, block_t: int, interpret, reps: int = 3):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(total_t, channels)).astype(np.float32)
    chunks = [x[i:i + chunk_t] for i in range(0, total_t, chunk_t)]
    eng = StreamEngine(channels, backend, m=3.0, fmt=fmt,
                       block_t=block_t, interpret=interpret)

    def run():
        eng.reset()  # mid-flight slot recycle; keeps the jit cache warm
        out = None
        for c in chunks:
            out = eng.process(c)
        jax.block_until_ready(out["ecc"])

    t0 = time.perf_counter()
    run()  # compile + warm caches
    compile_s = time.perf_counter() - t0

    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        walls.append(time.perf_counter() - t0)
    wall = float(np.median(walls))
    samples = total_t * channels
    assert int(eng.samples_seen[0]) == total_t
    return {
        "backend": backend,
        "chunk_t": chunk_t,
        "channels": channels,
        "samples": samples,
        "wall_s": wall,
        "samples_per_s": samples / wall,
        "throughput_msps": samples / wall / 1e6,
        "vs_paper_fpga": samples / wall / 1e6 / PAPER_FPGA_MSPS,
        "compile_s": compile_s,
    }


def run(channels: int, chunk_sizes, total_t: int, backends, *,
        wl: int = 32, fl: int = 20, block_t: int = 256, interpret=None,
        reps: int = 3):
    fmt = QFormat(wl, fl)
    rows = []
    for backend in backends:
        for chunk_t in chunk_sizes:
            bt = min(block_t, max(8, chunk_t))
            rows.append(bench_one(backend, channels, chunk_t, total_t,
                                  fmt=fmt, block_t=bt,
                                  interpret=interpret, reps=reps))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", type=int, default=128)
    ap.add_argument("--total-t", type=int, default=16384)
    ap.add_argument("--chunks", default="64,256,1024,4096",
                    help="comma-separated chunk lengths")
    ap.add_argument("--backends", default=",".join(list_backends()))
    ap.add_argument("--block-t", type=int, default=256)
    ap.add_argument("--wl", type=int, default=32)
    ap.add_argument("--fl", type=int, default=20)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + interpret mode (CI rot guard)")
    args = ap.parse_args(argv)

    if args.smoke:
        # big enough that each timed interval is tens of ms (median of
        # 3 reps): the CI regression gate compares samples/s against a
        # committed baseline, so the measurement must beat timer noise
        channels, total_t, chunks, reps = 8, 256, [16, 32], 3
        interpret = True
    else:
        channels, total_t, reps = args.channels, args.total_t, args.reps
        chunks = [int(s) for s in args.chunks.split(",")]
        interpret = None
    backends = [b for b in args.backends.split(",") if b]

    rows = run(channels, chunks, total_t, backends, wl=args.wl,
               fl=args.fl, block_t=args.block_t, interpret=interpret,
               reps=reps)
    doc = {"bench": "engine_throughput", "smoke": bool(args.smoke),
           "paper_fpga_msps": PAPER_FPGA_MSPS, "rows": rows}
    text = json.dumps(doc, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return doc


if __name__ == "__main__":
    main()
