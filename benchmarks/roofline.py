"""Assemble the §Roofline table from cached dry-run JSONs.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Prints a markdown table (used verbatim in EXPERIMENTS.md) and a CSV.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str):
    rows = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2 ** 30:.2f}"


def markdown(rows, mesh="single"):
    out = ["| arch | shape | acc | temp GiB/dev | compute s | memory s | "
           "collective s | bound | roofline frac | 6ND/HLO |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                       f"| SKIP | - | - |")
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('accum_steps', 1)} "
            f"| {fmt_bytes(r['memory']['temp_bytes'])} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['bottleneck']} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {r['useful_flop_ratio']:.2f} |")
    return "\n".join(out)


def csv(rows):
    out = ["arch,shape,mesh,devices,compute_s,memory_s,collective_s,"
           "bottleneck,roofline_fraction,useful_flop_ratio,temp_bytes"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},,,,,SKIP,,,")
            continue
        t = r["roofline"]
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['devices']},"
            f"{t['compute_s']:.6f},{t['memory_s']:.6f},"
            f"{t['collective_s']:.6f},{t['bottleneck']},"
            f"{t['roofline_fraction']:.4f},{r['useful_flop_ratio']:.3f},"
            f"{r['memory']['temp_bytes']}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--format", default="markdown",
                    choices=["markdown", "csv"])
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(args.dir)
    if not rows:
        print("no dry-run results yet; run python -m repro.launch.dryrun")
        return
    if args.format == "markdown":
        print(markdown(rows, args.mesh))
    else:
        print(csv(rows))


if __name__ == "__main__":
    main()
