"""Assemble the §Roofline table from cached dry-run JSONs.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Prints a markdown table (used verbatim in EXPERIMENTS.md) and a CSV.

With `--teda` the script instead emits an *analytic* roofline for the
TEDA Pallas kernels themselves (no measurement): per output contract it
models the HBM traffic per sample, the VMEM footprint of one
(block_t, block_c) grid step — including the Q kernel's two banked
recurrence scratch tiles — against the per-core VMEM budget, and the
memory-bound throughput ceiling at a nominal HBM bandwidth, expressed
both in MSPS and as a multiple of the paper's 7.2 MSPS FPGA line
(Table 5).  The TEDA recurrence does O(10) ALU ops per sample against
a 9-17 byte HBM footprint, so on any TPU the kernels sit far on the
memory-bound side of the roofline: the ceiling is bytes/sample * BW,
which is why the verdict contract (9 B/sample) is the serving hot path.

    PYTHONPATH=src python benchmarks/roofline.py --teda [--smoke] [--out f]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PAPER_FPGA_MSPS = 7.2  # Table 5, sustained MSPS of the FPGA pipeline
VMEM_BUDGET_BYTES = 16 * 2 ** 20  # ~16 MiB VMEM per TPU core
NOMINAL_HBM_GBPS = 819.0  # TPU v5e-class HBM bandwidth

# HBM bytes moved per stream sample, by (backend, output contract):
# every sample is read once (f32 / int32 Q-word); the contract decides
# what is written back.  The Q kernels flag with int8; the float full
# contract keeps its historical int32 flag.
_HBM_BYTES = {
    ("pallas", "full"): 4 + (4 + 4 + 4 + 4),    # x | mean,var,ecc,flag(i32)
    ("pallas", "verdict"): 4 + (4 + 1),         # x | ecc,flag(i8)
    ("pallas-q", "full"): 4 + (4 + 4 + 4 + 1),  # x | mean,var,ecc,flag(i8)
    ("pallas-q", "verdict"): 4 + (4 + 1),       # x | ecc,flag(i8)
}


def teda_vmem_bytes(backend: str, outputs: str, block_t: int,
                    block_c: int) -> int:
    """VMEM resident during one (block_t, block_c) grid step.

    Tiles: the x input plus the per-contract output tiles; the Q kernel
    additionally banks the mean/var recurrence rows in two scratch
    tiles so every divider runs as a whole-block pass.  Rows: vlen +
    3 init rows + 3 final rows + 2 carry scratch rows, all (1, block_c).
    """
    tile4 = block_t * block_c * 4
    tile1 = block_t * block_c
    row4 = block_c * 4
    if outputs == "full":
        out_tiles = 3 * tile4 + (tile4 if backend == "pallas" else tile1)
    else:
        out_tiles = tile4 + tile1
    scratch_tiles = 2 * tile4 if backend == "pallas-q" else 0
    return tile4 + out_tiles + scratch_tiles + 9 * row4


def teda_rows(block_ts, block_cs, bw_gbps: float):
    rows = []
    for backend in ("pallas", "pallas-q"):
        kernel = "teda_q_scan" if backend == "pallas-q" else "teda_scan"
        for outputs in ("full", "verdict"):
            bps = _HBM_BYTES[(backend, outputs)]
            ceiling_msps = bw_gbps * 1e9 / bps / 1e6
            for bt in block_ts:
                for bc in block_cs:
                    vmem = teda_vmem_bytes(backend, outputs, bt, bc)
                    rows.append({
                        "kernel": kernel,
                        "backend": backend,
                        "outputs": outputs,
                        "block_t": bt,
                        "block_c": bc,
                        "hbm_bytes_per_sample": bps,
                        "vmem_tile_bytes": vmem,
                        "vmem_budget_bytes": VMEM_BUDGET_BYTES,
                        "vmem_fits": vmem <= VMEM_BUDGET_BYTES,
                        "bound": "memory",
                        "ceiling_msps": ceiling_msps,
                        "vs_paper_fpga": ceiling_msps / PAPER_FPGA_MSPS,
                    })
    return rows


def teda_main(args):
    block_ts = [int(s) for s in args.block_ts.split(",")]
    block_cs = [int(s) for s in args.block_cs.split(",")]
    if args.smoke:
        block_ts, block_cs = block_ts[:2], block_cs[:2]
    rows = teda_rows(block_ts, block_cs, args.bw_gbps)
    doc = {"bench": "roofline_teda", "smoke": bool(args.smoke),
           "hbm_gbps": args.bw_gbps,
           "paper_fpga_msps": PAPER_FPGA_MSPS, "rows": rows}
    text = json.dumps(doc, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return doc


def load(dir_: str):
    rows = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2 ** 30:.2f}"


def markdown(rows, mesh="single"):
    out = ["| arch | shape | acc | temp GiB/dev | compute s | memory s | "
           "collective s | bound | roofline frac | 6ND/HLO |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                       f"| SKIP | - | - |")
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('accum_steps', 1)} "
            f"| {fmt_bytes(r['memory']['temp_bytes'])} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['bottleneck']} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {r['useful_flop_ratio']:.2f} |")
    return "\n".join(out)


def csv(rows):
    out = ["arch,shape,mesh,devices,compute_s,memory_s,collective_s,"
           "bottleneck,roofline_fraction,useful_flop_ratio,temp_bytes"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},,,,,SKIP,,,")
            continue
        t = r["roofline"]
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['devices']},"
            f"{t['compute_s']:.6f},{t['memory_s']:.6f},"
            f"{t['collective_s']:.6f},{t['bottleneck']},"
            f"{t['roofline_fraction']:.4f},{r['useful_flop_ratio']:.3f},"
            f"{r['memory']['temp_bytes']}")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--format", default="markdown",
                    choices=["markdown", "csv"])
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--teda", action="store_true",
                    help="analytic TEDA-kernel roofline (JSON) instead "
                         "of the dry-run table")
    ap.add_argument("--block-ts", default="256,128",
                    help="time-block depths for --teda")
    ap.add_argument("--block-cs", default="128,256,512,1024",
                    help="channel-block widths for --teda")
    ap.add_argument("--bw-gbps", type=float, default=NOMINAL_HBM_GBPS)
    ap.add_argument("--smoke", action="store_true",
                    help="--teda only: trim the tile sweep for CI")
    ap.add_argument("--out", default=None,
                    help="--teda only: write the JSON doc here")
    args = ap.parse_args(argv)
    if args.teda:
        return teda_main(args)
    rows = load(args.dir)
    if not rows:
        print("no dry-run results yet; run python -m repro.launch.dryrun")
        return
    if args.format == "markdown":
        print(markdown(rows, args.mesh))
    else:
        print(csv(rows))


if __name__ == "__main__":
    main()
