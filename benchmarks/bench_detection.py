"""Table 2 / Figures 6-7 analog: DAMADICS fault detection validation.

Runs TEDA (m = 3, threshold 5/k, exactly the paper's setting) over the
seven synthetic DAMADICS-like fault items and reports hit/latency/false
alarms for each — plus the eq-forms cross-check (lax.scan vs associative
scan vs Pallas kernel produce identical verdict sets).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.scan import teda_scan
from repro.core.teda import teda_stream
from repro.data.damadics import TABLE2, detection_report, make_benchmark


def run(window_slack: int = 20000):
    rows = []
    for item in range(len(TABLE2)):
        x, w = make_benchmark(item)
        # score only a window around the fault (keeps CPU runtime sane;
        # statistics carry from the window start like the paper's online
        # run — k restarts, conservative for detection)
        lo = max(0, w.start - window_slack)
        hi = min(len(x), w.stop + 2000)
        seg = jnp.asarray(x[lo:hi])
        _, out = teda_scan(seg, 3.0)
        shifted = type(w)(w.kind, w.start - lo, w.stop - lo)
        rep = detection_report(np.asarray(out.outlier), shifted)
        _, out_seq = teda_stream(seg, 3.0)
        agree = bool(
            (np.asarray(out.outlier) == np.asarray(out_seq.outlier)).all())
        rows.append({"item": item + 1, "fault": w.kind, **rep,
                     "forms_agree": agree})
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"detection/item{r['item']}_{r['fault']},0,"
              f"hit={int(r['hit'])}|latency={int(r['latency_samples'])}"
              f"|false_alarm_rate={r['false_alarm_rate']:.5f}"
              f"|forms_agree={int(r['forms_agree'])}")


if __name__ == "__main__":
    main()
