"""Inject the generated §Roofline table into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.finalize_experiments
"""
from __future__ import annotations

import re

from benchmarks.roofline import load, markdown

MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    rows = load("experiments/dryrun")
    if not rows:
        raise SystemExit("no dry-run results")
    n_ok = sum(1 for r in rows if not r.get("skipped"))
    n_skip = sum(1 for r in rows if r.get("skipped"))
    single = markdown(rows, "single")
    multi = markdown(rows, "multi")
    block = (f"{MARK}\n\n"
             f"Cells compiled: {n_ok} (+{n_skip} recorded skips). "
             f"`acc` = gradient-accumulation microbatches; `temp` from "
             f"`memory_analysis()` (per-device, must fit 16 GB with "
             f"args); `6ND/HLO` = useful-flop ratio.\n\n"
             f"### Single pod (16x16 = 256 chips)\n\n{single}\n\n"
             f"### Multi-pod (2x16x16 = 512 chips)\n\n{multi}\n")
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    pattern = re.compile(
        re.escape(MARK) + r".*?(?=\n## )", re.DOTALL)
    if pattern.search(text):
        text = pattern.sub(block + "\n", text)
    else:
        text = text.replace(MARK, block)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"injected {n_ok} cells (+{n_skip} skips)")


if __name__ == "__main__":
    main()
