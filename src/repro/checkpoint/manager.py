"""Checkpointing: atomic, async, keep-K, mesh-elastic restore.

Design goals for 1000+ node runs:
  * **atomic** — write to step_N.tmp, fsync, rename; a crash mid-save
    never corrupts the latest good checkpoint.
  * **async** — `save()` snapshots to host RAM synchronously (cheap) and
    writes in a background thread, overlapping the next train steps.
  * **elastic restore** — arrays are stored unsharded (npz) with a
    manifest of tree paths; `restore(..., shardings=...)` device_puts
    onto whatever mesh the *new* job has, so restarts may change pod
    count / mesh shape (re-sharding happens at load).
  * **keep-K** + a `latest` pointer file for the launcher's auto-resume.
  * guard/TEDA state and data-stream position are part of the state tree,
    so resume replays the exact stream (TokenStream is step-indexable).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

SEP = "|"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in leaves_p:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {tmpl.shape}")
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save --
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Snapshot now; write in background (unless async_save=False)."""
        self.wait()  # one in-flight save at a time
        host = _flatten(state)  # device->host copy happens here
        meta = {"step": int(step), "time": time.time(),
                "extra": extra or {}}
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: dict):
        try:
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
                f.write(os.path.basename(final))
            os.replace(os.path.join(self.dir, "latest.tmp"),
                       os.path.join(self.dir, "latest"))
            self._gc()
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Load into `template`'s structure; optionally place onto a new
        mesh via `shardings` (elastic restart)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(template, flat)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta
