"""AdamW with global-norm clipping, LR schedule, and TEDA-guard masking.

Optimizer state is a pytree congruent with params, so it inherits the
params' PartitionSpecs (ZeRO-1 flavor: FSDP-sharded params imply
FSDP-sharded m/v — no optimizer-state replication). `apply_updates`
takes a `skip` flag wired to the TEDAGuard verdict: a skipped step is a
no-op on params AND state (count included), which is what makes
guard-skipping equivalent to never having seen the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_dtype: str = "float32"  # bfloat16 => compressed grad accumulation
    m_dtype: str = "float32"     # bfloat16 => halve first-moment storage
    v_dtype: str = "float32"     # bfloat16 => halve second-moment storage


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def init(params, cfg: "AdamWConfig | None" = None) -> OptState:
    md = jnp.dtype(cfg.m_dtype) if cfg else jnp.float32
    vd = jnp.dtype(cfg.v_dtype) if cfg else jnp.float32
    return OptState(
        m=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, md), params),
        v=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, vd), params),
        count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree_util.tree_leaves(tree)))


def update(grads, state: OptState, params, cfg: AdamWConfig,
           skip: jnp.ndarray | bool = False
           ) -> Tuple[Any, OptState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)

    count = state.count + 1
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    lr = schedule(cfg, count)

    md, vd = jnp.dtype(cfg.m_dtype), jnp.dtype(cfg.v_dtype)
    new_m = jax.tree_util.tree_map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                      + (1 - cfg.b1) * g).astype(md), state.m, grads)
    new_v = jax.tree_util.tree_map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32)
                      + (1 - cfg.b2) * g * g).astype(vd), state.v, grads)

    def step_one(p, m, v):
        upd = (m.astype(jnp.float32) / b1c) / (
            jnp.sqrt(v.astype(jnp.float32) / b2c) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree_util.tree_map(step_one, params, new_m, new_v)

    # TEDA-guard masking: skipped step == unseen batch
    skip = jnp.asarray(skip)
    sel = lambda n, o: jnp.where(skip, o, n)
    new_params = jax.tree_util.tree_map(sel, new_params, params)
    new_m = jax.tree_util.tree_map(sel, new_m, state.m)
    new_v = jax.tree_util.tree_map(sel, new_v, state.v)
    new_count = jnp.where(skip, state.count, count)

    metrics = {"grad_norm": gnorm, "lr": lr,
               "skipped": skip.astype(jnp.float32)}
    return new_params, OptState(m=new_m, v=new_v, count=new_count), metrics
