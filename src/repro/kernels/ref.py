"""Pure-jnp oracle for the TEDA scan kernel.

Independent of both `core/teda.py` (lax.scan) and `core/scan.py`
(associative_scan): computes the prefix statistics directly from
O(T^2)-free closed forms using jnp.cumsum only, in float64-when-available
for a tight reference. Shapes: x (T, C) — C independent univariate
streams (the kernel's layout: time on sublanes, channels on lanes).
"""
from __future__ import annotations

import numpy as np

__all__ = ["teda_ref"]


def teda_ref(x, m: float = 3.0, k0: int = 0, sum0=None, var0=None):
    """Reference TEDA over x (T, C) with optional carried state.

    Returns dict(mean, var, ecc, zeta, threshold, outlier) each (T, C),
    computed with numpy in float64.
    """
    x = np.asarray(x, np.float64)
    T, C = x.shape
    sum0 = np.zeros(C) if sum0 is None else np.asarray(sum0, np.float64)
    var0 = np.zeros(C) if var0 is None else np.asarray(var0, np.float64)

    k = (k0 + np.arange(1, T + 1, dtype=np.float64))[:, None]  # (T, 1)
    s = sum0[None] + np.cumsum(x, axis=0)
    mean = s / k
    d2 = (x - mean) ** 2
    first = k <= 1.0
    d2 = np.where(first, 0.0, d2)

    # var_k = (k-1)/k var_{k-1} + d2_k / k  — sequential reference loop.
    var = np.zeros((T, C))
    prev = var0
    for i in range(T):
        kk = k[i, 0]
        prev = np.where(first[i], 0.0, (kk - 1.0) / kk * prev + d2[i] / kk)
        var[i] = prev

    safe = var > 0.0
    ecc = 1.0 / k + np.where(safe, d2 / (k * np.where(safe, var, 1.0)), 0.0)
    zeta = ecc / 2.0
    thr = (m * m + 1.0) / (2.0 * k) * np.ones((1, C))
    outlier = (zeta > thr) & (k >= 2.0)
    return {
        "mean": mean, "var": var, "ecc": ecc, "zeta": zeta,
        "threshold": thr * np.ones_like(ecc), "outlier": outlier,
    }
