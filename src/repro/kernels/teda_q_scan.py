"""Pallas TPU kernel: chunked *integer* Q-format TEDA scan.

The quantized datapath is not associative — truncation/saturation error
depends on operation order — so the float kernel's prefix-sum tricks
would change the bits.  Instead this kernel is the direct TPU analog of
the FPGA pipeline: a sequential row loop inside each time-chunk (one
sample retired per "cycle", exactly like the paper's critical path),
vectorized across the 128-lane channel axis.  The grid still walks
time-chunks, so Mosaic overlaps the HBM->VMEM DMA of chunk i+1 with
compute on chunk i — the inter-module pipeline registers' role.

Each row executes `repro.fixedpoint.teda_q._q_step_u`, the same
function `teda_q_scan_chan` scans over, which makes this kernel
bit-exact with the pure-JAX Q scan by construction.

Layout contract (enforced by ops.py):
  x: (T, C) int32 Q-values, T % block_t == 0, C % 128 == 0,
  block_t % 8 == 0.  SMEM scalar: [msq1_q] int32.  The per-channel
  counter offset `k0` and the per-channel valid length `vlen` are
  (1, C) int32 carry rows (slots may sit at different stream positions
  and retire different sample counts in one call; a uniform chunk is a
  broadcast vlen).  Rows of channel c at global index >= vlen[c] are
  masked: that channel's mean/var carries freeze, so the final-state
  rows — always emitted as (1, C) outputs — are exact for every ragged
  vlen vector, bit-for-bit with a per-channel isolated run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.fixedpoint.qformat import QFormat
from repro.fixedpoint.teda_q import _q_counter_terms, _q_step_u
from repro.kernels.teda_scan import tpu_compiler_params

__all__ = ["teda_q_scan_kernel", "teda_q_pallas_call"]


def teda_q_scan_kernel(scal_ref, x_ref, vlen_ref, init_k_ref,
                       init_mean_ref, init_var_ref, mean_ref, var_ref,
                       ecc_ref, outlier_ref, fmean_ref, fvar_ref,
                       mean_carry, var_carry, *, block_t: int,
                       fmt: QFormat):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        mean_carry[...] = init_mean_ref[...]
        var_carry[...] = init_var_ref[...]

    msq1 = scal_ref[0]
    vlen = vlen_ref[...]  # (1, C) int32 per-channel valid length
    k0 = init_k_ref[...]  # (1, C) int32 per-channel counter offset

    # counter-only dividers for the whole chunk, vectorized over rows
    # (one bit-serial pass instead of one per row; bit-identical values)
    kv = (k0 + i * block_t + 1
          + jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0))
    rk_b, inv_b, thr_b = _q_counter_terms(fmt, kv, msq1)

    def row(r, carry):
        mean, var = carry  # (1, C) int32 Q
        g = i * block_t + r            # global row index
        k = k0 + g + 1                 # the FPGA's counter register, (1, C)
        valid = g < vlen               # per-channel ragged mask, (1, C)
        xr = x_ref[pl.ds(r, 1), :]
        terms = tuple(jax.lax.dynamic_slice_in_dim(t, r, 1, 0)
                      for t in (rk_b, inv_b, thr_b))
        mean_n, var_n, ecc, _zeta, _thr, outl = _q_step_u(
            fmt, k, mean, var, xr, msq1, terms=terms)
        mean_ref[pl.ds(r, 1), :] = mean_n
        var_ref[pl.ds(r, 1), :] = var_n
        ecc_ref[pl.ds(r, 1), :] = ecc
        outlier_ref[pl.ds(r, 1), :] = outl.astype(jnp.int8)
        # each channel's ragged tail must not advance its carried state
        return (jnp.where(valid, mean_n, mean),
                jnp.where(valid, var_n, var))

    mean, var = jax.lax.fori_loop(
        0, block_t, row, (mean_carry[...], var_carry[...]))
    mean_carry[...] = mean
    var_carry[...] = var
    fmean_ref[...] = mean
    fvar_ref[...] = var


def teda_q_pallas_call(x: jnp.ndarray, scal: jnp.ndarray,
                       vlen: jnp.ndarray, init_k: jnp.ndarray,
                       init_mean: jnp.ndarray, init_var: jnp.ndarray, *,
                       fmt: QFormat, block_t: int, interpret: bool):
    """Raw pallas_call. x (T, C) int32 pre-padded; scal = [msq1] (1,);
    vlen / init_k / init_mean / init_var are (1, C) int32 carry rows —
    vlen[c] is the number of leading valid rows of channel c (0..T).

    Returns (mean, var, ecc, outlier, final_mean, final_var); the final
    rows are always populated (each channel's state after its own
    vlen[c] valid rows).
    """
    t_len, c = x.shape
    assert t_len % block_t == 0 and block_t % 8 == 0 and c % 128 == 0, (
        "ops.py must pad: T % block_t == 0, block_t % 8 == 0, C % 128 == 0")
    grid = (t_len // block_t,)

    row_spec = pl.BlockSpec((block_t, c), lambda i: (i, 0))
    carry_spec = pl.BlockSpec((1, c), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((t_len, c), jnp.int32),  # mean (Q)
        jax.ShapeDtypeStruct((t_len, c), jnp.int32),  # var (Q)
        jax.ShapeDtypeStruct((t_len, c), jnp.int32),  # ecc (Q)
        jax.ShapeDtypeStruct((t_len, c), jnp.int8),   # outlier flag
        jax.ShapeDtypeStruct((1, c), jnp.int32),      # final mean (Q)
        jax.ShapeDtypeStruct((1, c), jnp.int32),      # final var (Q)
    ]
    kernel = functools.partial(teda_q_scan_kernel, block_t=block_t,
                               fmt=fmt)
    compiler_params = None
    if not interpret:
        compiler_params = tpu_compiler_params(
            dimension_semantics=("arbitrary",))  # sequential carry
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scal (1,) int32
            row_spec,    # x
            carry_spec,  # vlen
            carry_spec,  # init_k
            carry_spec,  # init_mean
            carry_spec,  # init_var
        ],
        out_specs=[row_spec, row_spec, row_spec, row_spec,
                   carry_spec, carry_spec],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((1, c), jnp.int32),  # running mean carry
            pltpu.VMEM((1, c), jnp.int32),  # running var carry
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(scal, x, vlen, init_k, init_mean, init_var)
