"""Pallas TPU kernel: chunked *integer* Q-format TEDA scan.

The quantized datapath is not associative — truncation/saturation error
depends on operation order — so the float kernel's prefix-sum tricks
would change the bits.  Instead this kernel is the direct TPU analog of
the FPGA pipeline: a sequential row loop inside each time-chunk (one
sample retired per "cycle", exactly like the paper's critical path),
vectorized across the 128-lane channel axis.  The grid is 2-D
`(channel-block, time-block)`: the minor (time) axis walks time-chunks
sequentially — Mosaic overlaps the HBM->VMEM DMA of chunk i+1 with
compute on chunk i, the inter-module pipeline registers' role — while
the major axis tiles the channel lanes into independent `block_c`-wide
strips declared `parallel`, so a wide-C engine splits across TPU cores
instead of serializing every lane through one.

Inside a block the datapath is *rescheduled* around the bit-serial
dividers (the FPGA's multi-cycle units, ~WL iterations each).  Only
the MEAN and VARIANCE recurrences are genuinely sequential, and both
are a saturating multiply-add once their divider terms exist; every
divider input is either counter-only (rk=(k-1)/k, 1/k, (m^2+1)/2k),
depends only on the samples (x/k), or is a pure per-row function of
values the recurrences produce (d2/k, d2/var, ratio/k).  So the kernel
runs two sequential register loops — one bare saturating multiply-add
per sample each, the MEAN and VARIANCE accumulator registers, with the
k=1 overrides folded into the hoisted terms (rk = 0 and x/1 = x at
k=1) — and executes every divider as one vectorized whole-block pass
outside them: bit-identical values (the dividers are elementwise; each
element sees exactly the inputs and operation order of
`repro.fixedpoint.teda_q._q_step_u`, the function `teda_q_scan_chan`
scans over — the oracle this kernel is tested bit-exact against, for
every `block_c`, since channels never exchange data).  The sequential
critical path drops from four bit-serial divisions per sample to none,
and each hoisted pass runs through the host-width exact image of the
divider (`kernels/qdiv.py`): one integer divide plus FL restoring
steps instead of 31+FL shift-subtract iterations, same bits.

Layout contract (enforced by ops.py):
  x: (T, C) int32 Q-values, T % block_t == 0, C % block_c == 0,
  block_t % 8 == 0, block_c % 128 == 0.  SMEM scalar: [msq1_q] int32.
  The per-channel counter offset `k0` and the per-channel valid length
  `vlen` are (1, C) int32 carry rows (slots may sit at different stream
  positions and retire different sample counts in one call; a uniform
  chunk is a broadcast vlen).  Rows of channel c at global index >=
  vlen[c] are masked: that channel's mean/var carries freeze, so the
  final-state rows — always emitted as (1, C) outputs, written once at
  each strip's last time block — are exact for every ragged vlen
  vector, bit-for-bit with a per-channel isolated run.

Donation contract (wired by ops.py): `k0` aliases the in-kernel
final-k output, `init_mean`/`init_var` alias the final mean/var rows,
and the (T, C) Q-sample buffer `x` aliases the first (T, C) output —
the call consumes its operands and allocates no fresh HBM for them.
`vlen` is read by every grid step (the ragged mask) and has no output
successor, so it is the one carry row left undonated.

`verdict_only` drops the per-row mean/var outputs: the serving engine
consumes only (ecc, outlier) + the final carries, and skipping two
(T, C) int32 VMEM->HBM streams is a measured ~1.2x on the Q hot path
(the matching wrapper-level win — not re-deriving the (T, C) bit-serial
threshold the engine never reads — is in ops.teda_q_scan_verdict).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.fixedpoint.qformat import QFormat, sat_add, sat_mul, sat_sub
from repro.kernels.qdiv import fast_div_qi, fast_div_qq
from repro.kernels.teda_scan import block_spec, tpu_compiler_params

__all__ = ["teda_q_scan_kernel", "teda_q_pallas_call"]


def teda_q_scan_kernel(scal_ref, x_ref, vlen_ref, init_k_ref,
                       init_mean_ref, init_var_ref, *out_refs,
                       block_t: int, fmt: QFormat,
                       verdict_only: bool = False):
    if verdict_only:
        ecc_ref, outlier_ref, fk_ref, fmean_ref, fvar_ref = out_refs[:5]
        mean_carry, var_carry, mean_scr, var_scr = out_refs[5:]
        mean_ref = var_ref = None
    else:
        (mean_ref, var_ref, ecc_ref, outlier_ref, fk_ref, fmean_ref,
         fvar_ref) = out_refs[:7]
        mean_carry, var_carry, mean_scr, var_scr = out_refs[7:]
    i = pl.program_id(1)  # time block (sequential, carry-chained)

    # a new channel strip restarts the time sweep: re-seed its carries
    @pl.when(i == 0)
    def _init():
        mean_carry[...] = init_mean_ref[...]
        var_carry[...] = init_var_ref[...]

    msq1 = scal_ref[0]
    vlen = vlen_ref[...]  # (1, bc) int32 per-channel valid length
    k0 = init_k_ref[...]  # (1, bc) int32 per-channel counter offset
    xb = x_ref[...]       # (block_t, bc) int32 Q samples

    # the FPGA's counter register for every row of the block, plus the
    # whole-block iteration index and ragged mask
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0)
    kv = k0 + i * block_t + 1 + row_iota     # (block_t, bc)
    first_b = kv <= 1
    valid_b = (i * block_t + row_iota) < vlen

    # every data-independent divider, vectorized over the whole block:
    # the counter-only triple (rk = (k-1)/k, 1/k, thr = (m^2+1)/2k) of
    # `_q_counter_terms` and the MEAN module's x/k (eq (2)) — computed
    # through the host-width image of the bit-serial divider
    # (kernels/qdiv.py), one whole-block pass each instead of one
    # 31+FL-step division per row
    rk_b = fast_div_qq(fmt, kv - 1, kv)
    inv_b = fast_div_qi(fmt, jnp.broadcast_to(jnp.int32(fmt.one),
                                              kv.shape), kv)
    thr_b = fast_div_qi(fmt, jnp.broadcast_to(jnp.asarray(msq1,
                                                          jnp.int32),
                                              kv.shape), 2 * kv)
    xk_b = fast_div_qi(fmt, xb, kv)

    def _row(a, r):
        return jax.lax.dynamic_slice_in_dim(a, r, 1, 0)

    # MEAN recurrence, eq (2): mu = rk * mu + x/k — a bare saturating
    # multiply-add per row, the MEAN module's accumulator register.  The
    # k=1 override of `_q_mean_update` is bit-redundant here: at k=1,
    # rk = div_qq(0, 1) = 0 and x/k = div_qi(x, 1) = x exactly (division
    # by one is exact in the restoring divider, and x is in-format), so
    # the multiply-add itself yields x.
    def mean_row(r, mean):
        mean_n = sat_add(fmt, sat_mul(fmt, _row(rk_b, r), mean),
                         _row(xk_b, r))
        mean_scr[pl.ds(r, 1), :] = mean_n
        # each channel's ragged tail must not advance its carried state
        return jnp.where(_row(valid_b, r), mean_n, mean)

    mean_carry[...] = jax.lax.fori_loop(
        0, block_t, mean_row, mean_carry[...])

    # VARIANCE divider d2/k of eq (3): d2 = (x - mu_k)^2 is elementwise
    # in the banked mean rows, so it — and its divider — leave the
    # sequential path too.  The k=1 override (var resets to 0) is folded
    # in by zeroing the divider term: rk = 0 at k=1 makes the
    # multiply-add produce exactly 0.
    mean_b = mean_scr[...]
    d_b = sat_sub(fmt, xb, mean_b)
    d2_b = sat_mul(fmt, d_b, d_b)
    e_b = jnp.where(first_b, 0, fast_div_qi(fmt, d2_b, kv))
    if not verdict_only:
        mean_ref[...] = mean_b

    # VARIANCE recurrence: var = rk * var + d2/k — the second
    # accumulator register, again a bare multiply-add per row
    def var_row(r, var):
        var_n = sat_add(fmt, sat_mul(fmt, _row(rk_b, r), var),
                        _row(e_b, r))
        var_scr[pl.ds(r, 1), :] = var_n
        return jnp.where(_row(valid_b, r), var_n, var)

    var_carry[...] = jax.lax.fori_loop(0, block_t, var_row, var_carry[...])

    # ECCENTRICITY + OUTLIER, eqs (1)(5)(6): pure per-row functions of
    # the banked (d2, var) rows — the d2/var and ratio/k dividers run as
    # single whole-block passes, bit-identical to `_q_post_d2` (the ops
    # are elementwise; each element sees the same inputs in the same
    # order).  The var>0 guard also covers first rows (var == 0 there).
    var_b = var_scr[...]
    safe = var_b > 0
    ratio = fast_div_qq(fmt, d2_b, jnp.where(safe, var_b, 1))
    ecc = sat_add(fmt, inv_b,
                  jnp.where(safe, fast_div_qi(fmt, ratio, kv), 0))
    ecc_ref[...] = ecc
    outlier_ref[...] = (((ecc >> 1) > thr_b) & (kv >= 2)).astype(jnp.int8)
    if not verdict_only:
        var_ref[...] = var_b

    # final-state rows written once, at the strip's last time block —
    # required for the carry-row donation (init rows are read at i == 0,
    # their aliased buffers overwritten only here), and one (1, C) HBM
    # write per strip instead of one per block
    @pl.when(i == pl.num_programs(1) - 1)
    def _fin():
        fk_ref[...] = k0 + vlen  # vlen pre-clamped to [0, T] by ops.py
        fmean_ref[...] = mean_carry[...]
        fvar_ref[...] = var_carry[...]


def teda_q_pallas_call(x: jnp.ndarray, scal: jnp.ndarray,
                       vlen: jnp.ndarray, init_k: jnp.ndarray,
                       init_mean: jnp.ndarray, init_var: jnp.ndarray, *,
                       fmt: QFormat, block_t: int, block_c: int = 0,
                       interpret: bool, verdict_only: bool = False,
                       donate: bool = True):
    """Raw pallas_call. x (T, C) int32 pre-padded; scal = [msq1] (1,);
    vlen / init_k / init_mean / init_var are (1, C) int32 carry rows —
    vlen[c] is the number of leading valid rows of channel c (0..T,
    already clamped).  `block_c` tiles the channel axis into independent
    grid strips (0 means one strip spanning all C lanes — the 1-D grid).

    Returns (mean, var, ecc, outlier, fk, final_mean, final_var) or,
    with verdict_only, (ecc, outlier, fk, final_mean, final_var); the
    final rows are always populated (each channel's state after its own
    vlen[c] valid rows; fk = k0 + vlen).  With `donate` the carry rows
    and x alias the outputs — callers must treat the operands as
    consumed.
    """
    t_len, c = x.shape
    if not block_c:
        block_c = c
    assert (t_len % block_t == 0 and block_t % 8 == 0
            and c % block_c == 0 and block_c % 128 == 0), (
        "ops.py must pad: T % block_t == 0, block_t % 8 == 0, "
        "C % block_c == 0, block_c % 128 == 0")
    grid = (c // block_c, t_len // block_t)

    row_spec = block_spec((block_t, block_c), lambda j, i: (i, j),
                          memory_space=pltpu.VMEM)
    carry_spec = block_spec((1, block_c), lambda j, i: (0, j),
                            memory_space=pltpu.VMEM)
    i32 = jnp.int32
    final_shape = [
        jax.ShapeDtypeStruct((1, c), i32),  # final k
        jax.ShapeDtypeStruct((1, c), i32),  # final mean (Q)
        jax.ShapeDtypeStruct((1, c), i32),  # final var (Q)
    ]
    if verdict_only:
        out_shape = [
            jax.ShapeDtypeStruct((t_len, c), i32),       # ecc (Q)
            jax.ShapeDtypeStruct((t_len, c), jnp.int8),  # outlier flag
        ] + final_shape
        out_specs = [row_spec, row_spec, carry_spec, carry_spec,
                     carry_spec]
    else:
        out_shape = [
            jax.ShapeDtypeStruct((t_len, c), i32),       # mean (Q)
            jax.ShapeDtypeStruct((t_len, c), i32),       # var (Q)
            jax.ShapeDtypeStruct((t_len, c), i32),       # ecc (Q)
            jax.ShapeDtypeStruct((t_len, c), jnp.int8),  # outlier flag
        ] + final_shape
        out_specs = [row_spec, row_spec, row_spec, row_spec,
                     carry_spec, carry_spec, carry_spec]
    n_rows = 2 if verdict_only else 4
    aliases = {}
    if donate:
        # k0 -> fk, init_mean -> fmean, init_var -> fvar; the consumed
        # Q-sample buffer aliases the first (T, C) int32 output (vlen is
        # read by every step — not donated)
        aliases = {1: 0, 3: n_rows, 4: n_rows + 1, 5: n_rows + 2}
    kernel = functools.partial(teda_q_scan_kernel, block_t=block_t,
                               fmt=fmt, verdict_only=verdict_only)
    compiler_params = None
    if not interpret:
        compiler_params = tpu_compiler_params(
            # channel strips are independent (multi-core scaling); the
            # time axis is the sequential carry chain
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scal (1,) int32
            row_spec,    # x
            carry_spec,  # vlen
            carry_spec,  # init_k
            carry_spec,  # init_mean
            carry_spec,  # init_var
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        scratch_shapes=[
            pltpu.VMEM((1, block_c), i32),        # running mean carry
            pltpu.VMEM((1, block_c), i32),        # running var carry
            pltpu.VMEM((block_t, block_c), i32),  # banked mean rows
            pltpu.VMEM((block_t, block_c), i32),  # banked var rows
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(scal, x, vlen, init_k, init_mean, init_var)
