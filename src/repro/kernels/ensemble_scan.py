"""Fused multi-detector Pallas kernel: K detectors x C channels per call.

One (chunk_t, C) call on the PR 7 2-D `(channel-block, time-block)`
grid evaluates every detector of the ensemble (`repro.detectors`) for
every channel.  The carried state is no longer a fixed 2W+1 moment
formula: it is the `StateSpec` layout from `detectors/spec.py` — the
shared moment fabric (prefix-sum tails + the TEDA variance recursion)
in rows [0, 2W], then one opaque `(rows_k, C)` region group per
non-moment member, in detector order.  The whole block lives in ONE
`(spec.rows, block_c)` VMEM scratch tile, re-seeded from `aux` at each
strip's first time block and written back once at its last (the
carry/donation discipline of `teda_scan.py`).

Per (block_t, block_c) tile the kernel runs a per-member state-advance
dispatch:

  * moment members (teda / rde / zscore) share the masked prefix sum S
    (Hillis-Steele `_cumsum_rows`), the S2 twin, and the TEDA affine
    variance scan — the EXACT arithmetic of the PR 8 kernel, reading
    and writing the same aux rows, so moment-only ensembles are
    bit/array-identical to it (and the TEDA lane to `teda_scan.py`);
  * "hst" advances its opaque leaf-mass tables + phase row with a
    sequential per-row loop of exact small-integer f32 ops — identical
    bits to the `detectors/hst.py` oracle;
  * "teda-q" advances its opaque int32 Q registers (bitcast in the f32
    aux block) on the `teda_q_scan.py` divider-hoisted schedule through
    `kernels/qdiv.py` — bit-exact with the `detectors/teda_q.py`
    oracle, including the in-kernel f32 quantization of the m^2+1 ROM
    constant from the per-channel m carry.

Outputs per call: the (T, C) int32 detector bitmask (bit d = detector
d flagged, masked by selection weight and ragged validity), the (T, C)
weighted-vote verdict (sum_d w_d * flag_d >= thr[c], accumulated in
detector order in float32 — the exact order a host recomputation from
the emitted bitmask must use; the Q member's flag enters the same f32
accumulation, which is what makes the Q-path vote host-recomputable
bit-exactly), and K per-detector (T, C) float32 SCORE streams (TEDA
eccentricity, RDE Cauchy density, squared z-score, HST reference-cell
mass, dequantized Q eccentricity — zero on invalid rows).

Selection (`sel`, (K, C) weights; 0 = unselected) gates only flags and
the vote — state always advances for every member, which is what makes
a detector-masked slot bit-identical to a single-detector run of the
same stream.  Ragged `vlen` semantics are the TEDA kernel's: validity
is a per-channel prefix, invalid rows advance nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.detectors.spec import (HST_LEAVES, HST_RANGE, MOMENT_MEMBERS,
                                  ensemble_spec, f32_to_i32_bits,
                                  i32_to_f32_bits)
from repro.fixedpoint.qformat import sat_add, sat_mul, sat_sub
from repro.kernels.qdiv import fast_div_qi, fast_div_qq
from repro.kernels.teda_scan import (_affine_scan_rows, _cumsum_rows,
                                     block_spec, tpu_compiler_params)

__all__ = ["ensemble_scan_kernel", "ensemble_pallas_call"]


def _row(a, r):
    return jax.lax.dynamic_slice_in_dim(a, r, 1, 0)


def _hst_lane(state, spec, x, valid, m, *, window: int):
    """Advance the "hst" opaque regions; returns (flags, scores).

    Sequential per-row loop (the window flip is a data-dependent state
    machine, not a scan), but every op is an exact small-integer f32
    add/compare — identical bits to the `hst_scan` oracle step.
    """
    bt, bc = x.shape
    ell = HST_LEAVES
    off = spec.offset("hst:ref")
    ref0 = state[off:off + ell, :]
    cur0 = state[off + ell:off + 2 * ell, :]
    ph0 = state[off + 2 * ell:off + 2 * ell + 1, :]
    lo, hi = HST_RANGE
    scale = float(ell) / (hi - lo)
    lf = jnp.clip(jnp.floor((x - lo) * scale), 0.0, float(ell - 1))
    leaves = jax.lax.broadcasted_iota(jnp.float32, (ell, 1), 0)
    wn = float(int(window) * ell)
    zero = jnp.zeros((bt, bc), jnp.float32)

    def body(r, carry):
        ref, cur, ph, scores, flags = carry
        lf_r = _row(lf, r)                         # (1, bc)
        v_r = _row(valid, r)                       # (1, bc) bool
        onehot = leaves == lf_r                    # (ell, bc)
        score = jnp.sum(jnp.where(onehot, ref, 0.0), axis=0,
                        keepdims=True)
        filled = jnp.sum(ref, axis=0, keepdims=True) > 0.0
        flag = v_r & filled & (score * m < float(window))
        cur1 = cur + jnp.where(onehot & v_r, 1.0, 0.0)
        ph1 = ph + v_r.astype(jnp.float32)
        flip = ph1 == wn
        ref1 = jnp.where(flip, cur1, ref)
        cur2 = jnp.where(flip, 0.0, cur1)
        ph2 = jnp.where(flip, 0.0, ph1)
        scores = jax.lax.dynamic_update_slice(
            scores, jnp.where(v_r, score, 0.0), (r, 0))
        flags = jax.lax.dynamic_update_slice(
            flags, flag.astype(jnp.float32), (r, 0))
        return ref1, cur2, ph2, scores, flags

    ref_f, cur_f, ph_f, scores, flags = jax.lax.fori_loop(
        0, bt, body, (ref0, cur0, ph0, zero, zero))
    state[off:off + ell, :] = ref_f
    state[off + ell:off + 2 * ell, :] = cur_f
    state[off + 2 * ell:off + 2 * ell + 1, :] = ph_f
    return flags > 0.0, scores


def _teda_q_lane(state, spec, x, valid, k, m, fmt):
    """Advance the "teda-q" opaque Q registers; returns (flags, scores).

    The `teda_q_scan.py` kernel's rescheduled datapath on the member's
    bitcast int32 regions: every counter-only divider (rk=(k-1)/k, 1/k,
    thr=(m^2+1)/2k) and the sample divider x/k run as whole-block
    passes through the host-width exact divider image
    (`kernels/qdiv.py`); the MEAN and VARIANCE recurrences are two slim
    saturating multiply-add row loops with ragged carry freeze.
    Bit-exact with `_q_step_u` (hence the `teda_q_member_scan` oracle):
    each element sees the same inputs and operation order, with the
    k=1 overrides folded into the hoisted terms (rk = 0 and x/1 = x).
    """
    bt, bc = x.shape
    i32 = jnp.int32
    offm = spec.offset("teda-q:mean")
    offv = spec.offset("teda-q:var")
    mean0 = f32_to_i32_bits(state[offm:offm + 1, :])
    var0 = f32_to_i32_bits(state[offv:offv + 1, :])
    xq = fmt.quantize(x)                    # (bt, bc) int32 Q
    msq1 = fmt.quantize(m * m + 1.0)        # (1, bc) — the f32 m carry
    kv = k.astype(i32)                      # exact: k < 2^24
    first = kv <= 1

    rk_b = fast_div_qq(fmt, kv - 1, kv)
    inv_b = fast_div_qi(fmt, jnp.broadcast_to(i32(fmt.one), kv.shape), kv)
    thr_b = fast_div_qi(fmt, jnp.broadcast_to(msq1, kv.shape), 2 * kv)
    xk_b = fast_div_qi(fmt, xq, kv)
    zero = jnp.zeros((bt, bc), i32)

    def mean_row(r, carry):
        mean, bank = carry
        mean_n = sat_add(fmt, sat_mul(fmt, _row(rk_b, r), mean),
                         _row(xk_b, r))
        bank = jax.lax.dynamic_update_slice(bank, mean_n, (r, 0))
        return jnp.where(_row(valid, r), mean_n, mean), bank

    mean_f, mean_b = jax.lax.fori_loop(0, bt, mean_row, (mean0, zero))

    d_b = sat_sub(fmt, xq, mean_b)
    d2_b = sat_mul(fmt, d_b, d_b)
    e_b = jnp.where(first, 0, fast_div_qi(fmt, d2_b, kv))

    def var_row(r, carry):
        var, bank = carry
        var_n = sat_add(fmt, sat_mul(fmt, _row(rk_b, r), var),
                        _row(e_b, r))
        bank = jax.lax.dynamic_update_slice(bank, var_n, (r, 0))
        return jnp.where(_row(valid, r), var_n, var), bank

    var_f, var_b = jax.lax.fori_loop(0, bt, var_row, (var0, zero))

    safe = var_b > 0
    ratio = fast_div_qq(fmt, d2_b, jnp.where(safe, var_b, 1))
    ecc = sat_add(fmt, inv_b,
                  jnp.where(safe, fast_div_qi(fmt, ratio, kv), 0))
    flags = ((ecc >> 1) > thr_b) & (kv >= 2)
    scores = jnp.where(valid, fmt.dequantize(ecc), 0.0)
    state[offm:offm + 1, :] = i32_to_f32_bits(mean_f)
    state[offv:offv + 1, :] = i32_to_f32_bits(var_f)
    return flags, scores


def ensemble_scan_kernel(x_ref, vlen_ref, k0_ref, m_ref, thr_ref, sel_ref,
                         aux_ref, bits_ref, vote_ref, fk_ref, aux_out_ref,
                         *rest, block_t: int, window: int,
                         detectors: tuple, fmt=None):
    score_refs = rest[:-1]          # K per-detector (bt, bc) f32 outputs
    state = rest[-1]                # the (spec.rows, bc) scratch tile
    spec = ensemble_spec(detectors, window)
    w = window
    moment = any(d in MOMENT_MEMBERS for d in detectors)
    need_s2 = ("rde" in detectors) or ("zscore" in detectors)
    i = pl.program_id(1)  # time block (sequential, carry-chained)

    # a new channel strip restarts the time sweep: re-seed the whole
    # spec block from aux — a raw f32 copy, so the bitcast i32 regions'
    # payloads survive untouched
    @pl.when(i == 0)
    def _init():
        state[...] = aux_ref[...]

    x = x_ref[...].astype(jnp.float32)        # (bt, bc)
    bt, c = x.shape
    k0 = k0_ref[...].astype(jnp.float32)      # (1, bc)
    vlen = vlen_ref[...].astype(jnp.float32)  # (1, bc)
    m = m_ref[...].astype(jnp.float32)        # (1, bc) per-channel m
    thr = thr_ref[...].astype(jnp.float32)    # (1, bc) vote threshold
    t = jax.lax.broadcasted_iota(jnp.float32, (bt, 1), 0)
    g = i * block_t + t                # global row index, (bt, 1)
    valid = g < vlen                   # ragged-tail mask, (bt, bc)
    k = k0 + g + 1.0                   # per-channel iteration index
    m2 = m * m

    flags, scores = {}, {}
    if moment:
        # ---- shared MEAN fabric: one prefix sum feeds every moment
        # member (aux rows [0, 2W] — the PR 8 arithmetic, verbatim) ----
        s = _cumsum_rows(jnp.where(valid, x, 0.0)) + state[w - 1:w, :]
        mean = s / k
        dr = (x - mean) ** 2           # raw distance to the running mean

    if "teda" in detectors:
        # eq (3) affine scan + eqs (1)/(5)/(6) — the exact arithmetic of
        # `teda_scan_kernel`, so this lane's flags are bit-identical to
        # the standalone "pallas" backend at equal block_t
        first = k <= 1.0
        d2 = jnp.where(jnp.logical_or(first, ~valid), 0.0, dr)
        a = jnp.broadcast_to(jnp.where(first, 0.0, (k - 1.0) / k), (bt, c))
        a = jnp.where(valid, a, 1.0)   # identity map on padded rows
        av, bv = _affine_scan_rows(a, d2 / k)
        var = av * state[2 * w:2 * w + 1, :] + bv
        safe = var > 0.0
        ecc = 1.0 / k + jnp.where(safe,
                                  d2 / (k * jnp.where(safe, var, 1.0)), 0.0)
        flags["teda"] = jnp.logical_and(ecc * 0.5 > (m2 + 1.0) / (2.0 * k),
                                        k >= 2.0)
        scores["teda"] = ecc
        state[2 * w:2 * w + 1, :] = var[block_t - 1:block_t]

    if need_s2:
        s2 = (_cumsum_rows(jnp.where(valid, x * x, 0.0))
              + state[2 * w - 1:2 * w, :])

    if "rde" in detectors:
        # biased variance from the running moments (Angelov's RDE)
        meanr = s / k
        varb = s2 / k - meanr * meanr
        flags["rde"] = (varb > 0.0) & (k >= 2.0) & (dr > m2 * varb)
        okr = varb > 0.0
        scores["rde"] = 1.0 / (1.0 + jnp.where(
            okr, dr / jnp.where(okr, varb, 1.0), 0.0))

    if "zscore" in detectors:
        # windowed moments as prefix-sum differences against the W-deep
        # carried tails: s_full[p] = S_{k_blockstart + p - W + 1}, so the
        # lag row S_{k - W} of in-block row r is s_full[r]
        s_full = jnp.concatenate([state[0:w, :], s], axis=0)  # (W+bt, c)
        s2_full = jnp.concatenate([state[w:2 * w, :], s2], axis=0)
        winsum = s - s_full[:bt]
        winsq = s2 - s2_full[:bt]
        n = jnp.minimum(k, float(w))
        muw = winsum / n
        sigw = winsq / n - muw * muw
        dz = (x - muw) ** 2
        flags["zscore"] = (sigw > 0.0) & (k >= 2.0) & (dz > m2 * sigw)
        okz = sigw > 0.0
        scores["zscore"] = jnp.where(okz, dz / jnp.where(okz, sigw, 1.0),
                                     0.0)
        # advance the tails to the valid extent of this block: new tail
        # row j is s_full[n_valid + j] (validity is a prefix, so the
        # tail stays contiguous for every ragged vlen).  Static-W loop
        # of 2-D masked reductions — one per tail row — instead of a
        # 3-D gather (sublane-dynamic indexing is not a Mosaic op).
        n_valid = jnp.clip(vlen - i * block_t, 0.0, float(bt))  # (1, c)
        rows = jax.lax.broadcasted_iota(jnp.float32, (bt + w, 1), 0)
        new_s, new_s2 = [], []
        for j in range(w):
            hit = rows == (n_valid + float(j))  # (bt+w, c), exact f32
            new_s.append(jnp.sum(jnp.where(hit, s_full, 0.0), axis=0,
                                 keepdims=True))
            new_s2.append(jnp.sum(jnp.where(hit, s2_full, 0.0), axis=0,
                                  keepdims=True))
        state[0:w, :] = jnp.concatenate(new_s, axis=0)
        state[w:2 * w, :] = jnp.concatenate(new_s2, axis=0)
    elif moment:
        state[w - 1:w, :] = s[block_t - 1:block_t]
        if need_s2:
            state[2 * w - 1:2 * w, :] = s2[block_t - 1:block_t]

    # ---- opaque-region members: per-member state-advance dispatch -----
    if "hst" in detectors:
        flags["hst"], scores["hst"] = _hst_lane(state, spec, x, valid, m,
                                                window=window)
    if "teda-q" in detectors:
        flags["teda-q"], scores["teda-q"] = _teda_q_lane(
            state, spec, x, valid, k, m, fmt)

    # ---- selection-masked bitmask + weighted vote + score streams -----
    bits = jnp.zeros((bt, c), jnp.int32)
    votew = jnp.zeros((bt, c), jnp.float32)
    totw = jnp.zeros((1, c), jnp.float32)
    for d, name in enumerate(detectors):
        wrow = sel_ref[d:d + 1, :].astype(jnp.float32)  # (1, bc)
        f = flags[name] & (wrow > 0.0) & valid
        bits = bits + f.astype(jnp.int32) * (1 << d)
        votew = votew + f.astype(jnp.float32) * wrow
        totw = totw + wrow
        score_refs[d][...] = jnp.where(valid, scores[name], 0.0)
    vote = (votew >= thr) & (totw > 0.0) & valid
    bits_ref[...] = bits
    vote_ref[...] = vote.astype(jnp.int8)

    # final carries once per strip, at its last time block (the aux/k0
    # donation discipline of `teda_scan.py`)
    @pl.when(i == pl.num_programs(1) - 1)
    def _fin():
        fk_ref[...] = k0 + vlen  # vlen pre-clamped to [0, T] by wrapper
        aux_out_ref[...] = state[...]


def ensemble_pallas_call(x: jnp.ndarray, vlen: jnp.ndarray,
                         k0: jnp.ndarray, m: jnp.ndarray,
                         thr: jnp.ndarray, sel: jnp.ndarray,
                         aux: jnp.ndarray, *, block_t: int,
                         block_c: int = 0, window: int,
                         detectors: tuple, fmt=None, interpret: bool,
                         donate: bool = True):
    """Raw pallas_call.  x (T, C) pre-padded; vlen / k0 / m / thr are
    (1, C) per-channel carry rows; sel is the (K, C) selection-weight
    block; aux the (spec.rows, C) packed state block of
    `ensemble_spec(detectors, window)`.  `detectors` is the static
    ensemble tuple — bit d of the emitted mask is detectors[d]; `fmt`
    is the QFormat of the "teda-q" member (required iff present).
    Returns (det_bits, vote, fk, aux_final, score_0, ..., score_{K-1})
    with one (T, C) f32 score stream per detector.  With `donate`, k0
    aliases fk and aux aliases aux_final — callers must treat those
    operands as consumed.
    """
    t_len, c = x.shape
    if not block_c:
        block_c = c
    spec = ensemble_spec(detectors, window)
    n_aux = spec.rows
    assert (t_len % block_t == 0 and block_t % 8 == 0
            and c % block_c == 0 and block_c % 128 == 0), (
        "wrapper must pad: T % block_t == 0, block_t % 8 == 0, "
        "C % block_c == 0, block_c % 128 == 0")
    assert aux.shape == (n_aux, c) and sel.shape == (len(detectors), c)
    if "teda-q" in detectors and fmt is None:
        raise ValueError("the teda-q member needs fmt=QFormat(...)")
    grid = (c // block_c, t_len // block_t)

    row_spec = block_spec((block_t, block_c), lambda j, i: (i, j),
                          memory_space=pltpu.VMEM)
    carry_spec = block_spec((1, block_c), lambda j, i: (0, j),
                            memory_space=pltpu.VMEM)
    sel_spec = block_spec((len(detectors), block_c), lambda j, i: (0, j),
                          memory_space=pltpu.VMEM)
    aux_spec = block_spec((n_aux, block_c), lambda j, i: (0, j),
                          memory_space=pltpu.VMEM)
    f32 = jnp.float32
    out_shape = [
        jax.ShapeDtypeStruct((t_len, c), jnp.int32),  # detector bitmask
        jax.ShapeDtypeStruct((t_len, c), jnp.int8),   # fused vote
        jax.ShapeDtypeStruct((1, c), f32),            # final k
        jax.ShapeDtypeStruct((n_aux, c), f32),        # final aux block
    ] + [jax.ShapeDtypeStruct((t_len, c), f32)        # per-member score
         for _ in detectors]
    out_specs = [row_spec, row_spec, carry_spec, aux_spec] + \
                [row_spec for _ in detectors]
    aliases = {}
    if donate:
        # k0 -> fk, aux -> final aux (inputs 2 / 6); vlen, m, thr and
        # sel are read by every grid step — never donated
        aliases = {2: 2, 6: 3}
    kernel = functools.partial(ensemble_scan_kernel, block_t=block_t,
                               window=window, detectors=tuple(detectors),
                               fmt=fmt)
    compiler_params = None
    if not interpret:
        compiler_params = tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, carry_spec, carry_spec, carry_spec,
                  carry_spec, sel_spec, aux_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((n_aux, block_c), f32),  # the packed StateSpec
        ],
        input_output_aliases=aliases,
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, vlen, k0, m, thr, sel, aux)
