"""Fused multi-detector Pallas kernel: K detectors x C channels per call.

One (chunk_t, C) call on the PR 7 2-D `(channel-block, time-block)`
grid evaluates every detector of the ensemble (`repro.detectors`) for
every channel, on ONE shared streaming fabric — the fSEAD structure:
the detectors share their carried state (running sum, running sum of
squares, windowed prefix-sum tails, the TEDA variance recursion), so
adding a detector costs its elementwise score arithmetic, not another
pass over the stream.

Per (block_t, block_c) tile the kernel computes:

  * the masked prefix sum S (Hillis-Steele doubling — the same
    `_cumsum_rows` the TEDA kernel uses, so the TEDA lane is
    bit-identical to `teda_scan.py` at equal block_t),
  * the sum-of-squares prefix S2 (one more doubling scan; only when
    RDE or z-score is in the static `detectors` tuple),
  * the TEDA variance affine scan (only when "teda" is in it),
  * per-detector flags:  TEDA eq (6); RDE's m-sigma gate on the biased
    running moments; the windowed z-score via prefix-sum differences
    S_k - S_{k-W} against the carried W-deep tails,
  * the (T, C) int32 detector bitmask (bit d = detector d flagged,
    masked by that channel's selection weight and ragged validity),
  * the (T, C) weighted-vote verdict: sum_d w_d * flag_d >= thr[c],
    accumulated in detector order d = 0..K-1 in float32 — the exact
    order a host recomputation from the emitted bitmask must use.

Carried state is the `EngineState.aux` block (see `repro.detectors`
module docs for the row layout): W rows of S tail + W rows of S2 tail
+ 1 TEDA variance row, all (1, block_c)-strip scratch inside the
kernel, re-seeded at each strip's first time block and written back
once at its last (same carry/donation discipline as `teda_scan.py`:
`k0` aliases the final-k output, `aux` aliases the final-aux output).

Selection (`sel`, (K, C) weights; 0 = unselected) gates only flags and
the vote — state always advances for every detector, which is what
makes a detector-masked slot bit-identical to a single-detector run of
the same stream.  Ragged `vlen` semantics are the TEDA kernel's:
validity is a per-channel prefix, invalid rows contribute nothing to
any carry, no detector flags beyond a channel's vlen.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.teda_scan import (_affine_scan_rows, _cumsum_rows,
                                     block_spec, tpu_compiler_params)

__all__ = ["ensemble_scan_kernel", "ensemble_pallas_call"]


def ensemble_scan_kernel(x_ref, vlen_ref, k0_ref, m_ref, thr_ref, sel_ref,
                         aux_ref, bits_ref, vote_ref, fk_ref, aux_out_ref,
                         tail_s, tail_s2, var_c, *, block_t: int,
                         window: int, detectors: tuple):
    w = window
    need_s2 = ("rde" in detectors) or ("zscore" in detectors)
    i = pl.program_id(1)  # time block (sequential, carry-chained)

    # a new channel strip restarts the time sweep: re-seed its carries
    # from the aux block (rows [0, W) = S tail, [W, 2W) = S2 tail,
    # row 2W = TEDA variance)
    @pl.when(i == 0)
    def _init():
        tail_s[...] = aux_ref[0:w, :].astype(jnp.float32)
        tail_s2[...] = aux_ref[w:2 * w, :].astype(jnp.float32)
        var_c[...] = aux_ref[2 * w:2 * w + 1, :].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)        # (bt, bc)
    bt, c = x.shape
    k0 = k0_ref[...].astype(jnp.float32)      # (1, bc)
    vlen = vlen_ref[...].astype(jnp.float32)  # (1, bc)
    m = m_ref[...].astype(jnp.float32)        # (1, bc) per-channel m
    thr = thr_ref[...].astype(jnp.float32)    # (1, bc) vote threshold
    t = jax.lax.broadcasted_iota(jnp.float32, (bt, 1), 0)
    g = i * block_t + t                # global row index, (bt, 1)
    valid = g < vlen                   # ragged-tail mask, (bt, bc)
    k = k0 + g + 1.0                   # per-channel iteration index
    m2 = m * m

    # ---- shared MEAN fabric: one prefix sum feeds every detector -------
    s = _cumsum_rows(jnp.where(valid, x, 0.0)) + tail_s[w - 1:w, :]
    mean = s / k
    dr = (x - mean) ** 2               # raw distance to the running mean

    flags = {}
    if "teda" in detectors:
        # eq (3) affine scan + eqs (1)/(5)/(6) — the exact arithmetic of
        # `teda_scan_kernel`, so this lane's flags are bit-identical to
        # the standalone "pallas" backend at equal block_t
        first = k <= 1.0
        d2 = jnp.where(jnp.logical_or(first, ~valid), 0.0, dr)
        a = jnp.broadcast_to(jnp.where(first, 0.0, (k - 1.0) / k), (bt, c))
        a = jnp.where(valid, a, 1.0)   # identity map on padded rows
        av, bv = _affine_scan_rows(a, d2 / k)
        var = av * var_c[...] + bv
        safe = var > 0.0
        ecc = 1.0 / k + jnp.where(safe,
                                  d2 / (k * jnp.where(safe, var, 1.0)), 0.0)
        flags["teda"] = jnp.logical_and(ecc * 0.5 > (m2 + 1.0) / (2.0 * k),
                                        k >= 2.0)
        var_c[...] = var[block_t - 1:block_t]

    if need_s2:
        s2 = (_cumsum_rows(jnp.where(valid, x * x, 0.0))
              + tail_s2[w - 1:w, :])

    if "rde" in detectors:
        # biased variance from the running moments (Angelov's RDE)
        meanr = s / k
        varb = s2 / k - meanr * meanr
        flags["rde"] = (varb > 0.0) & (k >= 2.0) & (dr > m2 * varb)

    if "zscore" in detectors:
        # windowed moments as prefix-sum differences against the W-deep
        # carried tails: s_full[p] = S_{k_blockstart + p - W + 1}, so the
        # lag row S_{k - W} of in-block row r is s_full[r]
        s_full = jnp.concatenate([tail_s[...], s], axis=0)    # (W+bt, c)
        s2_full = jnp.concatenate([tail_s2[...], s2], axis=0)
        winsum = s - s_full[:bt]
        winsq = s2 - s2_full[:bt]
        n = jnp.minimum(k, float(w))
        muw = winsum / n
        sigw = winsq / n - muw * muw
        dz = (x - muw) ** 2
        flags["zscore"] = (sigw > 0.0) & (k >= 2.0) & (dz > m2 * sigw)
        # advance the tails to the valid extent of this block: new tail
        # row j is s_full[n_valid + j] (validity is a prefix, so the
        # tail stays contiguous for every ragged vlen).  Static-W loop
        # of 2-D masked reductions — one per tail row — instead of a
        # 3-D gather (sublane-dynamic indexing is not a Mosaic op).
        n_valid = jnp.clip(vlen - i * block_t, 0.0, float(bt))  # (1, c)
        rows = jax.lax.broadcasted_iota(jnp.float32, (bt + w, 1), 0)
        new_s, new_s2 = [], []
        for j in range(w):
            hit = rows == (n_valid + float(j))  # (bt+w, c), exact f32
            new_s.append(jnp.sum(jnp.where(hit, s_full, 0.0), axis=0,
                                 keepdims=True))
            new_s2.append(jnp.sum(jnp.where(hit, s2_full, 0.0), axis=0,
                                  keepdims=True))
        tail_s[...] = jnp.concatenate(new_s, axis=0)
        tail_s2[...] = jnp.concatenate(new_s2, axis=0)
    else:
        tail_s[w - 1:w, :] = s[block_t - 1:block_t]
        if need_s2:
            tail_s2[w - 1:w, :] = s2[block_t - 1:block_t]

    # ---- selection-masked bitmask + weighted vote ----------------------
    bits = jnp.zeros((bt, c), jnp.int32)
    votew = jnp.zeros((bt, c), jnp.float32)
    totw = jnp.zeros((1, c), jnp.float32)
    for d, name in enumerate(detectors):
        wrow = sel_ref[d:d + 1, :].astype(jnp.float32)  # (1, bc)
        f = flags[name] & (wrow > 0.0) & valid
        bits = bits + f.astype(jnp.int32) * (1 << d)
        votew = votew + f.astype(jnp.float32) * wrow
        totw = totw + wrow
    vote = (votew >= thr) & (totw > 0.0) & valid
    bits_ref[...] = bits
    vote_ref[...] = vote.astype(jnp.int8)

    # final carries once per strip, at its last time block (the aux/k0
    # donation discipline of `teda_scan.py`)
    @pl.when(i == pl.num_programs(1) - 1)
    def _fin():
        fk_ref[...] = k0 + vlen  # vlen pre-clamped to [0, T] by wrapper
        aux_out_ref[0:w, :] = tail_s[...]
        aux_out_ref[w:2 * w, :] = tail_s2[...]
        aux_out_ref[2 * w:2 * w + 1, :] = var_c[...]


def ensemble_pallas_call(x: jnp.ndarray, vlen: jnp.ndarray,
                         k0: jnp.ndarray, m: jnp.ndarray,
                         thr: jnp.ndarray, sel: jnp.ndarray,
                         aux: jnp.ndarray, *, block_t: int,
                         block_c: int = 0, window: int,
                         detectors: tuple, interpret: bool,
                         donate: bool = True):
    """Raw pallas_call.  x (T, C) pre-padded; vlen / k0 / m / thr are
    (1, C) per-channel carry rows; sel is the (K, C) selection-weight
    block; aux the (2*window + 1, C) shared-state block.  `detectors`
    is the static ensemble tuple — bit d of the emitted mask is
    detectors[d].  Returns (det_bits, vote, fk, aux_final).  With
    `donate`, k0 aliases fk and aux aliases aux_final — callers must
    treat those operands as consumed.
    """
    t_len, c = x.shape
    if not block_c:
        block_c = c
    n_aux = 2 * window + 1
    assert (t_len % block_t == 0 and block_t % 8 == 0
            and c % block_c == 0 and block_c % 128 == 0), (
        "wrapper must pad: T % block_t == 0, block_t % 8 == 0, "
        "C % block_c == 0, block_c % 128 == 0")
    assert aux.shape == (n_aux, c) and sel.shape == (len(detectors), c)
    grid = (c // block_c, t_len // block_t)

    row_spec = block_spec((block_t, block_c), lambda j, i: (i, j),
                          memory_space=pltpu.VMEM)
    carry_spec = block_spec((1, block_c), lambda j, i: (0, j),
                            memory_space=pltpu.VMEM)
    sel_spec = block_spec((len(detectors), block_c), lambda j, i: (0, j),
                          memory_space=pltpu.VMEM)
    aux_spec = block_spec((n_aux, block_c), lambda j, i: (0, j),
                          memory_space=pltpu.VMEM)
    f32 = jnp.float32
    out_shape = [
        jax.ShapeDtypeStruct((t_len, c), jnp.int32),  # detector bitmask
        jax.ShapeDtypeStruct((t_len, c), jnp.int8),   # fused vote
        jax.ShapeDtypeStruct((1, c), f32),            # final k
        jax.ShapeDtypeStruct((n_aux, c), f32),        # final aux block
    ]
    out_specs = [row_spec, row_spec, carry_spec, aux_spec]
    aliases = {}
    if donate:
        # k0 -> fk, aux -> final aux (inputs 2 / 6); vlen, m, thr and
        # sel are read by every grid step — never donated
        aliases = {2: 2, 6: 3}
    kernel = functools.partial(ensemble_scan_kernel, block_t=block_t,
                               window=window, detectors=tuple(detectors))
    compiler_params = None
    if not interpret:
        compiler_params = tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, carry_spec, carry_spec, carry_spec,
                  carry_spec, sel_spec, aux_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((window, block_c), f32),  # S prefix tail
            pltpu.VMEM((window, block_c), f32),  # S2 prefix tail
            pltpu.VMEM((1, block_c), f32),       # TEDA variance carry
        ],
        input_output_aliases=aliases,
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, vlen, k0, m, thr, sel, aux)
