"""Shared ragged-stream + kernel-layout helpers.

One definition of the contract every Pallas wrapper speaks: per-channel
valid-length normalization (`vlen_vec`), post-kernel verdict masking of
ragged tails (`mask_ragged_rows`), and the lane/sublane layout padding
(`pad_layout`, `norm_block_c`, `round_up`).  `kernels/ops.py` (the TEDA
wrappers) and `detectors/ensemble.py` (the fused ensemble wrapper) both
consume these — previously each carried its own copy, and a semantics
fix in one could silently miss the other.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["default_interpret", "round_up", "norm_block_c", "vlen_vec",
           "mask_ragged_rows", "pad_layout"]


def default_interpret() -> bool:
    """Interpret (CPU emulation) unless a real TPU backend is attached."""
    return jax.default_backend() != "tpu"


def round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def norm_block_c(block_c) -> int:
    """Normalize the channel-block width to a static int (0 = one strip)."""
    bc = int(block_c or 0)
    if bc and bc % 128 != 0:
        raise ValueError(f"block_c must be a multiple of 128, got {bc}")
    return bc


def vlen_vec(valid_lens, t_len: int, c: int, dtype):
    """Normalize `valid_lens` to a per-channel (C,) vector.

    Returns (vlen, ragged): `ragged` is the *static* flag that the
    caller asked for a valid-length restriction at all (None means the
    whole chunk is valid for every channel — the uniform fast case that
    skips the ragged verdict masking).  Values are clamped to [0, T]:
    the kernels freeze each carry at the padded time extent, so an
    unclamped vlen would make the returned k disagree with the state
    the carries actually hold (and traced callers skip the engine's
    host-side bounds check).
    """
    if valid_lens is None:
        return jnp.full((c,), t_len, dtype), False
    vl = jnp.clip(jnp.asarray(valid_lens, dtype), 0, t_len)
    vl = vl.reshape(-1) if vl.ndim else vl
    return jnp.broadcast_to(vl, (c,)), True


def mask_ragged_rows(outlier, vlen, t_len: int):
    """No verdicts beyond a channel's valid length (eq (6) gate)."""
    rows = jnp.arange(t_len, dtype=vlen.dtype)[:, None]
    return jnp.logical_and(outlier, rows < vlen[None, :])


def pad_layout(x, rows, block_t, lane_pad, block_c=0):
    """Shared kernel-layout padding: time to block_t, lanes to lane_pad
    and (when channel-blocking) to a block_c multiple.

    `rows` are per-channel (C,) carry vectors, returned as padded (1, C')
    rows.  Returns (padded x, padded rows, un-pad slice).  Every wrapper
    routes through this so the layout contract has one definition; the
    valid length is passed to the kernel, which masks the padded tail.
    """
    t_len, c = x.shape
    tp = round_up(max(t_len, block_t), block_t)
    cp = round_up(c, lane_pad)
    if block_c:
        cp = round_up(cp, block_c)
    xp = jnp.pad(x, ((0, tp - t_len), (0, cp - c)))
    rp = tuple(jnp.pad(r.reshape(1, c), ((0, 0), (0, cp - c)))
               for r in rows)
    return xp, rp, (slice(0, t_len), slice(0, c))
