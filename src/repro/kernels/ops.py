"""Jitted public wrapper around the TEDA Pallas kernel.

Handles layout (lane/sublane padding), state threading, dtype policy and
interpret-mode selection; returns the same (TedaState, dict) contract as
the rest of `repro.core`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.teda import TedaState
from repro.fixedpoint.qformat import QFormat, div_qi
from repro.fixedpoint.teda_q import msq1_const
from repro.kernels.teda_scan import teda_pallas_call
from repro.kernels.teda_q_scan import teda_q_pallas_call

__all__ = ["teda_scan_tpu", "teda_q_scan_tpu", "default_interpret"]


def default_interpret() -> bool:
    """Interpret (CPU emulation) unless a real TPU backend is attached."""
    return jax.default_backend() != "tpu"


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _pad_layout(x, init_a, init_b, block_t, lane_pad):
    """Shared kernel-layout padding: time to block_t, lanes to lane_pad.

    Returns the padded (x, init_a, init_b), the un-pad slice for
    (T, C)-shaped outputs, and the padded time length.  All three
    public wrappers route through this so the layout contract has one
    definition.
    """
    t_len, c = x.shape
    tp = _round_up(max(t_len, block_t), block_t)
    cp = _round_up(c, lane_pad)
    xp = jnp.pad(x, ((0, tp - t_len), (0, cp - c)))
    ap = jnp.pad(init_a, ((0, 0), (0, cp - c)))
    bp = jnp.pad(init_b, ((0, 0), (0, cp - c)))
    return xp, ap, bp, (slice(0, t_len), slice(0, c)), tp


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret", "lane_pad"))
def _padded_call(x, scal, init_sum, init_var, *, block_t, interpret,
                 lane_pad):
    xp, sp, vp, sl, _ = _pad_layout(x, init_sum, init_var, block_t,
                                    lane_pad)
    mean, var, ecc, outlier = teda_pallas_call(
        xp, scal, sp, vp, block_t=block_t, interpret=interpret)
    return mean[sl], var[sl], ecc[sl], outlier[sl]


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret", "lane_pad"))
def _padded_verdict_call(x, scal, init_sum, init_var, *, block_t,
                         interpret, lane_pad):
    t_len, c = x.shape
    xp, sp, vp, sl, tp = _pad_layout(x, init_sum, init_var, block_t,
                                     lane_pad)
    ecc, outlier, fsum, fvar = teda_pallas_call(
        xp, scal, sp, vp, block_t=block_t, interpret=interpret,
        verdict_only=True)
    # final state must come from the last VALID row, not the padded tail:
    # recompute it from the t_len-1 row semantics (padding adds zeros to
    # the sum; subtracting nothing needed because mean = sum/k uses k of
    # valid rows only when t_len % block_t == 0; otherwise derive from
    # ecc/outlier outputs upstream). We simply return the padded-final
    # carries when no padding was added, else None.
    exact = tp == t_len
    return ecc[sl], outlier[sl], (fsum[:, :c] if exact else None), (
        fvar[:, :c] if exact else None)


def teda_scan_verdict(x: jnp.ndarray, m: float | jnp.ndarray = 3.0,
                      state: Optional[TedaState] = None, *,
                      block_t: int = 256,
                      interpret: Optional[bool] = None,
                      lane_pad: int = 128):
    """Slim-output TEDA kernel: (ecc, outlier[, final state]).

    HBM write traffic per sample drops from 16B (mean+var+ecc+i32 flag)
    to 5B (ecc + i8 flag) — the memory-roofline optimization recorded in
    EXPERIMENTS.md §Perf. Final state is returned only when T divides
    block_t exactly (the monitoring hot path uses fixed-size chunks).
    """
    if interpret is None:
        interpret = default_interpret()
    t_len, c = x.shape
    if state is None:
        k0 = jnp.float32(0.0)
        init_sum = jnp.zeros((1, c), jnp.float32)
        init_var = jnp.zeros((1, c), jnp.float32)
    else:
        k0 = state.k.reshape(-1)[0].astype(jnp.float32)
        init_sum = (state.mean[..., 0] * state.k).reshape(1, c)
        init_var = state.var.reshape(1, c)
    scal = jnp.stack([jnp.asarray(m, jnp.float32), k0])
    ecc, outlier, fsum, fvar = _padded_verdict_call(
        x, scal, init_sum, init_var, block_t=block_t,
        interpret=interpret, lane_pad=lane_pad)
    final = None
    if fsum is not None:
        kf = k0 + t_len
        final = TedaState(k=jnp.full((c,), kf),
                          mean=(fsum[0] / kf)[:, None], var=fvar[0])
    return final, {"ecc": ecc, "outlier": outlier.astype(bool)}


def teda_scan_tpu(x: jnp.ndarray, m: float | jnp.ndarray = 3.0,
                  state: Optional[TedaState] = None, *,
                  block_t: int = 256, interpret: Optional[bool] = None,
                  lane_pad: int = 128) -> Tuple[TedaState, dict]:
    """TEDA over x (T, C) — C independent univariate streams.

    Returns (final TedaState with mean (C, 1) / var (C,), outputs dict of
    (T, C) arrays: mean, var, ecc, zeta, threshold, outlier).
    """
    if interpret is None:
        interpret = default_interpret()
    t_len, c = x.shape
    if state is None:
        k0 = jnp.float32(0.0)
        init_sum = jnp.zeros((1, c), jnp.float32)
        init_var = jnp.zeros((1, c), jnp.float32)
    else:
        k0 = state.k.reshape(-1)[0].astype(jnp.float32)
        init_sum = (state.mean[..., 0] * state.k).reshape(1, c)
        init_var = state.var.reshape(1, c)
    scal = jnp.stack([jnp.asarray(m, jnp.float32), k0])

    mean, var, ecc, outlier = _padded_call(
        x, scal, init_sum, init_var, block_t=block_t,
        interpret=interpret, lane_pad=lane_pad)

    k_all = k0 + jnp.arange(1, t_len + 1, dtype=jnp.float32)
    zeta = ecc * 0.5
    thr = (jnp.asarray(m, jnp.float32) ** 2 + 1.0) / (2.0 * k_all)[:, None]
    final = TedaState(
        k=jnp.full((c,), k0 + t_len),
        mean=mean[-1][:, None],
        var=var[-1],
    )
    outs = {"mean": mean, "var": var, "ecc": ecc, "zeta": zeta,
            "threshold": jnp.broadcast_to(thr, ecc.shape),
            "outlier": outlier.astype(bool)}
    return final, outs


# ------------------------------------------------------- Q-format kernel
@functools.partial(jax.jit,
                   static_argnames=("fmt", "block_t", "interpret",
                                    "lane_pad"))
def _padded_q_call(xq, scal, init_mean, init_var, *, fmt, block_t,
                   interpret, lane_pad):
    # zero-padded channels stay at mean=var=0 (var>0 guard absorbs them)
    xp, mp, vp, sl, _ = _pad_layout(xq, init_mean, init_var, block_t,
                                    lane_pad)
    mean, var, ecc, outlier = teda_q_pallas_call(
        xp, scal, mp, vp, fmt=fmt, block_t=block_t, interpret=interpret)
    return mean[sl], var[sl], ecc[sl], outlier[sl]


def teda_q_scan_tpu(x: jnp.ndarray, fmt: QFormat,
                    m: float | jnp.ndarray = 3.0,
                    state: Optional[TedaState] = None, *,
                    block_t: int = 256, interpret: Optional[bool] = None,
                    lane_pad: int = 128) -> Tuple[TedaState, dict]:
    """Bit-accurate Q-format TEDA kernel over x (T, C) channel streams.

    Float input is quantized through `fmt`; int32 input is taken as
    already-quantized Q values.  Bit-exact with the pure-JAX
    `fixedpoint.teda_q_scan_chan` (same per-row step function).  The
    final state is read from the last *valid* output row, so time
    padding never leaks into carried state.  Returns (TedaState with Q
    int32 mean (C, 1) / var (C,), outputs dict of (T, C) arrays: mean,
    var, ecc, zeta, threshold — all Q int32 — and bool outlier).
    """
    fmt.validate()
    if interpret is None:
        interpret = default_interpret()
    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        xq = fmt.quantize(x)
    else:
        xq = jnp.asarray(x, jnp.int32)
    t_len, c = xq.shape
    if state is None:
        k0 = jnp.int32(0)
        init_mean = jnp.zeros((1, c), jnp.int32)
        init_var = jnp.zeros((1, c), jnp.int32)
    else:
        k0 = jnp.asarray(state.k).reshape(-1)[0].astype(jnp.int32)
        init_mean = state.mean[..., 0].reshape(1, c).astype(jnp.int32)
        init_var = state.var.reshape(1, c).astype(jnp.int32)
    msq1 = jnp.asarray(msq1_const(fmt, m), jnp.int32)
    scal = jnp.stack([msq1, k0])

    mean, var, ecc, outlier = _padded_q_call(
        xq, scal, init_mean, init_var, fmt=fmt, block_t=block_t,
        interpret=interpret, lane_pad=lane_pad)

    k_all = k0 + jnp.arange(1, t_len + 1, dtype=jnp.int32)
    zeta = ecc >> 1
    thr = div_qi(fmt, jnp.broadcast_to(msq1, k_all.shape),
                 2 * k_all)[:, None]
    final = TedaState(
        k=jnp.full((c,), k0 + t_len, jnp.int32),
        mean=mean[-1][:, None],
        var=var[-1],
    )
    outs = {"mean": mean, "var": var, "ecc": ecc, "zeta": zeta,
            "threshold": jnp.broadcast_to(thr, ecc.shape),
            "outlier": outlier.astype(bool)}
    return final, outs
