"""Jitted public wrappers around the TEDA Pallas kernels.

One contract layer for all four kernel entry points (full float, slim
verdict-only float, full Q-format, slim verdict-only Q-format):
`state_vectors` normalizes carried state to honest per-channel (C,)
vectors — a per-channel `k` is preserved end-to-end, never collapsed to
a shared scalar — and `_pad_layout` owns the lane/sublane padding.  The
kernels mask padded time rows internally against the true valid length,
so the final state is *always* returned, for every T (no `final=None`
path remains).

`m` may be a scalar or a per-channel (C,) vector (multi-tenant slots
run different sensitivity levels in one batch).  The kernels take a
scalar threshold constant in SMEM, but only the OUTLIER comparison
depends on it — state and eccentricity do not — so the vector case
re-evaluates eq (6) outside the kernel from the kernel's own `ecc`,
with the exact same arithmetic (`div_qi` on the Q path), keeping the
per-slot verdicts bit-consistent with a scalar-`m` run of that slot.

`valid_lens` may likewise be a scalar or a per-channel (C,) vector:
vlen[c] leading rows of channel c are valid (0..T), so one fused call
can retire a *different* number of samples per slot — each channel's
carried state freezes after its own vlen[c] rows, bit-exact on the Q
path with a per-channel isolated run of that prefix.  `None` (the
uniform fast case: the whole chunk is valid for every channel) skips
the ragged verdict masking entirely and is bit-identical to a
broadcast vlen=T vector — the kernels have a single vector code path.
Per-sample outputs at rows >= vlen[c] are unspecified except `outlier`,
which is guaranteed False there.

`block_c` tiles the channel axis into independent grid strips (the
kernels' 2-D `(channel-block, time-block)` grid); channels are fully
independent in TEDA, so every block_c produces identical bits — `None`
keeps one strip spanning all lanes (the 1-D-grid behavior).  On
multi-core TPUs the strips are the unit of core parallelism; the
channel extent is padded up to a block multiple and padded lanes carry
vlen=0 (frozen at state zero, no verdicts).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.teda import TedaState
from repro.fixedpoint.qformat import QFormat, div_qi
from repro.fixedpoint.teda_q import msq1_const
from repro.kernels.ragged import (default_interpret, mask_ragged_rows,
                                  norm_block_c, pad_layout, round_up,
                                  vlen_vec)
from repro.kernels.teda_scan import teda_pallas_call
from repro.kernels.teda_q_scan import teda_q_pallas_call

__all__ = ["teda_scan_tpu", "teda_scan_verdict", "teda_q_scan_tpu",
           "teda_q_scan_verdict", "default_interpret", "state_vectors"]

# the helpers moved to `kernels/ragged.py` (shared with the ensemble
# wrapper); the underscore aliases remain for existing importers
_round_up = round_up
_norm_block_c = norm_block_c
_vlen_vec = vlen_vec
_mask_ragged_rows = mask_ragged_rows
_pad_layout = pad_layout


def state_vectors(state: Optional[TedaState], c: int, dtype
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Normalize carried state to per-channel (k, mean, var) (C,) vectors.

    Accepts `k` as a scalar or per-channel vector (multi-tenant slots sit
    at different stream positions), `mean` as (C,), (C, 1) or scalar, and
    `var` likewise.  This is the single state-layout definition shared by
    every kernel wrapper and by `repro.engine`.
    """
    if state is None:
        z = jnp.zeros((c,), dtype)
        return z, z, z

    def vec(v):
        v = jnp.asarray(v, dtype)
        v = v.reshape(-1) if v.ndim else v
        return jnp.broadcast_to(v, (c,))

    return vec(state.k), vec(state.mean), vec(state.var)


def _k_rows(k0, t_len, dtype):
    """Global iteration index of every row: k0 + 1 .. k0 + T, (T, C)."""
    return k0[None, :] + jnp.arange(1, t_len + 1, dtype=dtype)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_c", "interpret",
                                    "lane_pad", "verdict_only"))
def _padded_call(x, m, vlen, k0, sum0, var0, *, block_t, block_c,
                 interpret, lane_pad, verdict_only):
    # lane-padded channels get vlen=0 from the zero pad: frozen at state 0
    t_len, c = x.shape
    xp, (vlp, kp, sp, vp), sl = _pad_layout(x, (vlen, k0, sum0, var0),
                                            block_t, lane_pad, block_c)
    scal = jnp.asarray(m, jnp.float32).reshape(1)
    outs = teda_pallas_call(xp, scal, vlp, kp, sp, vp, block_t=block_t,
                            block_c=block_c, interpret=interpret,
                            verdict_only=verdict_only)
    rows, (fk, fsum, fvar) = outs[:-3], outs[-3:]
    return tuple(r[sl] for r in rows) + (fk[0, :c], fsum[0, :c],
                                         fvar[0, :c])


@functools.partial(jax.jit,
                   static_argnames=("fmt", "block_t", "block_c",
                                    "interpret", "lane_pad",
                                    "verdict_only"))
def _padded_q_call(xq, msq1, vlen, k0, mean0, var0, *, fmt, block_t,
                   block_c, interpret, lane_pad, verdict_only):
    # zero-padded channels stay at mean=var=0 (vlen=0: frozen carries)
    t_len, c = xq.shape
    xp, (vlp, kp, mp, vp), sl = _pad_layout(xq, (vlen, k0, mean0, var0),
                                            block_t, lane_pad, block_c)
    scal = jnp.asarray(msq1, jnp.int32).reshape(1)
    outs = teda_q_pallas_call(xp, scal, vlp, kp, mp, vp, fmt=fmt,
                              block_t=block_t, block_c=block_c,
                              interpret=interpret,
                              verdict_only=verdict_only)
    rows, (fk, fmean, fvar) = outs[:-3], outs[-3:]
    return tuple(r[sl] for r in rows) + (fk[0, :c], fmean[0, :c],
                                         fvar[0, :c])


def teda_scan_verdict(x: jnp.ndarray, m: float | jnp.ndarray = 3.0,
                      state: Optional[TedaState] = None, *,
                      valid_lens=None, block_t: int = 256,
                      block_c: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      lane_pad: int = 128):
    """Slim-output TEDA kernel: (final state, {ecc, outlier}).

    HBM write traffic per sample drops from 16B (mean+var+ecc+i32 flag)
    to 5B (ecc + i8 flag) — the memory-roofline optimization recorded in
    EXPERIMENTS.md §Perf.  The kernel masks each channel's ragged tail
    against its valid length, so a bit-exact final state is returned
    for every T — this is the engine's float hot path.  `m` may be
    per-channel (C,); eq (6) is then re-evaluated outside the kernel
    (see module docs).  `valid_lens` may be a scalar or per-channel
    (C,) vector of leading valid row counts (see module docs).
    `block_c` tiles the channel axis into parallel grid strips.
    """
    if interpret is None:
        interpret = default_interpret()
    x = jnp.asarray(x)
    t_len, c = x.shape
    k0, mean0, var0 = state_vectors(state, c, jnp.float32)
    vlen, ragged = _vlen_vec(valid_lens, t_len, c, jnp.float32)
    m_arr = jnp.asarray(m, jnp.float32)
    per_slot = m_arr.ndim > 0
    ecc, outlier, fk, fsum, fvar = _padded_call(
        x, jnp.float32(0.0) if per_slot else m_arr, vlen, k0, mean0 * k0,
        var0, block_t=block_t, block_c=_norm_block_c(block_c),
        interpret=interpret, lane_pad=lane_pad, verdict_only=True)
    if per_slot:
        k_all = _k_rows(k0, t_len, jnp.float32)
        thr = (m_arr[None, :] * m_arr[None, :] + 1.0) / (2.0 * k_all)
        outlier = jnp.logical_and(ecc * 0.5 > thr, k_all >= 2.0)
    if ragged:
        outlier = _mask_ragged_rows(outlier, vlen, t_len)
    final = TedaState(k=fk, mean=(fsum / jnp.maximum(fk, 1.0))[:, None],
                      var=fvar)
    return final, {"ecc": ecc, "outlier": outlier.astype(bool)}


def teda_scan_tpu(x: jnp.ndarray, m: float | jnp.ndarray = 3.0,
                  state: Optional[TedaState] = None, *,
                  valid_lens=None, block_t: int = 256,
                  block_c: Optional[int] = None,
                  interpret: Optional[bool] = None,
                  lane_pad: int = 128) -> Tuple[TedaState, dict]:
    """TEDA over x (T, C) — C independent univariate streams.

    Returns (final TedaState with k (C,) / mean (C, 1) / var (C,),
    outputs dict of (T, C) arrays: mean, var, ecc, zeta, threshold,
    outlier).  Per-channel state (including k) carries exactly across
    calls for arbitrary chunk lengths.  `m` may be per-channel (C,);
    eq (6) is then re-evaluated outside the kernel (see module docs).
    `valid_lens` may be a scalar or per-channel (C,) vector of leading
    valid row counts — one call retires vlen[c] samples per channel.
    `block_c` tiles the channel axis into parallel grid strips.
    """
    if interpret is None:
        interpret = default_interpret()
    x = jnp.asarray(x)
    t_len, c = x.shape
    k0, mean0, var0 = state_vectors(state, c, jnp.float32)
    vlen, ragged = _vlen_vec(valid_lens, t_len, c, jnp.float32)
    m_arr = jnp.asarray(m, jnp.float32)
    per_slot = m_arr.ndim > 0

    mean, var, ecc, outlier, fk, fsum, fvar = _padded_call(
        x, jnp.float32(0.0) if per_slot else m_arr, vlen, k0, mean0 * k0,
        var0, block_t=block_t, block_c=_norm_block_c(block_c),
        interpret=interpret, lane_pad=lane_pad, verdict_only=False)

    k_all = _k_rows(k0, t_len, jnp.float32)
    zeta = ecc * 0.5
    thr = (m_arr ** 2 + 1.0) / (2.0 * k_all)
    if per_slot:
        outlier = jnp.logical_and(zeta > thr, k_all >= 2.0)
    if ragged:
        outlier = _mask_ragged_rows(outlier, vlen, t_len)
    final = TedaState(k=fk, mean=(fsum / jnp.maximum(fk, 1.0))[:, None],
                      var=fvar)
    outs = {"mean": mean, "var": var, "ecc": ecc, "zeta": zeta,
            "threshold": thr, "outlier": outlier.astype(bool)}
    return final, outs


def _quantize_in(x, fmt: QFormat):
    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        return fmt.quantize(x)
    return jnp.asarray(x, jnp.int32)


def teda_q_scan_verdict(x: jnp.ndarray, fmt: QFormat,
                        m: float | jnp.ndarray = 3.0,
                        state: Optional[TedaState] = None, *,
                        valid_lens=None, block_t: int = 256,
                        block_c: Optional[int] = None,
                        interpret: Optional[bool] = None,
                        lane_pad: int = 128) -> Tuple[TedaState, dict]:
    """Slim-output Q-format TEDA kernel: (final state, {ecc, outlier}).

    The serving engine consumes only the verdict stream and the carried
    state, and the full wrapper's extra work is expensive out of all
    proportion on the Q path: per-row mean/var HBM writes inside the
    kernel, plus a host-side (T, C) *bit-serial* `div_qi` re-derivation
    of the eq (6) threshold that the engine never reads (~WL iterations
    per element — it dominated the PR 6 pallas-q profile).  This wrapper
    skips both: with scalar `m` the kernel's own in-loop verdict (the
    same `_q_step_u` bits) is returned as-is, so `ecc`/`outlier`/final
    state are bit-exact with `teda_q_scan_tpu` and with the pure-JAX
    `teda_q_scan_chan` oracle.  Per-channel `m` still re-evaluates
    eq (6) outside with the same `div_qi` arithmetic (only then is the
    threshold actually needed).  `block_c` tiles the channel axis into
    parallel grid strips.  This is the engine's Q hot path.
    """
    fmt.validate()
    if interpret is None:
        interpret = default_interpret()
    xq = _quantize_in(x, fmt)
    t_len, c = xq.shape
    k0, mean0, var0 = state_vectors(state, c, jnp.int32)
    vlen, ragged = _vlen_vec(valid_lens, t_len, c, jnp.int32)
    msq1 = msq1_const(fmt, m)
    per_slot = jnp.asarray(msq1).ndim > 0

    ecc, outlier, fk, fmean, fvar = _padded_q_call(
        xq, jnp.int32(0) if per_slot else msq1, vlen, k0, mean0, var0,
        fmt=fmt, block_t=block_t, block_c=_norm_block_c(block_c),
        interpret=interpret, lane_pad=lane_pad, verdict_only=True)

    if per_slot:
        k_all = _k_rows(k0, t_len, jnp.int32)
        thr = div_qi(fmt, jnp.broadcast_to(jnp.asarray(msq1, jnp.int32),
                                           k_all.shape), 2 * k_all)
        outlier = jnp.logical_and(ecc >> 1 > thr, k_all >= 2)
    if ragged:
        outlier = _mask_ragged_rows(outlier, vlen, t_len)
    final = TedaState(k=fk, mean=fmean[:, None], var=fvar)
    return final, {"ecc": ecc, "outlier": outlier.astype(bool)}


def teda_q_scan_tpu(x: jnp.ndarray, fmt: QFormat,
                    m: float | jnp.ndarray = 3.0,
                    state: Optional[TedaState] = None, *,
                    valid_lens=None, block_t: int = 256,
                    block_c: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    lane_pad: int = 128) -> Tuple[TedaState, dict]:
    """Bit-accurate Q-format TEDA kernel over x (T, C) channel streams.

    Float input is quantized through `fmt`; int32 input is taken as
    already-quantized Q values.  Bit-exact with the pure-JAX
    `fixedpoint.teda_q_scan_chan` (same per-row step function).  The
    kernel freezes the carried state on padded tail rows, so the final
    state is exact — and always returned — for every T.  Returns
    (TedaState with k (C,) int32, Q int32 mean (C, 1) / var (C,),
    outputs dict of (T, C) arrays: mean, var, ecc, zeta, threshold — all
    Q int32 — and bool outlier).  `m` may be per-channel (C,); eq (6) is
    then re-evaluated outside the kernel with the same `div_qi`
    arithmetic, so per-slot verdicts stay bit-exact (see module docs).
    `valid_lens` may be a scalar or per-channel (C,) vector of leading
    valid row counts — one fused call retires vlen[c] samples per
    channel, bit-exact with per-channel isolated runs of each prefix.
    `block_c` tiles the channel axis into parallel grid strips.  The
    serving hot path is `teda_q_scan_verdict`; this full wrapper keeps
    the complete (T, C) Q trajectory (mean/var/zeta/threshold) for
    oracle tests and offline analysis.
    """
    fmt.validate()
    if interpret is None:
        interpret = default_interpret()
    xq = _quantize_in(x, fmt)
    t_len, c = xq.shape
    k0, mean0, var0 = state_vectors(state, c, jnp.int32)
    vlen, ragged = _vlen_vec(valid_lens, t_len, c, jnp.int32)
    msq1 = msq1_const(fmt, m)
    per_slot = jnp.asarray(msq1).ndim > 0

    mean, var, ecc, outlier, fk, fmean, fvar = _padded_q_call(
        xq, jnp.int32(0) if per_slot else msq1, vlen, k0, mean0, var0,
        fmt=fmt, block_t=block_t, block_c=_norm_block_c(block_c),
        interpret=interpret, lane_pad=lane_pad, verdict_only=False)

    k_all = _k_rows(k0, t_len, jnp.int32)
    zeta = ecc >> 1
    thr = div_qi(fmt, jnp.broadcast_to(jnp.asarray(msq1, jnp.int32),
                                       k_all.shape), 2 * k_all)
    if per_slot:
        outlier = jnp.logical_and(zeta > thr, k_all >= 2)
    if ragged:
        outlier = _mask_ragged_rows(outlier, vlen, t_len)
    final = TedaState(k=fk, mean=fmean[:, None], var=fvar)
    outs = {"mean": mean, "var": var, "ecc": ecc, "zeta": zeta,
            "threshold": thr, "outlier": outlier.astype(bool)}
    return final, outs
