"""Host-width exact image of the fixed-point bit-serial divider.

`repro.fixedpoint.qformat._div_mag` is the *model*: a restoring
shift-subtract long division, one quotient bit per iteration, mirroring
the FPGA divider clock-for-clock.  Running that model on a host vector
unit costs 31+FL tiny dependent ops per divide — the dominant cost of
the integer Pallas kernel once everything else is vectorized.

This module computes the *same function* with host arithmetic:

  * the first 31 iterations of the model stream the 31 magnitude bits
    of the numerator, after which the long-division invariant gives
    exactly `q = floor(n / d)`, `r = n mod d` — one hardware integer
    divide reproduces them;
  * the remaining `shift` iterations stream zeros — for the Q/Q
    configuration (shift = FL) they are kept as explicit restoring
    steps on the sub-32-bit remainder (the 51-bit dividend is never
    materialized, exactly like the model), for the Q/int configuration
    (shift = 0) there are none;
  * the round-half-up correction, the d == 0 saturation and the
    quotient-overflow (`lost`) tracking replicate the model's bitwise.

Bit-for-bit equality with `_div_mag` over the full operand range is a
tested invariant (tests/test_qdiv.py), so kernels built on these
stay bit-exact with the `teda_q_scan_chan` oracle — the dividers are
elementwise, and every element sees the same quotient either way.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.fixedpoint.qformat import QFormat

__all__ = ["fast_div_mag", "fast_div_qq", "fast_div_qi"]

_I32 = jnp.int32
_U32 = jnp.uint32


def fast_div_mag(n: jnp.ndarray, d: jnp.ndarray, shift: int,
                 rounding: str, qmax: int) -> jnp.ndarray:
    """floor((n << shift) / d) on uint32 magnitudes — `_div_mag` bits.

    n, d uint32 with n < 2^31; returns the quotient saturated to
    [0, qmax], rounded half-up when `rounding == "round"`.
    """
    n, d = jnp.broadcast_arrays(n, d)
    dz = d == 0  # the model's guard-free divider saturates on d == 0
    ds = jnp.where(dz, _U32(1), d)

    # iterations 0..30 of the model in one divide: q = n/d, r = n%d
    q = n // ds
    r = n - q * ds
    lost = jnp.zeros_like(n)

    # iterations 31..31+shift-1: dividend bits are zero, the remainder
    # stays below 2^31 (r < d), so only q can shed a high bit
    for _ in range(shift):
        lost = lost | (q >> _U32(31))
        r = r << _U32(1)
        ge = r >= ds
        q = (q << _U32(1)) | ge.astype(_U32)
        r = jnp.where(ge, r - ds, r)

    if rounding == "round":
        half_up = r >= (ds >> _U32(1)) + (ds & _U32(1))
        q2 = q + half_up.astype(_U32)
        lost = lost | (q2 < q).astype(_U32)
        q = q2
    return jnp.where(dz | (lost > 0) | (q > _U32(qmax)), _U32(qmax), q)


def fast_div_qq(fmt: QFormat, num: jnp.ndarray, den: jnp.ndarray
                ) -> jnp.ndarray:
    """Saturating Q / Q -> Q, bit-equal to `qformat.div_qq`."""
    num = jnp.asarray(num, _I32)
    den = jnp.asarray(den, _I32)
    num, den = jnp.broadcast_arrays(num, den)
    neg = (num < 0) != (den < 0)
    q = fast_div_mag(jnp.abs(num).astype(_U32), jnp.abs(den).astype(_U32),
                     fmt.frac_len, fmt.rounding, fmt.qmax)
    q = q.astype(_I32)
    return jnp.where(neg, -q, q)


def fast_div_qi(fmt: QFormat, num: jnp.ndarray, k: jnp.ndarray
                ) -> jnp.ndarray:
    """Saturating Q / int -> Q, bit-equal to `qformat.div_qi`."""
    num = jnp.asarray(num, _I32)
    k = jnp.asarray(k, _I32)
    num, k = jnp.broadcast_arrays(num, k)
    neg = (num < 0) != (k < 0)
    q = fast_div_mag(jnp.abs(num).astype(_U32), jnp.abs(k).astype(_U32),
                     0, fmt.rounding, fmt.qmax)
    q = q.astype(_I32)
    return jnp.where(neg, -q, q)
