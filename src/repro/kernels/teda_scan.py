"""Pallas TPU kernel: chunked-scan TEDA over multichannel streams.

TPU-native analog of the paper's FPGA pipeline (Fig. 1). The grid walks
time-chunks sequentially — the Mosaic pipeline overlaps the HBM->VMEM DMA
of chunk i+1 with compute on chunk i, which is exactly the role of the
FPGA's inter-module pipeline registers. Within a chunk, log-depth
Hillis-Steele doubling scans run over the sublane (time) axis, vectorized
across the 128-lane channel axis, so every VPU "cycle" retires
8x128 samples instead of the FPGA's 1.

Layout contract (enforced by ops.py):
  x: (T, C) with T % block_t == 0, C % 128 == 0, block_t % 8 == 0.
Carried state (running sum, running variance per channel) lives in VMEM
scratch across grid steps.  `m` arrives as an SMEM scalar; the
per-channel iteration offset `k0` and the per-channel valid length
`vlen` arrive as (1, C) carry rows, so every channel may sit at a
different stream position *and* retire a different number of samples in
one call (ragged multi-tenant slots; a uniform chunk is just a
broadcast vlen).  Rows of channel c at global index >= vlen[c] are
masked in-kernel (sum += 0; variance map = identity), so the final
carries — always emitted as (1, C) outputs — hold each channel's state
after exactly vlen[c] valid samples regardless of time padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["teda_scan_kernel", "teda_pallas_call", "tpu_compiler_params"]


def tpu_compiler_params(**kw):
    """Version-compatible Pallas TPU CompilerParams.

    The class is TPUCompilerParams on jax 0.4.x and CompilerParams on
    newer releases; without this shim the compiled (non-interpret) TPU
    path raises AttributeError on one side of the rename.
    """
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kw)


def _shift_down(v: jnp.ndarray, d: int, fill: float) -> jnp.ndarray:
    """Rows r >= d get v[r-d]; rows < d get `fill`. Static d."""
    bt, c = v.shape
    pad = jnp.full((d, c), fill, v.dtype)
    return jnp.concatenate([pad, v[: bt - d]], axis=0)


def _cumsum_rows(v: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum over axis 0 via doubling (log2(bt) steps)."""
    bt = v.shape[0]
    d = 1
    while d < bt:
        v = v + _shift_down(v, d, 0.0)
        d *= 2
    return v


def _affine_scan_rows(a: jnp.ndarray, b: jnp.ndarray):
    """Inclusive composition scan of row-wise affine maps v -> a*v + b.

    Returns (A, B) with y_r = A_r * y_0 + B_r solving the recurrence
    y_r = a_r y_{r-1} + b_r. Doubling with identity fill (1, 0).
    """
    bt = a.shape[0]
    d = 1
    while d < bt:
        a_sh = _shift_down(a, d, 1.0)
        b_sh = _shift_down(b, d, 0.0)
        # newer map (a, b) applied after older shifted map (a_sh, b_sh)
        a, b = a * a_sh, a * b_sh + b
        d *= 2
    return a, b


def teda_scan_kernel(scal_ref, x_ref, vlen_ref, init_k_ref, init_sum_ref,
                     init_var_ref, *out_refs, block_t: int,
                     verdict_only: bool = False):
    if verdict_only:
        # slim outputs: (ecc, outlier, final_sum, final_var) — HBM write
        # traffic drops from 16B to ~5B per sample (see EXPERIMENTS §Perf)
        ecc_ref, outlier_ref, fsum_ref, fvar_ref = out_refs[:4]
        sum_carry, var_carry = out_refs[4:]
        mean_ref = var_ref = None
    else:
        (mean_ref, var_ref, ecc_ref, outlier_ref, fsum_ref,
         fvar_ref) = out_refs[:6]
        sum_carry, var_carry = out_refs[6:]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_carry[...] = init_sum_ref[...].astype(jnp.float32)
        var_carry[...] = init_var_ref[...].astype(jnp.float32)

    m = scal_ref[0]

    x = x_ref[...].astype(jnp.float32)  # (bt, C)
    bt, c = x.shape
    k0 = init_k_ref[...].astype(jnp.float32)  # (1, C) per-channel offset
    vlen = vlen_ref[...].astype(jnp.float32)  # (1, C) per-channel length
    t = jax.lax.broadcasted_iota(jnp.float32, (bt, 1), 0)
    g = i * block_t + t               # global row index, (bt, 1)
    valid = g < vlen                  # ragged-tail mask, (bt, C)
    k = k0 + g + 1.0                  # per-channel iteration index, (bt, C)

    # ---- MEAN module: eq (2) as a prefix sum ---------------------------
    # Invalid rows contribute nothing, so each channel's running sum
    # freezes at its last valid sample and the final carry is exact for
    # every ragged vlen vector.
    s = _cumsum_rows(jnp.where(valid, x, 0.0)) + sum_carry[...]
    mean = s / k

    # ---- VARIANCE module: eq (3) as an affine scan ---------------------
    d2 = (x - mean) ** 2
    first = k <= 1.0
    d2 = jnp.where(jnp.logical_or(first, ~valid), 0.0, d2)
    a = jnp.broadcast_to(jnp.where(first, 0.0, (k - 1.0) / k), (bt, c))
    a = jnp.where(valid, a, 1.0)  # identity map on padded rows
    b = d2 / k
    av, bv = _affine_scan_rows(a, b)
    var = av * var_carry[...] + bv

    # ---- ECCENTRICITY + OUTLIER modules: eqs (1), (5), (6) -------------
    safe = var > 0.0
    ecc = 1.0 / k + jnp.where(safe, d2 / (k * jnp.where(safe, var, 1.0)), 0.0)
    zeta = ecc * 0.5
    thr = (m * m + 1.0) / (2.0 * k)
    outlier = jnp.logical_and(zeta > thr, k >= 2.0)

    if verdict_only:
        ecc_ref[...] = ecc
        outlier_ref[...] = outlier.astype(jnp.int8)
    else:
        mean_ref[...] = mean
        var_ref[...] = var
        ecc_ref[...] = ecc
        outlier_ref[...] = outlier.astype(jnp.int32)

    fsum_ref[...] = s[block_t - 1:block_t]
    fvar_ref[...] = var[block_t - 1:block_t]
    sum_carry[...] = s[block_t - 1:block_t]
    var_carry[...] = var[block_t - 1:block_t]


def teda_pallas_call(x: jnp.ndarray, scal: jnp.ndarray, vlen: jnp.ndarray,
                     init_k: jnp.ndarray, init_sum: jnp.ndarray,
                     init_var: jnp.ndarray, *, block_t: int,
                     interpret: bool, verdict_only: bool = False):
    """Raw pallas_call. x (T, C) pre-padded; scal = [m] f32 (1,);
    vlen / init_k / init_sum / init_var are (1, C) per-channel carry
    rows — vlen[c] is the number of leading rows of channel c that are
    valid (0..T; a uniform chunk passes a broadcast T).

    Returns (mean, var, ecc, outlier, final_sum, final_var) or, with
    verdict_only, (ecc, outlier, final_sum, final_var).  The final
    carries are always populated (each channel's state after its own
    vlen[c] valid rows).
    """
    t_len, c = x.shape
    assert t_len % block_t == 0 and block_t % 8 == 0 and c % 128 == 0, (
        "ops.py must pad: T % block_t == 0, block_t % 8 == 0, C % 128 == 0")
    grid = (t_len // block_t,)

    row_spec = pl.BlockSpec((block_t, c), lambda i: (i, 0))
    carry_spec = pl.BlockSpec((1, c), lambda i: (0, 0))
    if verdict_only:
        out_shape = [
            jax.ShapeDtypeStruct((t_len, c), jnp.float32),  # ecc
            jax.ShapeDtypeStruct((t_len, c), jnp.int8),     # outlier
            jax.ShapeDtypeStruct((1, c), jnp.float32),      # final sum
            jax.ShapeDtypeStruct((1, c), jnp.float32),      # final var
        ]
        out_specs = [row_spec, row_spec, carry_spec, carry_spec]
    else:
        out_shape = [
            jax.ShapeDtypeStruct((t_len, c), jnp.float32),  # mean
            jax.ShapeDtypeStruct((t_len, c), jnp.float32),  # var
            jax.ShapeDtypeStruct((t_len, c), jnp.float32),  # ecc
            jax.ShapeDtypeStruct((t_len, c), jnp.int32),    # outlier
            jax.ShapeDtypeStruct((1, c), jnp.float32),      # final sum
            jax.ShapeDtypeStruct((1, c), jnp.float32),      # final var
        ]
        out_specs = [row_spec, row_spec, row_spec, row_spec,
                     carry_spec, carry_spec]
    kernel = functools.partial(teda_scan_kernel, block_t=block_t,
                               verdict_only=verdict_only)
    compiler_params = None
    if not interpret:
        compiler_params = tpu_compiler_params(
            dimension_semantics=("arbitrary",))  # sequential carry
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scal (1,)
            row_spec,  # x
            carry_spec,  # vlen
            carry_spec,  # init_k
            carry_spec,  # init_sum
            carry_spec,  # init_var
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((1, c), jnp.float32),  # running sum carry
            pltpu.VMEM((1, c), jnp.float32),  # running var carry
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(scal, x, vlen, init_k, init_sum, init_var)
