"""Pallas TPU kernel: chunked-scan TEDA over multichannel streams.

TPU-native analog of the paper's FPGA pipeline (Fig. 1). The grid is
2-D `(channel-block, time-block)`: the minor (time) axis walks
time-chunks sequentially — the Mosaic pipeline overlaps the HBM->VMEM
DMA of chunk i+1 with compute on chunk i, which is exactly the role of
the FPGA's inter-module pipeline registers — while the major axis tiles
the channel lanes into independent `block_c`-wide strips.  Channels
never exchange data, so the channel-block dimension is declared
`parallel`: on a multi-core TPU Mosaic splits the strips across cores
and a wide-C engine scales past a single core instead of serializing
the whole lane extent through one.  Within a chunk, log-depth
Hillis-Steele doubling scans run over the sublane (time) axis,
vectorized across the 128-lane channel axis, so every VPU "cycle"
retires 8x128 samples instead of the FPGA's 1.

Layout contract (enforced by ops.py):
  x: (T, C) with T % block_t == 0, C % block_c == 0,
  block_t % 8 == 0, block_c % 128 == 0.
Carried state (running sum, running variance per channel) lives in VMEM
scratch — one (1, block_c) row per channel strip, re-initialized when
the time axis restarts at the next strip.  `m` arrives as an SMEM
scalar; the per-channel iteration offset `k0` and the per-channel valid
length `vlen` arrive as (1, C) carry rows tiled per strip, so every
channel may sit at a different stream position *and* retire a different
number of samples in one call (ragged multi-tenant slots; a uniform
chunk is just a broadcast vlen).  Rows of channel c at global index >=
vlen[c] are masked in-kernel (sum += 0; variance map = identity), so
the final carries — always emitted as (1, C) outputs, written once at
each strip's last time block — hold each channel's state after exactly
vlen[c] valid samples regardless of time padding.

Donation contract (`input_output_aliases`, wired by ops.py): the
k/sum/var carry-row inputs alias the final-state outputs (`k0` -> the
in-kernel final-k row, `init_sum` -> final sum, `init_var` -> final
var), and the (T, C) sample buffer `x` aliases the first (T, C) output
when dtypes agree — the stream buffer is consumed by the call, so the
kernel's HBM working set is the outputs alone.  Aliasing the carries is
safe because they are only *read* at each strip's first time block and
only *written* at its last; `vlen` is read by every grid step and has
no output successor, so it is the one carry row that stays read-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["teda_scan_kernel", "teda_pallas_call", "tpu_compiler_params"]


def tpu_compiler_params(**kw):
    """Version-compatible Pallas TPU CompilerParams.

    The class is TPUCompilerParams on jax 0.4.x and CompilerParams on
    newer releases; without this shim the compiled (non-interpret) TPU
    path raises AttributeError on one side of the rename.
    """
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kw)


def block_spec(shape, index_map, memory_space=None):
    """Version-compatible BlockSpec with explicit memory-space placement.

    Blocked operands live in VMEM (the compute-adjacent space the tile
    sizes are budgeted against); older jax releases reject the
    `memory_space` kwarg next to a block shape, so placement degrades
    to the default on that side of the API.
    """
    if memory_space is None:
        return pl.BlockSpec(shape, index_map)
    try:
        return pl.BlockSpec(shape, index_map, memory_space=memory_space)
    except TypeError:  # old jax: block shape + memory space unsupported
        return pl.BlockSpec(shape, index_map)


def _shift_down(v: jnp.ndarray, d: int, fill: float) -> jnp.ndarray:
    """Rows r >= d get v[r-d]; rows < d get `fill`. Static d."""
    bt, c = v.shape
    pad = jnp.full((d, c), fill, v.dtype)
    return jnp.concatenate([pad, v[: bt - d]], axis=0)


def _cumsum_rows(v: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum over axis 0 via doubling (log2(bt) steps)."""
    bt = v.shape[0]
    d = 1
    while d < bt:
        v = v + _shift_down(v, d, 0.0)
        d *= 2
    return v


def _affine_scan_rows(a: jnp.ndarray, b: jnp.ndarray):
    """Inclusive composition scan of row-wise affine maps v -> a*v + b.

    Returns (A, B) with y_r = A_r * y_0 + B_r solving the recurrence
    y_r = a_r y_{r-1} + b_r. Doubling with identity fill (1, 0).
    """
    bt = a.shape[0]
    d = 1
    while d < bt:
        a_sh = _shift_down(a, d, 1.0)
        b_sh = _shift_down(b, d, 0.0)
        # newer map (a, b) applied after older shifted map (a_sh, b_sh)
        a, b = a * a_sh, a * b_sh + b
        d *= 2
    return a, b


def teda_scan_kernel(scal_ref, x_ref, vlen_ref, init_k_ref, init_sum_ref,
                     init_var_ref, *out_refs, block_t: int,
                     verdict_only: bool = False):
    if verdict_only:
        # slim outputs: (ecc, outlier, final k/sum/var) — HBM write
        # traffic drops from 16B to ~5B per sample (see EXPERIMENTS §Perf)
        ecc_ref, outlier_ref, fk_ref, fsum_ref, fvar_ref = out_refs[:5]
        sum_carry, var_carry = out_refs[5:]
        mean_ref = var_ref = None
    else:
        (mean_ref, var_ref, ecc_ref, outlier_ref, fk_ref, fsum_ref,
         fvar_ref) = out_refs[:7]
        sum_carry, var_carry = out_refs[7:]
    i = pl.program_id(1)  # time block (sequential, carry-chained)

    # a new channel strip restarts the time sweep: re-seed its carries
    @pl.when(i == 0)
    def _init():
        sum_carry[...] = init_sum_ref[...].astype(jnp.float32)
        var_carry[...] = init_var_ref[...].astype(jnp.float32)

    m = scal_ref[0]

    x = x_ref[...].astype(jnp.float32)  # (bt, block_c)
    bt, c = x.shape
    k0 = init_k_ref[...].astype(jnp.float32)  # (1, bc) per-channel offset
    vlen = vlen_ref[...].astype(jnp.float32)  # (1, bc) per-channel length
    t = jax.lax.broadcasted_iota(jnp.float32, (bt, 1), 0)
    g = i * block_t + t               # global row index, (bt, 1)
    valid = g < vlen                  # ragged-tail mask, (bt, bc)
    k = k0 + g + 1.0                  # per-channel iteration index, (bt, bc)

    # ---- MEAN module: eq (2) as a prefix sum ---------------------------
    # Invalid rows contribute nothing, so each channel's running sum
    # freezes at its last valid sample and the final carry is exact for
    # every ragged vlen vector.
    s = _cumsum_rows(jnp.where(valid, x, 0.0)) + sum_carry[...]
    mean = s / k

    # ---- VARIANCE module: eq (3) as an affine scan ---------------------
    d2 = (x - mean) ** 2
    first = k <= 1.0
    d2 = jnp.where(jnp.logical_or(first, ~valid), 0.0, d2)
    a = jnp.broadcast_to(jnp.where(first, 0.0, (k - 1.0) / k), (bt, c))
    a = jnp.where(valid, a, 1.0)  # identity map on padded rows
    b = d2 / k
    av, bv = _affine_scan_rows(a, b)
    var = av * var_carry[...] + bv

    # ---- ECCENTRICITY + OUTLIER modules: eqs (1), (5), (6) -------------
    safe = var > 0.0
    ecc = 1.0 / k + jnp.where(safe, d2 / (k * jnp.where(safe, var, 1.0)), 0.0)
    zeta = ecc * 0.5
    thr = (m * m + 1.0) / (2.0 * k)
    outlier = jnp.logical_and(zeta > thr, k >= 2.0)

    if verdict_only:
        ecc_ref[...] = ecc
        outlier_ref[...] = outlier.astype(jnp.int8)
    else:
        mean_ref[...] = mean
        var_ref[...] = var
        ecc_ref[...] = ecc
        outlier_ref[...] = outlier.astype(jnp.int32)

    sum_carry[...] = s[block_t - 1:block_t]
    var_carry[...] = var[block_t - 1:block_t]

    # final-state rows are written once, at the strip's last time block —
    # required for the carry-row donation (init rows are read at i == 0,
    # their aliased buffers overwritten only here), and one (1, C) HBM
    # write per strip instead of one per block
    @pl.when(i == pl.num_programs(1) - 1)
    def _fin():
        fk_ref[...] = k0 + vlen  # vlen pre-clamped to [0, T] by ops.py
        fsum_ref[...] = sum_carry[...]
        fvar_ref[...] = var_carry[...]


def teda_pallas_call(x: jnp.ndarray, scal: jnp.ndarray, vlen: jnp.ndarray,
                     init_k: jnp.ndarray, init_sum: jnp.ndarray,
                     init_var: jnp.ndarray, *, block_t: int,
                     block_c: int = 0, interpret: bool,
                     verdict_only: bool = False, donate: bool = True):
    """Raw pallas_call. x (T, C) pre-padded; scal = [m] f32 (1,);
    vlen / init_k / init_sum / init_var are (1, C) per-channel carry
    rows — vlen[c] is the number of leading rows of channel c that are
    valid (0..T; a uniform chunk passes a broadcast T, already clamped
    to [0, T]).  `block_c` tiles the channel axis into independent grid
    strips (0 means one strip spanning all C lanes — the 1-D grid).

    Returns (mean, var, ecc, outlier, fk, fsum, fvar) or, with
    verdict_only, (ecc, outlier, fk, fsum, fvar).  The final rows are
    always populated (each channel's state after its own vlen[c] valid
    rows; fk = k0 + vlen).  With `donate` the carry rows (and x, when
    its dtype matches the first row output) alias the outputs — callers
    must treat the operands as consumed.
    """
    t_len, c = x.shape
    if not block_c:
        block_c = c
    assert (t_len % block_t == 0 and block_t % 8 == 0
            and c % block_c == 0 and block_c % 128 == 0), (
        "ops.py must pad: T % block_t == 0, block_t % 8 == 0, "
        "C % block_c == 0, block_c % 128 == 0")
    grid = (c // block_c, t_len // block_t)

    row_spec = block_spec((block_t, block_c), lambda j, i: (i, j),
                          memory_space=pltpu.VMEM)
    carry_spec = block_spec((1, block_c), lambda j, i: (0, j),
                            memory_space=pltpu.VMEM)
    f32 = jnp.float32
    final_shape = [
        jax.ShapeDtypeStruct((1, c), f32),  # final k (= k0 + vlen)
        jax.ShapeDtypeStruct((1, c), f32),  # final sum
        jax.ShapeDtypeStruct((1, c), f32),  # final var
    ]
    if verdict_only:
        out_shape = [
            jax.ShapeDtypeStruct((t_len, c), f32),      # ecc
            jax.ShapeDtypeStruct((t_len, c), jnp.int8),  # outlier
        ] + final_shape
        out_specs = [row_spec, row_spec, carry_spec, carry_spec,
                     carry_spec]
    else:
        out_shape = [
            jax.ShapeDtypeStruct((t_len, c), f32),        # mean
            jax.ShapeDtypeStruct((t_len, c), f32),        # var
            jax.ShapeDtypeStruct((t_len, c), f32),        # ecc
            jax.ShapeDtypeStruct((t_len, c), jnp.int32),  # outlier
        ] + final_shape
        out_specs = [row_spec, row_spec, row_spec, row_spec,
                     carry_spec, carry_spec, carry_spec]
    n_rows = 2 if verdict_only else 4
    aliases = {}
    if donate:
        # carry-row donation: k0 -> fk, init_sum -> fsum, init_var ->
        # fvar (inputs 3/4/5; vlen is read by every step — not donated)
        aliases = {3: n_rows, 4: n_rows + 1, 5: n_rows + 2}
        if x.dtype == out_shape[0].dtype:
            aliases[1] = 0  # the stream buffer is consumed by the call
    kernel = functools.partial(teda_scan_kernel, block_t=block_t,
                               verdict_only=verdict_only)
    compiler_params = None
    if not interpret:
        compiler_params = tpu_compiler_params(
            # channel strips are independent (multi-core scaling); the
            # time axis is the sequential carry chain
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scal (1,)
            row_spec,  # x
            carry_spec,  # vlen
            carry_spec,  # init_k
            carry_spec,  # init_sum
            carry_spec,  # init_var
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((1, block_c), f32),  # running sum carry
            pltpu.VMEM((1, block_c), f32),  # running var carry
        ],
        input_output_aliases=aliases,
        compiler_params=compiler_params,
        interpret=interpret,
    )(scal, x, vlen, init_k, init_sum, init_var)
