"""Activation-sharding hints (Megatron-style sequence parallelism).

GSPMD propagates parameter shardings, but the residual stream (B, S, D)
defaults to batch-only sharding — replicated across the `model` axis,
which blows up saved activations at 34B/132B scale (DESIGN.md §5). The
fix is a with_sharding_constraint on the residual between blocks:
sequence over "model" outside attention/MLP; GSPMD inserts the
all-gather / reduce-scatter pair around the TP regions automatically.

Model code stays mesh-agnostic: it calls `maybe_shard(x, "residual")`,
which is a no-op unless the launcher installed a context via
`activation_hints(mesh, sp=...)` (contextvar, trace-time).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_hints", default=None)


class _Hints:
    def __init__(self, mesh: Mesh, sp: bool, dp: tuple):
        self.mesh, self.sp, self.dp = mesh, sp, dp


@contextlib.contextmanager
def activation_hints(mesh: Mesh, sp: bool = True):
    from repro.sharding.rules import dp_axes
    tok = _CTX.set(_Hints(mesh, sp, dp_axes(mesh)))
    try:
        yield
    finally:
        _CTX.reset(tok)


def sp_enabled() -> bool:
    h = _CTX.get()
    return bool(h and h.sp)


def _msize(mesh: Mesh) -> int:
    return dict(mesh.shape).get("model", 1)


def maybe_shard(x, kind: str = "residual"):
    """Apply the activation constraint for `kind` if hints are active."""
    h: Optional[_Hints] = _CTX.get()
    if h is None:
        return x
    if kind == "residual" and x.ndim == 3:
        b, s, _ = x.shape
        sizes = dict(h.mesh.shape)
        msz = _msize(h.mesh)
        dp_total = 1
        for a in h.dp:
            dp_total *= sizes[a]
        if dp_total > 1 and b % dp_total == 0:
            bspec = h.dp
        elif b % sizes.get("data", 1) == 0 and sizes.get("data", 1) > 1:
            bspec = "data"
        else:
            bspec = None
        if h.sp and s % msz == 0 and s > msz:
            spec = P(bspec, "model", None)
        else:
            spec = P(bspec, None, None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(h.mesh, spec))
    return x
