"""GPipe-style pipeline parallelism building block (shard_map + ppermute).

An optional parallelism dimension for depth-dominated models at >512-chip
scale: stage s holds 1/S of the layer stack; microbatches stream through
stages with `jax.lax.ppermute` handoffs; the schedule runs M + S - 1
ticks (fill + drain bubble). Composes with the data/model axes (the
"pipe" axis is just another mesh axis).

Used by tests and available to launch/train.py via --pipeline-stages;
the default production mesh keeps pipeline off (FSDP+TP covers the
assigned shapes), so this module is a first-class but opt-in feature.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import shard_map_compat


def pipeline_forward(stage_fn: Callable, n_stages: int, axis: str = "pipe"):
    """Build a per-device pipelined forward for shard_map.

    stage_fn(stage_params, x) -> x, applied by every device to each
    microbatch passing through. Input x: (M, mb, ...) microbatched on the
    leading axis; every device receives the same x but only stage 0's
    injections matter — outputs are collected from the last stage and
    broadcast back.
    """

    def run(stage_params, x):
        idx = jax.lax.axis_index(axis)
        m = x.shape[0]
        ticks = m + n_stages - 1
        buf = jnp.zeros_like(x[0])  # in-flight activation on this stage
        outs = jnp.zeros_like(x)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            inject = jnp.where(t < m, t, m - 1)
            x_in = jnp.where(idx == 0, x[inject],
                             jnp.zeros_like(x[0]) + buf)
            y = stage_fn(stage_params, x_in)
            # pass to the next stage; last stage's output wraps to 0
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf_next = jax.lax.ppermute(y, axis, perm)
            # last stage writes microbatch t - (S - 1)
            out_t = t - (n_stages - 1)
            take = jnp.logical_and(out_t >= 0, idx == 0)
            # the value arriving at stage 0 via the wrap IS the final
            # output of microbatch out_t
            idx_w = jnp.where(out_t >= 0, out_t, 0)
            outs = jnp.where(
                take,
                outs.at[idx_w].set(buf_next),
                outs)
            return (buf_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # outs is only populated on stage 0 — broadcast it everywhere so
        # the shard_map output is legitimately replicated
        return jax.lax.all_gather(outs, axis)[0]

    return run


def make_pipelined(mesh: Mesh, stage_fn: Callable, n_stages: int,
                   axis: str = "pipe"):
    """jit-wrapped shard_map pipeline. stage_params stacked (S, ...)."""
    run = pipeline_forward(stage_fn, n_stages, axis)
    mapped = shard_map_compat(
        run, mesh=mesh,
        in_specs=(P(axis), P()),  # params sharded by stage, x replicated
        out_specs=P(),
        check=False,
    )
    return jax.jit(mapped)
