"""Mesh/sharding rules + pipeline parallelism."""
from repro.sharding.rules import (batch_spec, cache_spec, dp_axes,
                                  param_spec, params_shardings,
                                  state_cache_shardings)
