"""Mesh/sharding rules + pipeline parallelism."""
from repro.sharding.rules import (abstract_mesh, batch_spec, cache_spec,
                                  dp_axes, make_mesh_compat, param_spec,
                                  params_shardings,
                                  state_cache_shardings)
