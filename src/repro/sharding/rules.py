"""Sharding rules: map every array in the system to a PartitionSpec.

Strategy (DESIGN.md §5):
  * batch/tokens         -> data-parallel over ("pod", "data")
  * 2D weights           -> FSDP on the input dim over "data", TP on the
                            output dim over "model" (down-projections
                            transpose this so the contracting dim stays
                            on "model")
  * embedding (vocab, d) -> vocab over "model" (sharded softmax/CE),
                            d over "data"
  * MoE expert stacks    -> expert-parallel over "model" when n_experts
                            divides the axis, else TP over d_ff
  * KV caches            -> batch over data when divisible, else sequence
                            over "data" (context parallelism, long_500k);
                            head_dim over "model" when divisible
  * tiny arrays (norms, biases, gates) -> replicated

Across pods parameters are replicated (DP over "pod"; FSDP stays inside a
pod where ICI is fast — grads cross DCN once per step). All rules are
*advisory*: pjit/GSPMD propagates them through the program.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

REPLICATE_BELOW = 1 << 16  # arrays smaller than 64k entries: replicate

_DOWN_PROJ_NAMES = ("wo", "wdown", "wout")
_EXPERT_NAMES = ("wi", "wg", "wo")

# Experiment toggles for the §Perf hillclimb (repro.launch.hillclimb
# --rule-flag). Defaults = production baseline.
RULE_FLAGS = {
    "moe_prefer_tp": False,   # True: shard expert ff dim instead of EP
    "embed_data_shard": True,  # False: replicate embed d over data
    # True: parameter/optimizer FSDP spans the pod axis too (ZeRO-3
    # across pods — DCN all-gathers per step; the production choice for
    # >=100B-param models whose state cannot replicate per pod)
    "fsdp_over_pod": False,
}


def abstract_mesh(axis_sizes: Tuple[int, ...],
                  axis_names: Tuple[str, ...]):
    """Version-compatible jax.sharding.AbstractMesh constructor.

    JAX 0.4.36+ takes a ((name, size), ...) shape_tuple; newer releases
    take (axis_sizes, axis_names) positionally.  Spec-rule tests and
    dry-runs construct device-free meshes through this shim so they work
    on either signature.
    """
    import inspect

    from jax.sharding import AbstractMesh

    params = inspect.signature(AbstractMesh.__init__).parameters
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def make_mesh_compat(axis_shapes: Tuple[int, ...],
                     axis_names: Tuple[str, ...]) -> Mesh:
    """Version-compatible jax.make_mesh with Auto axis types.

    jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist
    on newer JAX; older releases are Auto-by-default.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """Version-compatible shard_map.

    Newer JAX exposes jax.shard_map with `check_vma`; 0.4.x has
    jax.experimental.shard_map.shard_map with `check_rep`.
    """
    import inspect

    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kw = {}
    if "check_vma" in params:
        kw["check_vma"] = check
    elif "check_rep" in params:
        kw["check_rep"] = check
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_channel_fanout(fn, mesh: Mesh, axis_name: str = "data"):
    """shard_map fan-out of an independent-channel stream processor.

    `fn(x, k, mean, var, vlen, m) -> ((k', mean', var'),
    (ecc, outlier))` — the `repro.engine` backend contract: x is (T, C)
    with C independent univariate streams on the lane axis, the state
    rows (and the per-slot valid-length vector `vlen` and threshold
    vector `m`) are (C,) vectors, and the per-sample outputs are
    (T, C).  Channels are independent TEDA
    modules (the paper's replicated-module scaling, §5.2.1), so the
    fan-out needs no collectives: each device runs `fn` on its C/D
    channel slice.  The caller must keep C divisible by the axis size
    (StreamEngine asserts this).
    """
    vec = P(axis_name)
    row = P(None, axis_name)
    return shard_map_compat(
        fn, mesh=mesh,
        in_specs=(row, vec, vec, vec, vec, vec),
        out_specs=((vec, vec, vec), (row, row)),
    )


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis(mesh: Mesh, name: str) -> int:
    return dict(mesh.shape)[name]  # works for Mesh and AbstractMesh


def _div(n: int, k: int) -> bool:
    return n % k == 0 and n >= k


def param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    """Sharding rule for one parameter leaf, keyed on its tree path."""
    dsz, msz = _axis(mesh, "data"), _axis(mesh, "model")
    fsdp: object = "data"
    if RULE_FLAGS["fsdp_over_pod"] and "pod" in mesh.axis_names:
        fsdp = ("pod", "data")
        dsz = dsz * _axis(mesh, "pod")
    size = int(np.prod(shape)) if shape else 1
    if size < REPLICATE_BELOW or not shape:
        return P()
    parts = path.replace(".", "/").split("/")
    name = parts[-1]
    if name in ("w", "b") and len(parts) >= 2:  # dense leaf: use its module
        name = parts[-2]
    stacked = "blocks_" in path or "_blocks" in path  # leading groups dim
    off = 1 if stacked else 0
    dims = shape[off:]

    # embedding / unembedding tables
    if "table" in name or "embed" in path:
        d_ax = fsdp if (RULE_FLAGS["embed_data_shard"]
                        and _div(dims[1], dsz)) else None
        spec = [None] * off + ["model" if _div(dims[0], msz) else None,
                               d_ax]
        return P(*spec)

    # expert-stacked weights (E, din, dout)
    if "moe" in path and len(dims) == 3:
        e, din, dout = dims
        if _div(e, msz) and not RULE_FLAGS["moe_prefer_tp"]:
            # EP on E; FSDP on the ff dim so (E, C, ff) dispatch
            # intermediates shard over data instead of materializing per
            # expert-shard (wi/wg: ff is dim 2; wo: ff is dim 1)
            ff_dim = 2 if name in ("wi", "wg") else 1
            spec = [None] * 3
            spec[0] = "model"
            if _div(dims[ff_dim], dsz):
                spec[ff_dim] = fsdp
            return P(*([None] * off), *spec)
        # fall back to TP over the ff dim
        ff_dim = 2 if name in ("wi", "wg") else 1
        spec: list = [None] * (off + 3)
        if _div(dims[ff_dim], msz):
            spec[off + ff_dim] = "model"
        other = 1 if ff_dim == 2 else 2
        if _div(dims[other], dsz):
            spec[off + other] = fsdp
        return P(*spec)

    if len(dims) == 2:
        din, dout = dims
        if name in _DOWN_PROJ_NAMES:  # contracting dim on model
            return P(*([None] * off),
                     "model" if _div(din, msz) else None,
                     fsdp if _div(dout, dsz) else None)
        return P(*([None] * off),
                 fsdp if _div(din, dsz) else None,
                 "model" if _div(dout, msz) else None)

    if len(dims) == 1:
        return P(*([None] * off),
                 "model" if _div(dims[0], msz) else None)
    # conv kernels / recurrent blocks etc.
    spec = [None] * (off + len(dims))
    # shard the largest dim on model if possible
    big = int(np.argmax(dims))
    if _div(dims[big], msz):
        spec[off + big] = "model"
    return P(*spec)


def params_shardings(mesh: Mesh, params_tree):
    """NamedShardings for a whole param pytree (by tree path)."""

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                        for k in path)
        return NamedSharding(mesh, param_spec(mesh, pstr, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params_tree)


def batch_spec(mesh: Mesh, batch_size: int, kind: str = "train") -> P:
    """Spec for (B, S) token batches / (B,) decode tokens."""
    axes = dp_axes(mesh)
    total = int(np.prod([_axis(mesh, a) for a in axes]))
    if _div(batch_size, total):
        return P(axes) if kind == "decode" else P(axes, None)
    if "data" in axes and _div(batch_size, _axis(mesh, "data")):
        return P("data") if kind == "decode" else P("data", None)
    return P() if kind == "decode" else P(None, None)


def cache_spec(mesh: Mesh, shape: Tuple[int, ...], batch_axis: int = 1,
               seq_axis: int = 2, head_dim_axis: int = -1) -> P:
    """KV-cache spec: (groups, B, S, kv, hd)."""
    dsz, msz = _axis(mesh, "data"), _axis(mesh, "model")
    axes = dp_axes(mesh)
    total = int(np.prod([_axis(mesh, a) for a in axes]))
    spec = [None] * len(shape)
    b = shape[batch_axis]
    if _div(b, total):
        spec[batch_axis] = axes
    elif _div(b, dsz):
        spec[batch_axis] = "data"
    else:  # tiny batch: context-parallel over the sequence instead
        if _div(shape[seq_axis], dsz):
            spec[seq_axis] = "data"
    hd = shape[head_dim_axis]
    if _div(hd, msz):
        spec[head_dim_axis] = "model"
    elif _div(shape[-2], msz):  # else try kv-heads
        spec[-2] = "model"
    return P(*spec)


def state_cache_shardings(mesh: Mesh, caches):
    """Shardings for a decode-cache pytree (KV caches + SSM/xLSTM states)."""

    def one(leaf):
        shape = leaf.shape
        if len(shape) >= 5:  # (G, B, S, kv, hd) attention cache
            return NamedSharding(mesh, cache_spec(mesh, shape))
        # recurrent states: (G, B, ...) — batch over dp, biggest trailing
        # dim over model
        dsz, msz = _axis(mesh, "data"), _axis(mesh, "model")
        axes = dp_axes(mesh)
        total = int(np.prod([_axis(mesh, a) for a in axes]))
        spec = [None] * len(shape)
        if len(shape) >= 2:
            if _div(shape[1], total):
                spec[1] = axes
            elif _div(shape[1], dsz):
                spec[1] = "data"
        trail = list(range(2, len(shape)))
        if trail:
            big = max(trail, key=lambda i: shape[i])
            if _div(shape[big], msz):
                spec[big] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, caches)
