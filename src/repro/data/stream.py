"""Deterministic synthetic data pipeline with TEDA screening + prefetch.

`TokenStream` yields LM batches (B, S+1) from a seeded Markov-ish zipfian
sampler — fully reproducible across restarts (the stream is indexable by
step, so checkpoint-resume replays exactly). `corrupt_prob` injects
anomalous batches (token-id saturation bursts) to exercise the TEDA
guard end-to-end.

`PrefetchIterator` runs the generator in a background thread with a
bounded queue (host-side input pipelining) and can screen per-batch
statistics with a TEDA state, dropping flagged batches before they reach
the device — the paper's detector as a data-quality gate.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.guard import GuardConfig, guard_init, guard_step

import jax.numpy as jnp


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 corrupt_prob: float = 0.0, corrupt_every: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.corrupt_prob = corrupt_prob
        self.corrupt_every = corrupt_every  # deterministic corruption

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # zipf-distributed ids with short-range repetition structure
        raw = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (raw % self.vocab).astype(np.int32)
        rep = rng.random((self.batch, self.seq + 1)) < 0.25
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        corrupt = (self.corrupt_prob and rng.random() < self.corrupt_prob)
        if self.corrupt_every and step and step % self.corrupt_every == 0:
            corrupt = True
        if corrupt:
            toks[:] = self.vocab - 1  # saturated garbage batch
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_stats(batch: Dict[str, np.ndarray]) -> np.ndarray:
    """Telemetry vector for TEDA screening: [mean_id, unique_frac]."""
    t = batch["tokens"]
    return np.asarray([float(t.mean()),
                       len(np.unique(t)) / t.size], np.float32)


class PrefetchIterator:
    def __init__(self, source, depth: int = 2,
                 screen: Optional[GuardConfig] = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._src = iter(source)
        self._screen_cfg = screen
        self._gs = guard_init(screen) if screen else None
        self.dropped = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for item in self._src:
                if self._stop.is_set():
                    return
                if self._screen_cfg is not None:
                    stats = jnp.asarray(batch_stats(item))
                    self._gs, verdict = guard_step(self._gs, stats,
                                                   self._screen_cfg)
                    if bool(verdict.skip):
                        self.dropped += 1
                        continue
                if not self._put(item):
                    return
        finally:
            self._put(None)  # sentinel (skipped when closing)

    def _put(self, item) -> bool:
        """Bounded put that aborts when the iterator is closing.

        A plain `Queue.put` on a full queue would block the daemon
        thread forever once the consumer stops draining; polling the
        stop event keeps `close()` able to finish the worker.
        """
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self):
        return self

    def __next__(self):
        # poll the stop event: a consumer already blocked here must wake
        # when close() is called from another thread (after close, the
        # producer drops items and the sentinel instead of enqueueing)
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is None:
                raise StopIteration
            return item

    def close(self, timeout: float = 2.0):
        """Stop the worker, unblock it if it sits on a full queue, join
        it, and drain leftovers (incl. the sentinel) so no daemon thread
        or queued batch outlives the iterator.

        Bounded by `timeout`: a worker stuck inside the *source*
        iterator (e.g. a blocking socket read) cannot observe the stop
        event; after the deadline the daemon thread is abandoned rather
        than hanging the caller.
        """
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:  # make room so a blocked producer can observe the stop
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
