"""Synthetic DAMADICS-like actuator streams (the paper's validation data).

The real DAMADICS server (diag.mchtr.pw.edu.pl) is offline; we synthesize
statistically similar 2-channel actuator telemetry (flow + valve-position
style signals: slow sinusoidal process trend + measurement noise) and
inject the paper's four artificial fault types (Table 1):

  f16 — positioner supply pressure drop   (level drop, ramp in/out)
  f17 — unexpected pressure change        (sustained offset)
  f18 — partly opened bypass valve        (step change on one channel)
  f19 — flow rate sensor fault            (stuck-at + noise burst)

`make_benchmark()` reproduces the Table-2 layout: a long stream with
fault windows at known sample indices, so Figures 6–7 (eccentricity vs
5/k threshold crossing inside the fault window) can be regenerated.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Tuple

import numpy as np


class FaultWindow(NamedTuple):
    kind: str
    start: int
    stop: int


def base_signals(t_len: int, seed: int = 0) -> np.ndarray:
    """Nominal 2-channel actuator telemetry (T, 2)."""
    rng = np.random.default_rng(seed)
    t = np.arange(t_len)
    flow = (1.0 + 0.15 * np.sin(2 * np.pi * t / 9000.0)
            + 0.05 * np.sin(2 * np.pi * t / 613.0)
            + 0.02 * rng.normal(size=t_len))
    valve = (0.6 + 0.1 * np.sin(2 * np.pi * t / 9000.0 + 0.7)
             + 0.015 * rng.normal(size=t_len))
    return np.stack([flow, valve], axis=-1).astype(np.float32)


def inject(x: np.ndarray, w: FaultWindow, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = x.copy()
    n = w.stop - w.start
    sl = slice(w.start, w.stop)
    if w.kind == "f16":  # supply pressure drop: ramped level drop
        ramp = np.minimum(np.arange(n) / max(n // 8, 1), 1.0)
        x[sl, 0] -= 0.55 * ramp  # ~4.5 sigma of the nominal signal
        x[sl, 1] -= 0.30 * ramp
    elif w.kind == "f17":  # pressure change across the valve
        x[sl, 0] += 0.4
        x[sl, 1] -= 0.15
    elif w.kind == "f18":  # partly opened bypass valve: step on flow
        x[sl, 0] += 0.5
    elif w.kind == "f19":  # sensor fault: stuck + noise burst
        x[sl, 0] = x[w.start, 0] + 0.2 * rng.normal(size=n)
    else:
        raise ValueError(w.kind)
    return x


# Table 2 analog: (kind, start, stop) in sample indices
TABLE2: List[FaultWindow] = [
    FaultWindow("f18", 58800, 59800),
    FaultWindow("f16", 57275, 57550),
    FaultWindow("f18", 58830, 58930),
    FaultWindow("f18", 58520, 58625),
    FaultWindow("f18", 54600, 54700),
    FaultWindow("f16", 56670, 56770),
    FaultWindow("f17", 37780, 38400),
]


def make_benchmark(item: int = 0, t_len: int = 60000, seed: int = 0
                   ) -> Tuple[np.ndarray, FaultWindow]:
    """Stream + its injected fault window (items index Table 2)."""
    w = TABLE2[item]
    x = base_signals(t_len, seed=seed + item)
    return inject(x, w, seed=seed + 100 + item), w


def detection_report(outlier: np.ndarray, w: FaultWindow,
                     guard_band: int = 50) -> Dict[str, float]:
    """Detection metrics for one run: latency, hit, false alarms."""
    flags = np.asarray(outlier, bool)
    inside = flags[w.start:w.stop]
    before = flags[:w.start - guard_band]
    hit = bool(inside.any())
    latency = int(np.argmax(inside)) if hit else -1
    return {
        "hit": float(hit),
        "latency_samples": float(latency),
        "false_alarm_rate": float(before.mean()),
        "in_window_rate": float(inside.mean()),
    }
