"""Data pipeline: synthetic token streams + DAMADICS-like fault streams."""
from repro.data.stream import PrefetchIterator, TokenStream, batch_stats
from repro.data.damadics import (TABLE2, FaultWindow, base_signals,
                                 detection_report, inject, make_benchmark)
