"""Streaming event bus: verdicts stream at retirement, not completion.

Results in the serving stack used to materialise only when a request
completed (`BatchingScheduler.results()`); production traffic wants
them as they retire.  `EventBus` is the in-process primitive for
that: the scheduler publishes structured `Event`s the moment the
fused call that produced them is fetched to host —

    admitted       request acquired a slot      (slot, priority)
    chunk_retired  one member of a fused call   (slot, n, flags,
                   retired its samples           outlier[, ecc])
    done           request completed             (samples, flags)
    evicted        finished record aged out of
                   the retention window

Subscribers pull: `subscribe()` returns a `Subscription` whose
iterator drains the events queued so far without blocking (the
scheduler tick is single-threaded; a subscriber polls between
`step()` calls, or from another thread).  Each subscription has its
own bounded queue — a slow consumer drops its *own* oldest events
(counted in `Subscription.dropped`), never stalls the scheduler, and
never affects other subscribers.  `attach(callback)` is the push
alternative for in-process hooks (`serve_streams(on_event=...)`):
the callback runs synchronously at publish time, in retirement order.

Publishing is zero-cost with no consumers: `bus.active` is False and
the scheduler skips event assembly entirely.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional

__all__ = ["Event", "EventBus", "Subscription"]


@dataclass
class Event:
    """One structured scheduler event.

    `seq` is the bus-wide publish sequence number: events compare in
    retirement order across kinds (the event-bus ordering contract —
    concatenating a request's `chunk_retired` payloads reproduces its
    `results()` bit-for-bit).
    """

    kind: str
    seq: int
    tick: int
    rid: Optional[str] = None
    data: dict = field(default_factory=dict)


class Subscription:
    """A pull-side queue of events, bounded, drop-oldest."""

    def __init__(self, bus: "EventBus", maxlen: int):
        self._bus = bus
        self._q: deque = deque()
        self._maxlen = int(maxlen)
        self.dropped = 0
        self.closed = False
        self._lock = threading.Lock()

    def _push(self, ev: Event) -> None:
        with self._lock:
            if len(self._q) >= self._maxlen:
                self._q.popleft()
                self.dropped += 1
            self._q.append(ev)

    def poll(self) -> List[Event]:
        """Drain and return every event queued so far (never blocks)."""
        with self._lock:
            out = list(self._q)
            self._q.clear()
        return out

    def __iter__(self) -> Iterator[Event]:
        """Yield queued events until the queue is momentarily empty
        (non-blocking: iterate again after the next scheduler tick)."""
        while True:
            with self._lock:
                if not self._q:
                    return
                ev = self._q.popleft()
            yield ev

    def close(self) -> None:
        """Unsubscribe: the bus stops delivering to this queue."""
        if not self.closed:
            self.closed = True
            self._bus._drop(self)

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class EventBus:
    """Publish/subscribe fan-out for scheduler events (in-process)."""

    def __init__(self):
        self._subs: List[Subscription] = []
        self._callbacks: List[Callable[[Event], None]] = []
        self._seq = itertools.count()
        self._lock = threading.Lock()

    @property
    def active(self) -> bool:
        """True when anyone is listening — publishers use this to skip
        event assembly entirely on the silent path."""
        return bool(self._subs or self._callbacks)

    def subscribe(self, maxlen: int = 4096) -> Subscription:
        """A new independent subscription (bounded at `maxlen`)."""
        sub = Subscription(self, maxlen)
        with self._lock:
            self._subs.append(sub)
        return sub

    def attach(self, callback: Callable[[Event], None]):
        """Register a synchronous push callback; returns it (pass to
        `detach` to remove).  Exceptions propagate to the publisher —
        a hook that raises aborts the scheduler tick that fired it."""
        with self._lock:
            self._callbacks.append(callback)
        return callback

    def detach(self, callback) -> None:
        with self._lock:
            if callback in self._callbacks:
                self._callbacks.remove(callback)

    def _drop(self, sub: Subscription) -> None:
        with self._lock:
            if sub in self._subs:
                self._subs.remove(sub)

    def publish(self, kind: str, tick: int, rid: Optional[str] = None,
                **data) -> Optional[Event]:
        """Deliver one event to every subscription and callback; the
        assigned `seq` makes publish order observable.  No-op (returns
        None) when nothing is listening."""
        if not self.active:
            return None
        ev = Event(kind=kind, seq=next(self._seq), tick=tick, rid=rid,
                   data=data)
        for sub in list(self._subs):
            sub._push(ev)
        for cb in list(self._callbacks):
            cb(ev)
        return ev
