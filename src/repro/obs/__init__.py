"""repro.obs — dependency-free observability for the serving stack.

Three parts, all host-side and zero-overhead when unused:

  * `metrics` — Counter/Gauge/Histogram instruments with labels in a
    `MetricsRegistry` (JSON snapshot + Prometheus text exposition);
    the engine, pool and scheduler keep their telemetry here and
    `stats()` reads it back O(1).
  * `trace` — `TickTracer`, a bounded ring buffer of span events
    (admit/dispatch/retire/flush, pool resizes, program compiles)
    exportable as Chrome trace-event JSON for Perfetto; `NULL_TRACER`
    is the free disabled default.
  * `events` — `EventBus`: the scheduler streams structured events
    (admitted / chunk_retired / done / evicted) at retirement via
    `BatchingScheduler.subscribe()` and `serve_streams(on_event=)`.

See README §observability.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               LATENCY_MS_BUCKETS, MetricsRegistry,
                               TICK_BUCKETS, auto_name, get_registry)
from repro.obs.trace import NULL_TRACER, NullTracer, TickTracer
from repro.obs.events import Event, EventBus, Subscription

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "auto_name", "LATENCY_MS_BUCKETS", "TICK_BUCKETS",
    "TickTracer", "NullTracer", "NULL_TRACER",
    "Event", "EventBus", "Subscription",
]
