"""Dependency-free metrics registry: Counter / Gauge / Histogram.

The paper validates its pipeline with measured occupation and
throughput tables; the serving stack deserves the same rigor about
itself.  This module is the first-class replacement for the ad-hoc
integer attributes the scheduler/pool/engine used to keep behind
`stats()`: Prometheus-shaped instruments (monotonic counters, gauges,
fixed-bucket histograms, all with label axes) collected in a
`MetricsRegistry` that snapshots to plain-JSON dicts and renders
Prometheus text exposition — with zero third-party dependencies, so it
runs wherever the kernels do.

Design points that differ from a full Prometheus client, on purpose:

  * `Histogram.observe(value, weight=)` takes a weight: the serving
    scheduler weights each fused-call wall time by the samples the
    call retired, so a 1-sample decode tick does not count the same
    as a full prefill chunk (the honest-percentile rule from ISSUE 5,
    now O(1) per `stats()` read instead of a re-sort of the call log).
  * `Histogram.quantile(q)` gives a weighted nearest-rank estimate
    over the bucket upper edges (exact whenever observations land on
    bucket edges — the property `tests/test_obs.py` pins against the
    old sort-based computation).
  * Instruments are get-or-create: registering the same name twice
    with the same type/labels returns the same instrument; a
    conflicting re-registration raises.

Components take an injectable `registry=` (default: a private
registry per component, so two schedulers never mix values) and label
every instrument with their instance name; `get_registry()` returns
the process-global default for apps that want one scrape surface.
"""
from __future__ import annotations

import itertools
import threading
from collections import defaultdict
from typing import Dict, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "get_registry", "auto_name", "LATENCY_MS_BUCKETS",
           "TICK_BUCKETS"]

# fused-call wall times in milliseconds: log-ish spacing from 50us
# (warm interpret-mode decode ticks) to 5s (cold compiles)
LATENCY_MS_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0)

# tick-valued quantities (queue waits, request latencies): exact for
# small integer values, log-spaced past 16 so the vector stays short
TICK_BUCKETS: Tuple[float, ...] = (
    0.0, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0,
    64.0, 96.0, 128.0, 192.0, 256.0, 384.0, 512.0, 768.0, 1024.0,
    1536.0, 2048.0)

_instance_seq: Dict[str, itertools.count] = defaultdict(itertools.count)


def auto_name(kind: str) -> str:
    """Process-unique instance name for a component kind
    (``sched0``, ``sched1``, ``pool0``, ...) — the label value that
    keeps two components' series apart in a shared registry."""
    return f"{kind}{next(_instance_seq[kind])}"


def _fmt(v: float) -> str:
    """Exposition number format: integral floats print as ints."""
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def _escape(v) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


class _Child:
    """One labelled series of a metric family."""

    __slots__ = ("_lock",)

    def __init__(self):
        self._lock = threading.Lock()


class _CounterChild(_Child):
    __slots__ = ("_value",)

    def __init__(self):
        super().__init__()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got inc({amount})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class _GaugeChild(_Child):
    __slots__ = ("_value",)

    def __init__(self):
        super().__init__()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value


class _HistogramChild(_Child):
    __slots__ = ("_uppers", "_counts", "_sum", "_count", "_max")

    def __init__(self, uppers: Tuple[float, ...]):
        super().__init__()
        self._uppers = uppers                 # finite, sorted
        self._counts = [0.0] * (len(uppers) + 1)  # + the +Inf bucket
        self._sum = 0.0
        self._count = 0.0
        self._max = 0.0

    def observe(self, value: float, weight: float = 1.0) -> None:
        """Record `value` with multiplicity `weight` (weight must be
        positive; le edges are inclusive, Prometheus-style)."""
        if weight <= 0:
            raise ValueError(f"observation weight must be > 0: {weight}")
        value = float(value)
        # first bucket whose upper edge >= value (bisect is overkill
        # for <= ~23 edges and this keeps the hot path allocation-free)
        idx = len(self._uppers)
        for i, ub in enumerate(self._uppers):
            if value <= ub:
                idx = i
                break
        with self._lock:
            self._counts[idx] += weight
            self._sum += value * weight
            self._count += weight
            if value > self._max:
                self._max = value

    @property
    def count(self) -> float:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Weighted nearest-rank quantile estimated at bucket upper
        edges: the first bucket whose cumulative weight fraction
        reaches `q` (the searchsorted rule the scheduler's old exact
        computation used).  Observations in the +Inf bucket report the
        maximum value seen.  Exact whenever observations equal bucket
        edges; 0.0 on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must lie in [0, 1], got {q}")
        with self._lock:
            total = self._count
            if total == 0:
                return 0.0
            cum = 0.0
            for i, c in enumerate(self._counts):
                cum += c
                if cum / total >= q:
                    if i < len(self._uppers):
                        return float(self._uppers[i])
                    return float(self._max)
            return float(self._max)  # fp slack: the tail is the max

    def buckets(self):
        """[(upper_edge, cumulative_count), ...] ending at +Inf."""
        out, cum = [], 0.0
        with self._lock:
            for ub, c in zip(self._uppers, self._counts):
                cum += c
                out.append((ub, cum))
            out.append((float("inf"), cum + self._counts[-1]))
        return out


class _Family:
    """A named metric family: children keyed by label values."""

    kind = "untyped"
    _child_cls = _Child

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[tuple, _Child] = {}
        self._lock = threading.Lock()

    def _make_child(self) -> _Child:
        return self._child_cls()

    def labels(self, **labelvalues):
        """The child series for this exact label assignment (created
        on first use); label names must match the family's axes."""
        if set(labelvalues) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(labelvalues))}")
        key = tuple(str(labelvalues[k]) for k in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._make_child()
                self._children[key] = child
        return child

    def _default_child(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} has label axes {self.labelnames}; "
                "use .labels(...)")
        return self.labels()

    def series(self):
        """[(labels_dict, child), ...] in creation order."""
        with self._lock:
            items = list(self._children.items())
        return [(dict(zip(self.labelnames, key)), ch)
                for key, ch in items]

    def signature(self) -> tuple:
        return (self.kind, self.labelnames)


class Counter(_Family):
    kind = "counter"
    _child_cls = _CounterChild

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)

    @property
    def value(self) -> float:
        return self._default_child().value


class Gauge(_Family):
    kind = "gauge"
    _child_cls = _GaugeChild

    def set(self, value: float) -> None:
        self._default_child().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default_child().dec(amount)

    @property
    def value(self) -> float:
        return self._default_child().value


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = LATENCY_MS_BUCKETS):
        super().__init__(name, help, labelnames)
        ub = tuple(sorted(float(b) for b in buckets
                          if b != float("inf")))
        if not ub or len(set(ub)) != len(ub):
            raise ValueError(f"bad histogram buckets: {buckets}")
        self.bucket_uppers = ub

    def _make_child(self) -> _HistogramChild:
        return _HistogramChild(self.bucket_uppers)

    def observe(self, value: float, weight: float = 1.0) -> None:
        self._default_child().observe(value, weight)

    def quantile(self, q: float) -> float:
        return self._default_child().quantile(q)

    @property
    def count(self) -> float:
        return self._default_child().count

    @property
    def sum(self) -> float:
        return self._default_child().sum

    def signature(self) -> tuple:
        return (self.kind, self.labelnames, self.bucket_uppers)


class MetricsRegistry:
    """Instrument container with get-or-create registration, a plain
    JSON snapshot, and Prometheus text exposition.

    >>> reg = MetricsRegistry()
    >>> ticks = reg.counter("sched_ticks_total", "ticks", ("sched",))
    >>> ticks.labels(sched="sched0").inc()
    >>> reg.snapshot()["sched_ticks_total"]["samples"]
    [{'labels': {'sched': 'sched0'}, 'value': 1.0}]
    """

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _register(self, cls, name: str, help: str, labelnames,
                  **kwargs) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(name, help, labelnames, **kwargs)
                self._families[name] = fam
                return fam
        new_sig = cls(name, help, labelnames, **kwargs).signature()
        if fam.signature() != new_sig:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{fam.signature()}, conflicting with {new_sig}")
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_MS_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._families

    # ---------------------------------------------------- exposition
    def snapshot(self) -> dict:
        """Every family as plain JSON-ready dicts (sorted by name):
        counters/gauges carry ``value`` per series, histograms carry
        ``count`` / ``sum`` / cumulative ``buckets`` plus the p50/p95
        nearest-rank estimates."""
        out = {}
        for name in sorted(self._families):
            fam = self._families[name]
            samples = []
            for labels, ch in fam.series():
                if fam.kind == "histogram":
                    samples.append({
                        "labels": labels, "count": ch.count,
                        "sum": ch.sum,
                        "p50": ch.quantile(0.5),
                        "p95": ch.quantile(0.95),
                        "buckets": [["+Inf" if ub == float("inf")
                                     else ub, c]
                                    for ub, c in ch.buckets()]})
                else:
                    samples.append({"labels": labels,
                                    "value": ch.value})
            out[name] = {"type": fam.kind, "help": fam.help,
                         "labelnames": list(fam.labelnames),
                         "samples": samples}
        return out

    def to_text(self) -> str:
        """Prometheus text exposition format (the scrape payload)."""
        lines = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {_escape(fam.help)}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for labels, ch in fam.series():
                base = ",".join(f'{k}="{_escape(v)}"'
                                for k, v in labels.items())
                if fam.kind == "histogram":
                    for ub, cum in ch.buckets():
                        le = "+Inf" if ub == float("inf") else _fmt(ub)
                        lbl = (base + "," if base else "") + f'le="{le}"'
                        lines.append(
                            f"{name}_bucket{{{lbl}}} {_fmt(cum)}")
                    sfx = f"{{{base}}}" if base else ""
                    lines.append(f"{name}_sum{sfx} {_fmt(ch.sum)}")
                    lines.append(f"{name}_count{sfx} {_fmt(ch.count)}")
                else:
                    sfx = f"{{{base}}}" if base else ""
                    lines.append(f"{name}{sfx} {_fmt(ch.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


_DEFAULT_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global default registry — pass it as `registry=` to
    components that should share one scrape surface (components default
    to a private registry so independent instances never mix values)."""
    return _DEFAULT_REGISTRY
