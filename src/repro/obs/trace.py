"""Tick-level tracer: bounded ring buffer of span events, Chrome
trace-event JSON export.

The serving tick is a pipeline (admit -> dispatch -> retire -> flush,
with pool resizes and program compiles as out-of-band events); this
records it as spans with monotonic timestamps and tick/rid/slot
attribution, in a preallocated ring buffer so a forever-running
gateway traces at O(capacity) memory.  `to_chrome_trace()` emits the
Chrome trace-event JSON that Perfetto (ui.perfetto.dev) and
`chrome://tracing` open directly.

Off by default and zero-cost when disabled: components hold the
module's `NULL_TRACER` singleton (``enabled = False``, no-op
`span`/`instant`), and guard any argument assembly behind
``tracer.enabled`` — a disabled serving run records nothing and pays
nothing beyond one attribute check per site.

With ``annotate_device=True`` (and jax importable), spans marked
``device=True`` also enter a `jax.profiler.TraceAnnotation`, so host
spans line up with the device trace when a run is captured under
`jax.profiler.trace()`.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional

__all__ = ["TickTracer", "NullTracer", "NULL_TRACER"]

try:  # optional pass-through to device traces
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax is a baked-in dep here
    _TraceAnnotation = None


class _NullSpan:
    """Reusable no-op context manager (one shared instance)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled recorder: every call is a no-op.

    Components default to the `NULL_TRACER` singleton so the tracing
    hooks cost one truthiness check when tracing is off.
    """

    enabled = False

    def span(self, name: str, device: bool = False, **args):
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        return None

    def events(self) -> List[dict]:
        return []

    def to_chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one duration ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0", "_ann")

    def __init__(self, tracer: "TickTracer", name: str, device: bool,
                 args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args
        self._ann = (_TraceAnnotation(name)
                     if device and tracer._annotate_device
                     and _TraceAnnotation is not None else None)

    def __enter__(self):
        if self._ann is not None:
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tracer._record(self._name, "X", self._t0,
                             dur_s=t1 - self._t0, args=self._args)
        return False


class TickTracer:
    """Bounded ring-buffer recorder of scheduler/pool/engine events.

    >>> tracer = TickTracer(capacity=4096)
    >>> with tracer.span("dispatch", device=True, tick=3, t=32):
    ...     out = pool.process(x, valid_lens=vlens)
    >>> tracer.instant("pool.resize", frm=8, to=16)
    >>> tracer.dump("trace.json")          # open in ui.perfetto.dev

    `capacity` bounds memory: past it the oldest events are
    overwritten (`dropped` counts the overwrites).  Timestamps are
    `time.perf_counter()` microseconds relative to construction —
    monotonic, shared by every component handed this tracer.
    """

    enabled = True

    def __init__(self, capacity: int = 65536,
                 annotate_device: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1: {capacity}")
        self.capacity = int(capacity)
        self._annotate_device = bool(annotate_device)
        self._buf: List[Optional[dict]] = [None] * self.capacity
        self._head = 0          # next write position
        self.total = 0          # events ever recorded
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._lock = threading.Lock()

    # ------------------------------------------------------ recording
    def _record(self, name: str, ph: str, t_start: float, *,
                dur_s: Optional[float] = None,
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": ph, "pid": self._pid,
              "tid": threading.get_ident(),
              "ts": (t_start - self._t0) * 1e6}
        if dur_s is not None:
            ev["dur"] = dur_s * 1e6
        if args:
            ev["args"] = args
        with self._lock:
            self._buf[self._head] = ev
            self._head = (self._head + 1) % self.capacity
            self.total += 1

    def span(self, name: str, device: bool = False, **args) -> _Span:
        """Context manager recording a duration span; `device=True`
        additionally enters a `jax.profiler.TraceAnnotation` when the
        tracer was built with ``annotate_device=True``."""
        return _Span(self, name, device, args)

    def instant(self, name: str, **args) -> None:
        """Record a zero-duration instant event."""
        self._record(name, "i", time.perf_counter(), args=args)

    # ------------------------------------------------------ inspection
    def __len__(self) -> int:
        return min(self.total, self.capacity)

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wraparound."""
        return max(0, self.total - self.capacity)

    def events(self) -> List[dict]:
        """Retained events, oldest first (recording order survives
        wraparound)."""
        with self._lock:
            if self.total < self.capacity:
                return [e for e in self._buf[:self._head]]
            return (self._buf[self._head:] + self._buf[:self._head])

    def to_chrome_trace(self) -> dict:
        """The Chrome trace-event JSON document (Perfetto-loadable);
        events are sorted by timestamp as the viewers expect."""
        evs = sorted(self.events(), key=lambda e: e["ts"])
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"recorded": self.total,
                              "dropped": self.dropped}}

    def dump(self, path) -> None:
        """Write the Chrome trace JSON to `path`."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
            f.write("\n")
