"""Continuous-batching request scheduler: an engine slot *is* the
request lifecycle.

The paper's FPGA keeps detection at line rate because the pipeline
never drains between streams; the software analogue is continuous
batching: requests attach to a `SlotPool` slot on arrival, replay
their history through the engine in fixed-size chunks (chunked
prefill — long histories never trigger a fresh compile because the
chunk shape is constant), interleave with the decode-phase trickle of
live samples every tick, and detach/recycle the slot on completion.

ONE compiled (chunk_t, C) program per capacity bucket serves every
tenant mix: each tick makes a single fused engine call in which slot c
retires `min(pending_c, chunk_t)` samples via the engine's per-slot
`valid_lens` vector — a prefill-heavy slot rides the full chunk, a
decode-phase slot retires its one live sample, and a slot with nothing
pending is suspended at vlen=0 (frozen state, no flags, no detach) —
all in the same call.  This kills both the old bulk/trickle program
split (two dispatches per tick over disjoint slot sets) and the
1-sample-per-tick prefill-tail drain: a history of H samples now
retires in ceil(H / chunk_t) ticks instead of
floor(H / chunk_t) + (H mod chunk_t).

Ragged interleaved execution is bit-exact with running each request
alone — per-slot valid-length masking inside the kernels
(tests/test_ragged.py) plus slot independence, verified end-to-end by
tests/test_batching.py on the Q path.

Admission is a bounded queue: `submit` returns False when the queue is
full (caller backpressure), and requests wait in the queue while every
bucket of the pool is occupied (`PoolFull` backpressure inside the
scheduler).  Per-request telemetry (queue wait, chunk latencies, flag
counts) is kept for the serving benchmark and the gateway in
`launch/serve.py`.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.engine import PoolFull, SlotPool

__all__ = ["Request", "RequestStats", "BatchingScheduler"]

QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"


@dataclass
class Request:
    """One tenant stream: a history to replay + live samples to come.

    `m` is this tenant's outlier sensitivity (None: scheduler default).
    `closed` requests complete once their pending samples drain; open
    requests keep their slot and wait for `feed`.
    """

    rid: str
    history: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.float32))
    m: Optional[float] = None
    closed: bool = False


@dataclass
class RequestStats:
    """Per-request telemetry, filled in as the lifecycle advances."""

    rid: str
    submitted_tick: int
    admitted_tick: Optional[int] = None
    done_tick: Optional[int] = None
    slot: Optional[int] = None
    samples: int = 0
    flags: int = 0
    prefill_chunks: int = 0
    decode_steps: int = 0
    chunk_latency_s: List[float] = field(default_factory=list)

    @property
    def queue_wait_ticks(self) -> Optional[int]:
        if self.admitted_tick is None:
            return None
        return self.admitted_tick - self.submitted_tick


class _Run:
    """Internal per-request runtime record (admitted requests only)."""

    __slots__ = ("req", "slot", "pending", "cursor", "phase", "stats",
                 "ecc_parts", "outlier_parts")

    def __init__(self, req: Request, slot: int, stats: RequestStats):
        self.req = req
        self.slot = slot
        self.pending = np.asarray(req.history, np.float32).reshape(-1)
        self.cursor = 0
        self.phase = PREFILL if self.avail else DECODE
        self.stats = stats
        self.ecc_parts: List[np.ndarray] = []
        self.outlier_parts: List[np.ndarray] = []

    @property
    def avail(self) -> int:
        return self.pending.shape[0] - self.cursor

    def push(self, samples: np.ndarray) -> None:
        samples = np.asarray(samples, np.float32).reshape(-1)
        # drop the consumed prefix before growing, keeping push O(new)
        if self.cursor:
            self.pending = self.pending[self.cursor:]
            self.cursor = 0
        self.pending = np.concatenate([self.pending, samples])

    def take(self, n: int) -> np.ndarray:
        out = self.pending[self.cursor:self.cursor + n]
        self.cursor += n
        return out


class BatchingScheduler:
    """Continuous batching of TEDA detection requests over a SlotPool.

    >>> sched = BatchingScheduler("pallas", chunk_t=64)
    >>> sched.submit(Request("tenant-a", history, m=2.5))
    >>> sched.feed("tenant-a", live_chunk); sched.step()
    >>> sched.close("tenant-a"); sched.drain()
    >>> sched.results("tenant-a")["outlier"]

    One `step()` = admit what fits, one fused ragged (chunk_t, C) call
    retiring min(pending, chunk_t) samples per slot, retire what
    finished.  All engine options pass through to the pool.
    """

    def __init__(self, backend: str = "scan", *,
                 buckets: Tuple[int, ...] = (8, 16, 32, 64),
                 chunk_t: int = 32, m: float = 3.0,
                 queue_limit: int = 64, collect: bool = True,
                 measure_latency: bool = False,
                 keep_finished: int = 1024,
                 call_log_len: int = 4096, **engine_opts):
        if chunk_t < 2:
            raise ValueError("chunk_t must be >= 2")
        # decode-only ticks retire 1 sample/slot of the (chunk_t, C)
        # program: a small block keeps the padded time extent (and
        # interpret-mode cost) proportionate
        engine_opts.setdefault("block_t", 8)
        self.pool = SlotPool(backend, buckets=buckets, m=m, **engine_opts)
        self.chunk_t = int(chunk_t)
        self.queue_limit = int(queue_limit)
        self.collect = collect
        self.measure_latency = measure_latency
        # retention caps: a forever-running gateway must not accumulate
        # per-request records without bound.  The oldest finished
        # requests (results + telemetry; their rid becomes reusable)
        # and engine-call log entries are evicted past these limits.
        self.keep_finished = int(keep_finished)
        self.queue: deque[Request] = deque()
        self.runs: Dict[str, _Run] = {}     # admitted, not yet done
        self._finished: Dict[str, _Run] = {}
        self.stats_by_rid: Dict[str, RequestStats] = {}
        self.tick_no = 0
        self.rejected = 0
        self.completed = 0
        self.call_log: deque = deque(maxlen=int(call_log_len))

    # --------------------------------------------------------- intake
    def submit(self, req: Request) -> bool:
        """Queue a request for admission; False = queue full (caller
        backpressure — retry later or shed load)."""
        if req.rid in self.stats_by_rid:
            raise ValueError(f"duplicate request id {req.rid!r}")
        if len(self.queue) >= self.queue_limit:
            self.rejected += 1
            return False
        self.stats_by_rid[req.rid] = RequestStats(
            rid=req.rid, submitted_tick=self.tick_no)
        self.queue.append(req)
        return True

    def feed(self, rid: str, samples) -> None:
        """Append live (decode-phase) samples to a request's stream."""
        run = self.runs.get(rid)
        if run is not None:
            if run.req.closed:
                raise ValueError(f"request {rid!r} is closed")
            run.push(samples)
            return
        for req in self.queue:  # not yet admitted: samples are backlog
            if req.rid == rid:
                if req.closed:
                    raise ValueError(f"request {rid!r} is closed")
                req.history = np.concatenate(
                    [np.asarray(req.history, np.float32).reshape(-1),
                     np.asarray(samples, np.float32).reshape(-1)])
                return
        raise KeyError(f"unknown or finished request {rid!r}")

    def close(self, rid: str) -> None:
        """No more live samples: the request completes once drained."""
        run = self.runs.get(rid)
        if run is not None:
            run.req.closed = True
            return
        for req in self.queue:
            if req.rid == rid:
                req.closed = True
                return
        raise KeyError(f"unknown or finished request {rid!r}")

    # --------------------------------------------------------- the tick
    def _admit(self, events: dict) -> None:
        while self.queue:
            req = self.queue[0]
            try:
                slot = int(self.pool.acquire(1, m=req.m)[0])
            except PoolFull:
                break  # pool backpressure: wait for a release
            self.queue.popleft()
            st = self.stats_by_rid[req.rid]
            st.admitted_tick = self.tick_no
            st.slot = slot
            self.runs[req.rid] = _Run(req, slot, st)
            events["admitted"].append(req.rid)

    def _call(self, members: List[_Run], events: dict) -> None:
        """One fused ragged (chunk_t, C) engine call: slot c retires
        min(pending_c, chunk_t) samples via the per-slot valid-length
        vector; everyone else is suspended at vlen=0."""
        cap = self.pool.capacity
        t_len = self.chunk_t
        x = np.zeros((t_len, cap), np.float32)
        vlens = np.zeros((cap,), np.int32)
        taken: Dict[str, int] = {}
        for run in members:
            n = min(run.avail, t_len)
            x[:n, run.slot] = run.take(n)
            vlens[run.slot] = n
            taken[run.req.rid] = n
        t0 = time.perf_counter()
        out = self.pool.process(x, valid_lens=vlens)
        if self.measure_latency:
            jax.block_until_ready(out["ecc"])
        wall = time.perf_counter() - t0
        self.call_log.append({"kind": "fused", "t": t_len,
                              "slots": len(members),
                              "retired": int(vlens.sum()),
                              "wall_s": wall})
        outlier = np.asarray(out["outlier"])
        ecc = np.asarray(out["ecc"]) if self.collect else None
        for run in members:
            st = run.stats
            n = taken[run.req.rid]
            st.samples += n
            if len(st.chunk_latency_s) < 4096:  # bounded per request
                st.chunk_latency_s.append(wall)
            col = outlier[:n, run.slot]
            nf = int(col.sum())
            st.flags += nf
            if nf:
                events["flagged"].append(run.req.rid)
            if n > 1:
                st.prefill_chunks += 1  # a multi-sample (chunked) ride
            else:
                st.decode_steps += 1    # the 1-sample decode trickle
            if self.collect:
                run.ecc_parts.append(ecc[:n, run.slot].copy())
                run.outlier_parts.append(col.copy())

    def step(self) -> dict:
        """One scheduler tick; returns {admitted, flagged, completed}."""
        self.tick_no += 1
        events: dict = {"admitted": [], "flagged": [], "completed": []}
        self._admit(events)

        ready = [r for r in self.runs.values() if r.avail > 0]
        if ready:
            self._call(ready, events)

        for rid in [rid for rid, r in self.runs.items()
                    if r.req.closed and r.avail == 0]:
            run = self.runs.pop(rid)
            run.phase = DONE
            run.stats.done_tick = self.tick_no
            self.pool.release([run.slot])
            self.completed += 1
            events["completed"].append(rid)
            self._finished[rid] = run
            while len(self._finished) > self.keep_finished:
                old = next(iter(self._finished))  # oldest completion
                del self._finished[old]
                self.stats_by_rid.pop(old, None)
        return events

    def drain(self, max_ticks: int = 100_000) -> int:
        """Tick until every submitted request has completed; returns
        the number of ticks it took."""
        start = self.tick_no
        while self.queue or self.runs:
            if self.tick_no - start >= max_ticks:
                raise RuntimeError(
                    f"drain exceeded {max_ticks} ticks with "
                    f"{len(self.queue)} queued / {len(self.runs)} running"
                    " requests (open requests need close())")
            self.step()
        return self.tick_no - start

    # --------------------------------------------------------- results
    def results(self, rid: str) -> dict:
        """Per-sample verdicts of a request, in stream order."""
        run = self.runs.get(rid) or self._finished.get(rid)
        if run is None:
            raise KeyError(f"unknown request {rid!r}")
        if not self.collect:
            raise RuntimeError("scheduler built with collect=False")
        cat = (lambda parts, dt: np.concatenate(parts)
               if parts else np.zeros((0,), dt))
        return {"ecc": cat(run.ecc_parts, np.float32),
                "outlier": cat(run.outlier_parts, bool)}

    def telemetry(self, rid: str) -> RequestStats:
        return self.stats_by_rid[rid]

    def stats(self) -> dict:
        """Aggregate scheduler telemetry (the serving-bench payload)."""
        walls = [c["wall_s"] for c in self.call_log]
        lat = {}
        if walls:
            lat = {"calls": len(walls),
                   "p50_ms": float(np.percentile(walls, 50) * 1e3),
                   "p95_ms": float(np.percentile(walls, 95) * 1e3)}
        return {"ticks": self.tick_no, "completed": self.completed,
                "running": len(self.runs), "queued": len(self.queue),
                "rejected_submits": self.rejected,
                "chunk_latency": lat, "pool": self.pool.stats()}
