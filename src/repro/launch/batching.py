"""Continuous-batching request scheduler: an engine slot *is* the
request lifecycle.

The paper's FPGA keeps detection at line rate because the pipeline
never drains between streams; the software analogue is continuous
batching: requests attach to a `SlotPool` slot on arrival, replay
their history through the engine in fixed-size chunks (chunked
prefill — long histories never trigger a fresh compile because the
chunk shape is constant), interleave with the decode-phase trickle of
live samples every tick, and detach/recycle the slot on completion.

ONE compiled (chunk_t, C) program per capacity bucket serves every
tenant mix: each tick makes a single fused engine call in which slot c
retires `min(pending_c, chunk_t)` samples via the engine's per-slot
`valid_lens` vector — a prefill-heavy slot rides the full chunk, a
decode-phase slot retires its one live sample, and a slot with nothing
pending is suspended at vlen=0 (frozen state, no flags, no detach) —
all in the same call.

Three scheduler-level optimisations ride on that fused call:

  * **Async double-buffered tick loop** — `step()` dispatches the
    fused call and returns without fetching its outputs (JAX async
    dispatch keeps the device busy); the next tick's host bookkeeping
    (admission, `take`, vlens assembly) overlaps with the in-flight
    device compute, and the *previous* tick's outputs are fetched only
    then — or earlier, when `results()`/`telemetry()` consume them or
    a request completes.  Bit-exact with the synchronous loop: the
    engine-call sequence depends only on host-side counters, never on
    fetched verdicts (`tests/test_batching.py::test_async_equals_sync`).
    `measure_latency=True` keeps the fully synchronous loop (block
    after every call) so per-call wall times stay honest.

  * **Deep dispatch pipeline** — `pipeline_depth=d` keeps up to `d`
    fused calls dispatched-but-unfetched at once.  Slots touched by a
    still-in-flight call are *fenced* from re-dispatch (each slot sits
    in at most one in-flight call, so its chunks are fetched in
    dispatch order no matter when each call retires), which makes
    retirement safely out-of-order: any in-flight call whose outputs
    have already landed retires immediately, and the oldest call is
    force-retired when the pipeline is full — or when every ready slot
    is fenced, so a tick with work always dispatches.  Gateway-visible
    results are bit-exact with depth 1 (chunk-exactness makes the
    per-slot sample stream independent of how ticks partition it).
    Depth beyond 1 pays off under staggered load — admission waves and
    decode trickles touching disjoint slot sets — where successive
    calls genuinely overlap on device; under uniform load every ready
    slot is fenced by the previous call and the loop degrades
    gracefully to the depth-1 double buffer.  `measure_latency=True`
    overrides the pipeline (every call blocks at dispatch), keeping
    wall times honest.

  * **Adaptive chunk_t** — when every ready slot is in decode phase
    (pending <= `decode_t`, default 1), the tick rides a short cached
    (decode_t, C) program instead of the full (chunk_t, C) one:
    decode-only ticks stop paying a chunk_t-deep program to retire one
    sample per slot.  Both shapes are cached per capacity bucket (the
    jit program cache keyed on (capacity, t) — see
    `SlotPool.stats()["programs"]`), so after warmup no tick
    recompiles.

  * **Priority classes / weighted admission** — `Request(priority=)`
    names an admission class; `class_weights` gives each class a
    weighted-deficit share of slot acquisitions, so a burst of bulk
    prefills cannot starve latency-class tenants.  Per-class
    queue-wait/latency telemetry is in `stats()["classes"]`.

Ragged interleaved execution is bit-exact with running each request
alone — per-slot valid-length masking inside the kernels
(tests/test_ragged.py) plus slot independence, verified end-to-end by
tests/test_batching.py on the Q path.

Admission is a bounded queue: `submit` returns False when the queue is
full (caller backpressure), and requests wait in their class queue
while every bucket of the pool is occupied (`PoolFull` backpressure
inside the scheduler).  Per-request telemetry (queue wait, per-call
(wall, retired) latency pairs, flag counts) is kept for the serving
benchmark and the gateway in `launch/serve.py`.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.engine import PoolFull, ShardedPool, SlotPool
from repro.obs import (EventBus, LATENCY_MS_BUCKETS, MetricsRegistry,
                       NULL_TRACER, TICK_BUCKETS, auto_name)

__all__ = ["Request", "RequestStats", "BatchingScheduler",
           "EvictedRequest"]

QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"


class EvictedRequest(KeyError):
    """The request completed but its record aged out of the
    `keep_finished` retention window — distinct from a rid that was
    never submitted, so callers can tell "gone" from "wrong"."""


@dataclass
class Request:
    """One tenant stream: a history to replay + live samples to come.

    `m` is this tenant's outlier sensitivity (None: scheduler default).
    `closed` requests complete once their pending samples drain; open
    requests keep their slot and wait for `feed`.  `priority` names
    the admission class (see `BatchingScheduler(class_weights=)`).

    Under the ensemble backend, `detectors` selects this tenant's
    detector subset and `vote` its vote mode / threshold fraction
    (None: the backend's defaults) — threaded to the slot at admission
    (`SlotPool.acquire` -> `StreamEngine.attach`).
    """

    rid: str
    history: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.float32))
    m: Optional[float] = None
    closed: bool = False
    priority: str = "default"
    detectors: Optional[Tuple[str, ...]] = None
    vote: Optional[object] = None


@dataclass
class RequestStats:
    """Per-request telemetry, filled in as the lifecycle advances.

    `chunk_latency_s` holds (wall_s, retired_this_call) pairs: the
    fused call's wall time is shared by every member slot, so honest
    percentiles weight each observation by the samples that request
    actually retired in the call, instead of attributing the whole
    wall to a slot that retired one sample.
    """

    rid: str
    submitted_tick: int
    priority: str = "default"
    admitted_tick: Optional[int] = None
    done_tick: Optional[int] = None
    slot: Optional[int] = None
    # sharded scheduling only: the current shard (None on a single
    # pool) and how many times the rebalancer moved this stream
    shard: Optional[int] = None
    migrations: int = 0
    samples: int = 0
    flags: int = 0
    prefill_chunks: int = 0
    decode_steps: int = 0
    chunk_latency_s: List[Tuple[float, int]] = field(default_factory=list)
    # ensemble backend only: per-detector flag counts ({name: count},
    # selection-masked — an unselected detector never appears)
    det_flags: Dict[str, int] = field(default_factory=dict)
    # ensemble backend only: per-detector score-stream sums over every
    # retired sample ({name: float} — the kernel's float score streams,
    # NOT selection-gated; divide by `samples` for the running mean)
    det_scores: Dict[str, float] = field(default_factory=dict)

    @property
    def queue_wait_ticks(self) -> Optional[int]:
        if self.admitted_tick is None:
            return None
        return self.admitted_tick - self.submitted_tick


class _Run:
    """Internal per-request runtime record (admitted requests only)."""

    __slots__ = ("req", "slot", "shard", "pending", "cursor", "phase",
                 "stats", "ecc_parts", "outlier_parts", "hist_len",
                 "consumed", "inflight")

    def __init__(self, req: Request, slot: int, stats: RequestStats,
                 shard: int = 0):
        self.req = req
        self.slot = slot
        self.shard = shard
        self.pending = np.asarray(req.history, np.float32).reshape(-1)
        self.cursor = 0
        # the replayed prefix: everything backlogged at admission is
        # prefill; samples fed after admission are the decode trickle
        self.hist_len = self.pending.shape[0]
        self.consumed = 0
        self.phase = PREFILL if self.avail else DECODE
        self.stats = stats
        self.ecc_parts: List[np.ndarray] = []
        self.outlier_parts: List[np.ndarray] = []
        self.inflight = 0  # dispatched calls not yet host-fetched

    @property
    def avail(self) -> int:
        return self.pending.shape[0] - self.cursor

    @property
    def place(self) -> Tuple[int, int]:
        """(shard, local slot) — the fencing key: local slot indices
        collide across shards, the pair never does."""
        return (self.shard, self.slot)

    def push(self, samples: np.ndarray) -> None:
        samples = np.asarray(samples, np.float32).reshape(-1)
        # drop the consumed prefix before growing, keeping push O(new)
        if self.cursor:
            self.pending = self.pending[self.cursor:]
            self.cursor = 0
        self.pending = np.concatenate([self.pending, samples])

    def take(self, n: int) -> np.ndarray:
        out = self.pending[self.cursor:self.cursor + n]
        self.cursor += n
        self.consumed += n
        if self.phase == PREFILL and self.consumed >= self.hist_len:
            self.phase = DECODE  # history cursor passed the prefix
        return out


class _InFlight:
    """One dispatched-but-unfetched fused call (device arrays are JAX
    async futures; fetching them is the sync point)."""

    __slots__ = ("out", "members", "t_len", "tick", "t0", "sync_wall",
                 "shard")

    def __init__(self, out, members, t_len, tick, t0, sync_wall,
                 shard=None):
        self.out = out              # {"ecc", "outlier"} device arrays
        self.members = members      # [(run, col, n)] at dispatch time
        self.t_len = t_len
        self.tick = tick
        self.t0 = t0
        self.sync_wall = sync_wall  # honest wall when measured sync
        self.shard = shard          # which shard's engine ran the call


def _host_ready(out) -> bool:
    """True when a dispatched call's outputs have already landed (its
    fetch would not block).  `jax.Array.is_ready` where available;
    conservatively False otherwise — the depth bound still forces
    retirement, so opportunism is an optimization, never a liveness
    requirement."""
    is_ready = getattr(out["outlier"], "is_ready", None)
    if is_ready is None:
        return False
    try:
        return bool(is_ready())
    except Exception:
        return False


class BatchingScheduler:
    """Continuous batching of TEDA detection requests over a SlotPool.

    >>> sched = BatchingScheduler("pallas", chunk_t=64,
    ...                           class_weights={"latency": 4, "bulk": 1})
    >>> sched.submit(Request("tenant-a", history, m=2.5,
    ...                      priority="latency"))
    >>> sched.feed("tenant-a", live_chunk); sched.step()
    >>> sched.close("tenant-a"); sched.drain()
    >>> sched.results("tenant-a")["outlier"]

    One `step()` = admit what the deficit-weighted class queues allow,
    one fused ragged engine call retiring min(pending, t) samples per
    slot on the adaptive (t, C) program, retire the *previous* tick's
    host-fetched outputs, complete what finished.  All engine options
    pass through to the pool.
    """

    def __init__(self, backend: str = "scan", *,
                 buckets: Tuple[int, ...] = (8, 16, 32, 64),
                 chunk_t: int = 32, decode_t: int = 1, m: float = 3.0,
                 queue_limit: int = 64, collect: bool = True,
                 measure_latency: bool = False,
                 pipeline_depth: int = 1,
                 keep_finished: int = 1024,
                 call_log_len: int = 4096,
                 latency_log_len: int = 4096,
                 class_weights: Optional[Dict[str, float]] = None,
                 shards: int = 1, shard_devices=None,
                 ring_vnodes: int = 128,
                 rebalance_every: int = 0,
                 rebalance_threshold: int = 2,
                 registry=None, tracer=None,
                 name: Optional[str] = None,
                 **engine_opts):
        if chunk_t < 2:
            raise ValueError("chunk_t must be >= 2")
        if not 1 <= decode_t <= chunk_t:
            raise ValueError(
                f"decode_t must lie in [1, chunk_t={chunk_t}], "
                f"got {decode_t}")
        # observability (repro.obs): the scheduler's hand-rolled
        # counters live in registry instruments now — `stats()` reads
        # them back, the tracer records tick spans, the event bus
        # streams verdicts at retirement (`subscribe()`)
        self.registry = (MetricsRegistry() if registry is None
                         else registry)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.name = auto_name("sched") if name is None else str(name)
        self.events = EventBus()
        self._init_instruments()
        # decode-only ticks retire 1 sample/slot of the (decode_t, C)
        # program: a small block keeps the padded time extent (and
        # interpret-mode cost) proportionate
        engine_opts.setdefault("block_t", 8)
        # shards > 1 swaps the single SlotPool for a ShardedPool: one
        # logical pool over N shards with consistent-hash routing and
        # live migration; each tick dispatches one fused call per shard
        # with work, async and fenced exactly like the single pool
        self.n_shards = int(shards)
        if self.n_shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self._sharded = self.n_shards > 1
        self.rebalance_every = int(rebalance_every)
        if self.rebalance_every < 0:
            raise ValueError(
                f"rebalance_every must be >= 0, got {rebalance_every}")
        if self._sharded:
            self.pool = ShardedPool(
                backend, shards=self.n_shards, buckets=buckets, m=m,
                vnodes=ring_vnodes, devices=shard_devices,
                rebalance_threshold=rebalance_threshold,
                registry=self.registry, tracer=self.tracer,
                events=self.events, name=f"{self.name}/pool",
                **engine_opts)
        else:
            self.pool = SlotPool(backend, buckets=buckets, m=m,
                                 registry=self.registry,
                                 tracer=self.tracer,
                                 name=f"{self.name}/pool", **engine_opts)
        # detector-ensemble serving: when the backend carries a
        # detector axis, verdict columns come back as per-detector flag
        # bitmasks ("ecc" stream) and the scheduler accounts flags per
        # detector at retirement
        be = self.pool.engine.backend
        self._ensemble = bool(getattr(be, "aux_rows", 0))
        self._det_names: Tuple[str, ...] = tuple(
            getattr(be, "detectors", ()) or ())
        self.chunk_t = int(chunk_t)
        self.decode_t = int(decode_t)
        self.queue_limit = int(queue_limit)
        self.collect = collect
        # measure_latency=True keeps the synchronous loop (block after
        # every fused call) so per-call wall times are honest device
        # latencies; False runs the async double-buffered loop
        self.measure_latency = measure_latency
        # pipeline_depth > 1 keeps several fused calls in flight with
        # slot fencing + out-of-order retirement (see module docs);
        # depth 1 is the PR 5 double buffer, bit-for-bit
        self.pipeline_depth = int(pipeline_depth)
        if self.pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}")
        # retention caps: a forever-running gateway must not accumulate
        # per-request records without bound.  The oldest finished
        # requests (results + telemetry; their rid becomes reusable)
        # and engine-call log entries are evicted past these limits.
        self.keep_finished = int(keep_finished)
        self.latency_log_len = int(latency_log_len)
        if class_weights is not None and any(
                w <= 0 for w in class_weights.values()):
            raise ValueError(
                f"class weights must be positive: {class_weights}")
        self._weights: Dict[str, float] = dict(class_weights or {})
        self._ctor_classes = frozenset(self._weights)
        self._queues: "OrderedDict[str, deque]" = OrderedDict()
        self._deficit: Dict[str, float] = {}
        self.runs: Dict[str, _Run] = {}     # admitted, not yet done
        self._finished: Dict[str, _Run] = {}
        self._evicted: deque = deque(maxlen=max(4096, self.keep_finished))
        # rid -> live entries in the ring (a rid can re-enter after a
        # resubmit cycle, so membership is refcounted, not a set)
        self._evicted_counts: Dict[str, int] = {}
        self.stats_by_rid: Dict[str, RequestStats] = {}
        self.call_log: deque = deque(maxlen=int(call_log_len))
        self._inflight: deque = deque()   # dispatched, not host-fetched
        self._deferred_flagged: List[str] = []

    def _init_instruments(self) -> None:
        """Create the scheduler's registry instruments (the counters
        `tick_no`/`completed`/`rejected`/`short_ticks` read back as
        properties, plus the running latency/wait histograms that make
        `stats()` an O(1) snapshot)."""
        reg, lbl = self.registry, {"sched": self.name}
        self._c_ticks = reg.counter(
            "sched_ticks_total", "scheduler ticks",
            ("sched",)).labels(**lbl)
        self._c_short = reg.counter(
            "sched_short_ticks_total",
            "ticks that rode the short (decode_t, C) program",
            ("sched",)).labels(**lbl)
        self._c_completed = reg.counter(
            "sched_completed_total", "requests completed",
            ("sched",)).labels(**lbl)
        self._c_rejected = reg.counter(
            "sched_rejected_submits_total",
            "submits rejected by the bounded admission queue",
            ("sched",)).labels(**lbl)
        self._c_submitted = reg.counter(
            "sched_submitted_total", "requests accepted into a queue",
            ("sched",)).labels(**lbl)
        self._c_calls = reg.counter(
            "sched_calls_total", "fused engine calls dispatched",
            ("sched",)).labels(**lbl)
        self._c_samples = reg.counter(
            "sched_samples_retired_total",
            "samples retired across all requests",
            ("sched",)).labels(**lbl)
        self._c_flags = reg.counter(
            "sched_flags_total", "outlier verdicts raised",
            ("sched",)).labels(**lbl)
        self._g_inflight = reg.gauge(
            "sched_inflight_calls",
            "dispatched fused calls not yet host-fetched",
            ("sched",)).labels(**lbl)
        self._h_wall = reg.histogram(
            "sched_call_wall_ms",
            "fused-call wall time, weighted by samples retired",
            ("sched",), buckets=LATENCY_MS_BUCKETS).labels(**lbl)
        # per-class families: children created lazily per priority
        self._f_queued = reg.gauge(
            "sched_class_queued", "requests waiting for admission",
            ("sched", "class"))
        self._f_running = reg.gauge(
            "sched_class_running", "admitted, not yet completed",
            ("sched", "class"))
        self._f_cls_done = reg.counter(
            "sched_class_completed_total", "completions per class",
            ("sched", "class"))
        self._f_wait = reg.histogram(
            "sched_queue_wait_ticks", "submit-to-admission wait",
            ("sched", "class"), buckets=TICK_BUCKETS)
        self._f_latency = reg.histogram(
            "sched_request_latency_ticks", "submit-to-done latency",
            ("sched", "class"), buckets=TICK_BUCKETS)
        self._classes: Dict[str, dict] = {}
        # per-detector flag counts under the ensemble backend; children
        # created lazily per member detector at first flag
        self._f_det_flags = reg.counter(
            "sched_detector_flags_total",
            "per-detector flags raised (ensemble backend, "
            "selection-masked)", ("sched", "detector"))
        self._det_counters: Dict[str, object] = {}

    def _det_counter(self, detector: str):
        c = self._det_counters.get(detector)
        if c is None:
            c = self._f_det_flags.labels(sched=self.name,
                                         detector=detector)
            self._det_counters[detector] = c
        return c

    def _cls(self, cls: str) -> dict:
        """The cached per-class instrument children for one priority."""
        ch = self._classes.get(cls)
        if ch is None:
            lbl = {"sched": self.name, "class": cls}
            ch = {"queued": self._f_queued.labels(**lbl),
                  "running": self._f_running.labels(**lbl),
                  "completed": self._f_cls_done.labels(**lbl),
                  "wait": self._f_wait.labels(**lbl),
                  "latency": self._f_latency.labels(**lbl)}
            self._classes[cls] = ch
        return ch

    # ------------------------------------------- registry-backed counts
    @property
    def tick_no(self) -> int:
        return int(self._c_ticks.value)

    @property
    def completed(self) -> int:
        return int(self._c_completed.value)

    @property
    def rejected(self) -> int:
        return int(self._c_rejected.value)

    @property
    def short_ticks(self) -> int:
        """Ticks that rode the (decode_t, C) program."""
        return int(self._c_short.value)

    def subscribe(self, maxlen: int = 4096):
        """A `Subscription` streaming this scheduler's events
        (admitted / chunk_retired / done / evicted) as they flush —
        verdicts at retirement, not completion.  See `repro.obs.events`."""
        return self.events.subscribe(maxlen=maxlen)

    # --------------------------------------------------------- intake
    @property
    def queue(self) -> List[Request]:
        """Queued-for-admission requests across every class (FIFO
        within a class; class interleaving is decided at admission)."""
        return [req for q in self._queues.values() for req in q]

    @property
    def queued_total(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def submit(self, req: Request) -> bool:
        """Queue a request for admission; False = queue full (caller
        backpressure — retry later or shed load)."""
        if req.rid in self.stats_by_rid:
            raise ValueError(f"duplicate request id {req.rid!r}")
        if self.queued_total >= self.queue_limit:
            self._c_rejected.inc()
            return False
        # rid is reusable post-evict (stale ring entries age out inert)
        self._evicted_counts.pop(req.rid, None)
        if req.priority not in self._weights:
            # unknown classes admit at unit weight (documented) rather
            # than rejecting: the weights dict is a tuning knob
            self._weights[req.priority] = 1.0
        self.stats_by_rid[req.rid] = RequestStats(
            rid=req.rid, submitted_tick=self.tick_no,
            priority=req.priority)
        self._queues.setdefault(req.priority, deque()).append(req)
        self._c_submitted.inc()
        self._cls(req.priority)["queued"].inc()
        return True

    def feed(self, rid: str, samples) -> None:
        """Append live (decode-phase) samples to a request's stream."""
        run = self.runs.get(rid)
        if run is not None:
            if run.req.closed:
                raise ValueError(f"request {rid!r} is closed")
            run.push(samples)
            return
        for req in self.queue:  # not yet admitted: samples are backlog
            if req.rid == rid:
                if req.closed:
                    raise ValueError(f"request {rid!r} is closed")
                req.history = np.concatenate(
                    [np.asarray(req.history, np.float32).reshape(-1),
                     np.asarray(samples, np.float32).reshape(-1)])
                return
        raise KeyError(f"unknown or finished request {rid!r}")

    def close(self, rid: str) -> None:
        """No more live samples: the request completes once drained."""
        run = self.runs.get(rid)
        if run is not None:
            run.req.closed = True
            return
        for req in self.queue:
            if req.rid == rid:
                req.closed = True
                return
        raise KeyError(f"unknown or finished request {rid!r}")

    # --------------------------------------------------------- the tick
    def _admit(self, events: dict) -> None:
        """Weighted-deficit round robin across the class queues.

        Every pass tops each backlogged class's deficit up by its
        weight; a class admits heads while its deficit covers the unit
        cost.  Drained classes are pruned entirely (no deficit
        hoarding, and per-class state stays bounded by the *backlogged*
        class count, not every priority string ever seen — ctor-declared
        weights are the one retained configuration), `PoolFull` ends
        the round — leftover deficits carry to the next tick, so a
        class starved by backpressure catches up first.

        Sharded pools narrow the backpressure: `PoolFull` from one
        shard's ladder blocks only the class whose head is routed
        there (FIFO within the class holds); other classes keep
        admitting — their streams may route to shards with room.  On a
        single pool a full ladder still ends the whole round, exactly
        as before.
        """
        blocked: set = set()
        while True:
            for c in [c for c, q in self._queues.items() if not q]:
                del self._queues[c]
                self._deficit.pop(c, None)
                if c not in self._ctor_classes:
                    self._weights.pop(c, None)
            backlogged = [c for c in self._queues if c not in blocked]
            if not backlogged:
                return
            # top every backlogged class up *before* admitting, so a
            # round cut short by PoolFull credits all of them equally
            for cls in backlogged:
                self._deficit[cls] = (self._deficit.get(cls, 0.0)
                                      + self._weights[cls])
            for cls in backlogged:
                q = self._queues[cls]
                while q and self._deficit[cls] >= 1.0:
                    req = q[0]
                    try:
                        if self._sharded:
                            shard, slot = self.pool.acquire(
                                req.rid, m=req.m,
                                detectors=req.detectors, vote=req.vote)
                        else:
                            shard, slot = 0, int(self.pool.acquire(
                                1, m=req.m, detectors=req.detectors,
                                vote=req.vote)[0])
                    except PoolFull:
                        if not self._sharded:
                            return  # whole pool full: round over
                        blocked.add(cls)  # this head's shard is full
                        break
                    q.popleft()
                    self._deficit[cls] -= 1.0
                    st = self.stats_by_rid[req.rid]
                    st.admitted_tick = self.tick_no
                    st.slot = slot
                    if self._sharded:
                        st.shard = shard
                    self.runs[req.rid] = _Run(req, slot, st,
                                              shard=shard)
                    events["admitted"].append(req.rid)
                    ch = self._cls(req.priority)
                    ch["queued"].dec()
                    ch["running"].inc()
                    ch["wait"].observe(st.queue_wait_ticks)
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "admit", tick=self.tick_no, rid=req.rid,
                            slot=slot, cls=req.priority)
                    self.events.publish(
                        "admitted", self.tick_no, req.rid, slot=slot,
                        priority=req.priority)

    def _dispatch(self, members: List[_Run]) -> None:
        """Dispatch one fused ragged call per shard holding ready
        members (a single call on an unsharded pool).  The per-shard
        split cannot change any slot's retirement: each slot still
        takes n = min(pending, t_len), and the short-tick choice only
        drops t_len when every member of that call fits under it."""
        if not self._sharded:
            self._dispatch_group(members, 0)
            return
        by_shard: Dict[int, List[_Run]] = {}
        for run in members:
            by_shard.setdefault(run.shard, []).append(run)
        for shard in sorted(by_shard):
            self._dispatch_group(by_shard[shard], shard)

    def _dispatch_group(self, members: List[_Run],
                        shard: int) -> None:
        """One fused ragged (t, C) engine call on one shard: slot c
        retires min(pending_c, t) samples via the per-slot
        valid-length vector; everyone else is suspended at vlen=0.
        Decode-only ticks (every member's pending <= decode_t) ride
        the short cached (decode_t, C) program instead of the full
        chunk."""
        cap = (self.pool.shard_capacity(shard) if self._sharded
               else self.pool.capacity)
        t_len = self.chunk_t
        if all(r.avail <= self.decode_t for r in members):
            t_len = self.decode_t
            self._c_short.inc()
        x = np.zeros((t_len, cap), np.float32)
        vlens = np.zeros((cap,), np.int32)
        mem = []
        for run in members:
            n = min(run.avail, t_len)
            x[:n, run.slot] = run.take(n)
            vlens[run.slot] = n
            run.inflight += 1
            mem.append((run, run.slot, n))
        self._c_calls.inc()
        span = (self.tracer.span(
                    "dispatch", device=True, tick=self.tick_no,
                    t=t_len, slots=len(mem), shard=shard,
                    samples=int(sum(n for _, _, n in mem)))
                if self.tracer.enabled else None)
        if span is not None:
            span.__enter__()
        t0 = time.perf_counter()
        if self._sharded:
            out = self.pool.process_shard(shard, x, valid_lens=vlens)
        else:
            out = self.pool.process(x, valid_lens=vlens)
        sync_wall = None
        if self.measure_latency:
            jax.block_until_ready(out["ecc"])
            sync_wall = time.perf_counter() - t0
        if span is not None:
            span.__exit__(None, None, None)
        self._inflight.append(_InFlight(
            out, mem, t_len, self.tick_no, t0, sync_wall,
            shard=shard if self._sharded else None))
        self._g_inflight.set(len(self._inflight))

    def _retire(self, inf: _InFlight, events: Optional[dict]) -> None:
        """Fetch one in-flight call's outputs to host and account them.

        The np.asarray fetch is the sync point; in the async loop it
        lands one tick after dispatch, overlapped with the next call's
        device compute.  With `events=None` (a flush outside `step`),
        flagged rids are deferred into the next tick's events.
        Every member's verdict streams on the event bus here — this is
        the retirement moment, the earliest a verdict exists on host.
        """
        # the ensemble backend's "ecc" stream is the per-detector flag
        # bitmask — fetched even with collect=False, it feeds the
        # per-detector counters below
        want_ecc = self.collect or self._ensemble
        if self.tracer.enabled:
            with self.tracer.span("retire", tick=self.tick_no,
                                  dispatch_tick=inf.tick, t=inf.t_len,
                                  slots=len(inf.members)):
                outlier = np.asarray(inf.out["outlier"])
                ecc = (np.asarray(inf.out["ecc"]) if want_ecc
                       else None)
        else:
            outlier = np.asarray(inf.out["outlier"])
            ecc = np.asarray(inf.out["ecc"]) if want_ecc else None
        # the ensemble's per-detector (K, T, C) float score streams
        # ride the same fetch — per-request sums feed RequestStats /
        # chunk_retired telemetry
        scores = (np.asarray(inf.out["scores"])
                  if self._ensemble and "scores" in inf.out else None)
        wall = (inf.sync_wall if inf.sync_wall is not None
                else time.perf_counter() - inf.t0)
        retired = int(sum(n for _, _, n in inf.members))
        self.call_log.append({
            "kind": "fused", "t": inf.t_len, "slots": len(inf.members),
            "retired": retired,
            "wall_s": wall, "sync": inf.sync_wall is not None})
        # running latency instrument: each call weighted by the samples
        # it retired (stats() reads percentiles back O(1) — the old
        # per-call re-sort of the whole log is gone)
        self._h_wall.observe(wall * 1e3, weight=max(retired, 1))
        self._c_samples.inc(retired)
        stream = self.events.active
        flagged = (events["flagged"] if events is not None
                   else self._deferred_flagged)
        for run, slot, n in inf.members:
            st = run.stats
            st.samples += n
            if len(st.chunk_latency_s) < self.latency_log_len:
                st.chunk_latency_s.append((wall, n))
            col = outlier[:n, slot]
            nf = int(col.sum())
            st.flags += nf
            if nf:
                flagged.append(run.req.rid)
                self._c_flags.inc(nf)
            det_counts = None
            det_sums = None
            if self._ensemble:
                # bit d of the "ecc" bitmask column is detectors[d]
                col_bits = ecc[:n, slot].astype(np.int64)
                det_counts = {}
                for d, det in enumerate(self._det_names):
                    c = int(((col_bits >> d) & 1).sum())
                    if c:
                        det_counts[det] = c
                        self._det_counter(det).inc(c)
                        st.det_flags[det] = st.det_flags.get(det, 0) + c
                if scores is not None and n:
                    # row d of the score block is detectors[d]'s float
                    # score stream over this slot's retired prefix
                    det_sums = {}
                    for d, det in enumerate(self._det_names):
                        s = float(scores[d, :n, slot].sum())
                        det_sums[det] = s
                        st.det_scores[det] = (
                            st.det_scores.get(det, 0.0) + s)
            if n > 1:
                st.prefill_chunks += 1  # a multi-sample (chunked) ride
            else:
                st.decode_steps += 1    # the 1-sample decode trickle
            if self.collect:
                run.ecc_parts.append(ecc[:n, slot].copy())
                run.outlier_parts.append(col.copy())
            if stream:
                data = {"slot": slot, "n": n, "flags": nf,
                        "dispatch_tick": inf.tick,
                        "outlier": col.copy()}
                if inf.shard is not None:
                    data["shard"] = inf.shard
                if self.collect:
                    data["ecc"] = ecc[:n, slot].copy()
                if det_counts is not None:
                    data["det_flags"] = det_counts
                    data["detectors"] = self._det_names
                if det_sums is not None:
                    data["det_scores"] = det_sums
                self.events.publish("chunk_retired", self.tick_no,
                                    run.req.rid, **data)
            run.inflight -= 1
        self._g_inflight.set(len(self._inflight))

    def _flush(self, events: Optional[dict] = None) -> None:
        """Retire every in-flight call (the consume-side sync)."""
        if not self._inflight:
            return
        if self.tracer.enabled:
            with self.tracer.span("flush", tick=self.tick_no,
                                  calls=len(self._inflight)):
                while self._inflight:
                    self._retire(self._inflight.popleft(), events)
            return
        while self._inflight:
            self._retire(self._inflight.popleft(), events)

    def step(self) -> dict:
        """One scheduler tick; returns {admitted, flagged, completed}.

        In the async loop, `flagged` events surface on the tick whose
        retirement fetched them — one tick after dispatch.
        """
        self._c_ticks.inc()
        events: dict = {"admitted": [], "flagged": [], "completed": []}
        if self._deferred_flagged:
            events["flagged"].extend(self._deferred_flagged)
            self._deferred_flagged.clear()
        # host bookkeeping first: admission + take + vlens assembly all
        # overlap with the previous tick's in-flight device compute
        if (self._sharded and self.rebalance_every
                and self.tick_no > 0
                and self.tick_no % self.rebalance_every == 0):
            self._rebalance()
        self._admit(events)
        ready = [r for r in self.runs.values() if r.avail > 0]
        deep = self.pipeline_depth > 1 and not self.measure_latency
        if deep and ready:
            # fence: a slot in a still-in-flight call cannot join a new
            # one (its chunks must be fetched in dispatch order).  When
            # every ready slot is fenced, force-retire oldest calls
            # until one frees up — a tick with work always dispatches.
            # The fence key is (shard, slot): local slot indices
            # collide across shards, the pair never does.
            def _free():
                fenced = {r.place for i in self._inflight
                          for r, _, _ in i.members}
                return [r for r in ready if r.place not in fenced]
            free = _free()
            while not free and self._inflight:
                self._retire(self._inflight.popleft(), events)
                free = _free()
            if free:
                self._dispatch(free)
        elif ready:
            self._dispatch(ready)
        if deep:
            # out-of-order retirement: calls whose outputs already
            # landed on host retire now, whatever their dispatch order
            # (fencing makes per-slot order immune to it); then the
            # oldest calls retire until the pipeline fits its depth
            # (each shard dispatches its own call, so a K-shard pool
            # keeps depth*K calls in flight)
            for inf in [i for i in self._inflight
                        if _host_ready(i.out)]:
                self._inflight.remove(inf)
                self._retire(inf, events)
            depth_cap = self.pipeline_depth * self.n_shards
            while len(self._inflight) > depth_cap:
                self._retire(self._inflight.popleft(), events)
        else:
            # retire everything dispatched *before* this tick; this
            # tick's call stays in flight across the tick boundary (the
            # double buffer) unless the loop is synchronous
            while self._inflight and (
                    self.measure_latency
                    or self._inflight[0].tick < self.tick_no):
                self._retire(self._inflight.popleft(), events)

        done = [rid for rid, r in self.runs.items()
                if r.req.closed and r.avail == 0]
        if any(self.runs[rid].inflight for rid in done):
            # completion consumes results: sync the tail call now so
            # done_tick/telemetry are final the tick the stream drains
            self._flush(events)
        for rid in done:
            run = self.runs.pop(rid)
            run.phase = DONE
            st = run.stats
            st.done_tick = self.tick_no
            if self._sharded:
                self.pool.release(rid)
            else:
                self.pool.release([run.slot])
            self._c_completed.inc()
            ch = self._cls(st.priority)
            ch["running"].dec()
            ch["completed"].inc()
            ch["latency"].observe(st.done_tick - st.submitted_tick)
            events["completed"].append(rid)
            self.events.publish("done", self.tick_no, rid,
                                slot=run.slot, samples=st.samples,
                                flags=st.flags, priority=st.priority)
            self._finished[rid] = run
            while len(self._finished) > self.keep_finished:
                old = next(iter(self._finished))  # oldest completion
                del self._finished[old]
                self.stats_by_rid.pop(old, None)
                self._note_evicted(old)
                self.events.publish("evicted", self.tick_no, old)
        return events

    def _rebalance(self) -> None:
        """Run the pool's occupancy rebalancer and mirror the moves
        into scheduler bookkeeping.  Streams with in-flight calls are
        pinned in place: migration's state fetch must not race a
        dispatched chunk, and the fence key (shard, slot) must stay
        stable while a call referencing it is outstanding."""
        avoid = {rid for rid, r in self.runs.items() if r.inflight}
        moves = self.pool.rebalance(avoid=avoid, tick=self.tick_no)
        for rid, _src, dst, new_slot in moves:
            run = self.runs[rid]
            run.shard = dst
            run.slot = new_slot
            st = run.stats
            st.shard = dst
            st.slot = new_slot
            st.migrations += 1

    def _note_evicted(self, rid: str) -> None:
        if len(self._evicted) == self._evicted.maxlen:
            old = self._evicted.popleft()
            n = self._evicted_counts.get(old, 0) - 1
            if n <= 0:
                self._evicted_counts.pop(old, None)
            else:
                self._evicted_counts[old] = n
        self._evicted.append(rid)
        self._evicted_counts[rid] = self._evicted_counts.get(rid, 0) + 1

    def drain(self, max_ticks: int = 100_000) -> int:
        """Tick until every submitted request has completed; returns
        the number of ticks it took.  Raises immediately — naming the
        rids — when progress is impossible because requests are still
        open (no pending samples, not closed): they hold their slots
        waiting for `feed`, and only `close()` lets them finish."""
        start = self.tick_no
        while self.queued_total or self.runs:
            if self._sharded:
                # pool-wide headroom is not enough here: each class's
                # FIFO head is pinned to its ring shard, so progress
                # needs *that* shard (not just any shard) to have room
                can_admit = any(
                    self.pool.shard_free(self.pool.route(q[0].rid)) > 0
                    for q in self._queues.values() if q)
            else:
                can_admit = bool(self.queued_total) and (
                    self.pool.occupancy < self.pool.max_capacity)
            has_work = (self._inflight
                        or any(r.avail > 0 for r in self.runs.values()))
            completing = any(r.req.closed and r.avail == 0
                             for r in self.runs.values())
            if not (can_admit or has_work or completing):
                open_rids = sorted(rid for rid, r in self.runs.items()
                                   if not r.req.closed)
                raise RuntimeError(
                    f"drain stalled: requests {open_rids} are open with "
                    "no pending samples — they wait on feed() forever; "
                    "close() them (or feed more data) before drain()")
            if self.tick_no - start >= max_ticks:
                raise RuntimeError(
                    f"drain exceeded {max_ticks} ticks with "
                    f"{self.queued_total} queued / {len(self.runs)} "
                    "running requests")
            self.step()
        self._flush()
        return self.tick_no - start

    # --------------------------------------------------------- results
    def _missing(self, rid: str) -> KeyError:
        if rid in self._evicted_counts:
            return EvictedRequest(
                f"request {rid!r} completed and was evicted "
                f"(keep_finished={self.keep_finished}); raise the "
                "retention cap to keep results longer")
        return KeyError(f"unknown request {rid!r}")

    def results(self, rid: str) -> dict:
        """Per-sample verdicts of a request, in stream order.  Syncs
        the async loop: any of the request's in-flight samples are
        fetched before returning."""
        run = self.runs.get(rid) or self._finished.get(rid)
        if run is None:
            raise self._missing(rid)
        if not self.collect:
            raise RuntimeError("scheduler built with collect=False")
        if run.inflight:
            self._flush()  # consume-side sync point
        cat = (lambda parts, dt: np.concatenate(parts)
               if parts else np.zeros((0,), dt))
        return {"ecc": cat(run.ecc_parts, np.float32),
                "outlier": cat(run.outlier_parts, bool)}

    def telemetry(self, rid: str) -> RequestStats:
        """The request's `RequestStats` (sample/flag counts final only
        after its in-flight calls retire — synced here)."""
        st = self.stats_by_rid.get(rid)
        if st is None:
            raise self._missing(rid)
        run = self.runs.get(rid)
        if run is not None and run.inflight:
            self._flush()  # consume-side sync point
        return st

    def request_phase(self, rid: str) -> str:
        """Lifecycle phase of a request: queued/prefill/decode/done."""
        run = self.runs.get(rid)
        if run is not None:
            return run.phase
        if rid in self._finished:
            return DONE
        if rid in self.stats_by_rid:
            return QUEUED
        raise self._missing(rid)

    def stats(self) -> dict:
        """Aggregate scheduler telemetry (the serving-bench payload),
        read back from the obs registry in O(instruments) — nothing is
        re-sorted or re-scanned per call.

        `chunk_latency` percentiles come from the running weighted
        wall-time histogram (each fused call weighted by the samples
        it retired, estimated at bucket edges); `classes` carries
        per-priority-class state counts plus queue-wait and
        completion-latency percentiles over *every* request the class
        ever saw (retention eviction no longer shifts them);
        `programs` lists the (capacity, t) program cache — its size
        going flat after warmup is the no-recompile guarantee of the
        adaptive path.
        """
        lat = {}
        if self._h_wall.count:
            lat = {"calls": len(self.call_log),
                   "p50_ms": self._h_wall.quantile(0.5),
                   "p95_ms": self._h_wall.quantile(0.95)}
        classes: Dict[str, dict] = {}
        for cls, ch in self._classes.items():
            c = {"queued": int(ch["queued"].value),
                 "running": int(ch["running"].value),
                 "completed": int(ch["completed"].value)}
            for key, h in (("queue_wait_ticks", ch["wait"]),
                           ("latency_ticks", ch["latency"])):
                if h.count:
                    c[f"{key}_p50"] = h.quantile(0.5)
                    c[f"{key}_p95"] = h.quantile(0.95)
            classes[cls] = c
        out = {"ticks": self.tick_no, "completed": self.completed,
               "running": len(self.runs), "queued": self.queued_total,
               "rejected_submits": self.rejected,
               "inflight_calls": len(self._inflight),
               "pipeline_depth": self.pipeline_depth,
               "short_ticks": self.short_ticks,
               "chunk_latency": lat, "classes": classes,
               "programs": self.pool.programs(),
               "pool": self.pool.stats()}
        if self._sharded:
            out["shards"] = self.n_shards
            out["migrations"] = self.pool.migrations
            out["imbalance"] = self.pool.imbalance
        if self._ensemble:
            out["detector_flags"] = {
                d: int(c.value) for d, c in self._det_counters.items()}
        return out
