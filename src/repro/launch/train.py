"""End-to-end training driver with TEDA guard + fault tolerance.

Runs on anything from 1 CPU device (reduced configs, examples/tests) to
the production mesh (full configs). Integrates:

  * TEDAGuard inside the jitted train step (loss/grad-norm anomaly ->
    masked update),
  * host-side StragglerDetector on per-step wall time,
  * CheckpointManager (atomic, async, keep-K, auto-resume),
  * TokenStream data pipeline with optional TEDA input screening,
  * crash-and-resume: `--steps N --resume` continues from the latest
    checkpoint with bitwise-identical data order.

Usage (CPU example):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --scale tiny --steps 30 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.core.guard import StragglerDetector, guard_init
from repro.data import TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.specs import GUARD_CFG, make_train_step
from repro.models import init_encdec_params, init_lm_params
from repro.optim import adamw
from repro.sharding.rules import batch_spec

from jax.sharding import NamedSharding


def build_state(cfg, key):
    init = init_encdec_params if cfg.family == "encdec" else init_lm_params
    params = init(key, cfg)
    return params, adamw.init(params), guard_init(GUARD_CFG)


def train(cfg, steps: int, batch: int, seq: int, ckpt_dir: str | None,
          resume: bool = False, mesh=None, corrupt_prob: float = 0.0,
          log_every: int = 10, opt_cfg: adamw.AdamWConfig | None = None,
          save_every: int = 200, guard_cfg=None, corrupt_every: int = 0):
    mesh = mesh or make_host_mesh()
    opt_cfg = opt_cfg or adamw.AdamWConfig(warmup_steps=min(100, steps // 4
                                                            + 1),
                                           total_steps=steps)
    guard_cfg = guard_cfg or GUARD_CFG
    step_fn = make_train_step(cfg, opt_cfg, guard_cfg=guard_cfg)

    params, opt_state, guard_state = build_state(cfg, jax.random.PRNGKey(0))
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state, guard_state), meta = mgr.restore(
            (params, opt_state, guard_state))
        start_step = meta["step"]
        print(f"[train] resumed from step {start_step}")

    b_sh = NamedSharding(mesh, batch_spec(mesh, batch))
    with mesh:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        stream = TokenStream(cfg.vocab, batch, seq,
                             corrupt_prob=corrupt_prob,
                             corrupt_every=corrupt_every)
        straggler = StragglerDetector(m=4.0, warmup=10)
        history = []
        for step in range(start_step, steps):
            data = stream.batch_at(step)
            batch_dev = {k: jax.device_put(jnp.asarray(v), b_sh
                                           if k == "tokens" else None)
                         for k, v in data.items()}
            straggler.tick()
            params, opt_state, guard_state, metrics = jitted(
                params, opt_state, guard_state, batch_dev)
            metrics = jax.device_get(metrics)
            straggled = straggler.tock()
            history.append(metrics)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step={step} loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f} "
                      f"lr={metrics['lr']:.2e} "
                      f"skipped={int(metrics['skipped'])} "
                      f"straggler={straggled}", flush=True)
            if mgr and (step + 1) % save_every == 0:
                mgr.save(step + 1, (params, opt_state, guard_state))
        if mgr:
            mgr.save(steps, (params, opt_state, guard_state))
            mgr.wait()
    skipped_total = int(jax.device_get(guard_state.skipped))
    print(f"[train] done. total guard-skipped steps: {skipped_total}, "
          f"straggler trips: {straggler.trips}")
    return params, history, {"skipped": skipped_total,
                             "straggler_trips": straggler.trips}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--corrupt-prob", type=float, default=0.0)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "tiny":
        cfg = cfg.reduced()
    elif args.scale == "small":  # ~100M-class
        cfg = cfg.reduced(n_layers=max(4, min(cfg.n_layers, 8)),
                          d_model=512, n_heads=8, n_kv=2, head_dim=64,
                          d_ff=1536 if cfg.d_ff else 0, vocab=32768,
                          q_chunk=128, kv_chunk=128)
    mesh = make_production_mesh() if args.production_mesh else None
    train(cfg, args.steps, args.batch, args.seq, args.ckpt,
          resume=args.resume, mesh=mesh, corrupt_prob=args.corrupt_prob)


if __name__ == "__main__":
    main()
