"""Perf hillclimbing driver: re-lower one cell with a named change and
compare roofline terms against its baseline.

Each invocation = one hypothesis->change->measure iteration
(EXPERIMENTS.md §Perf). Results land in experiments/hillclimb/ tagged
with the change name; `--compare` prints the before/after table.

  python -m repro.launch.hillclimb --arch dbrx-132b --shape train_4k \
      --mesh single --tag accum4 --accum 4
  python -m repro.launch.hillclimb --arch dbrx-132b --shape train_4k \
      --mesh single --tag remat_dots --set remat_policy=dots
  python -m repro.launch.hillclimb --compare dbrx_132b train_4k single
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse
import glob
import json


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            continue
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def compare(out_dir: str, arch: str, shape: str, mesh: str):
    rows = []
    for p in sorted(glob.glob(os.path.join(
            out_dir, f"{arch}__{shape}__{mesh}*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    base_dir = os.path.join(os.path.dirname(out_dir), "dryrun")
    base = os.path.join(base_dir, f"{arch}__{shape}__{mesh}.json")
    if os.path.exists(base):
        with open(base) as f:
            rows.insert(0, json.load(f))
    print(f"{'tag':24s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'bound':>10s} {'temp_GiB':>9s} {'frac':>6s}")
    for r in rows:
        t = r["roofline"]
        tag = r.get("tag") or "baseline"
        print(f"{tag:24s} {t['compute_s']:10.4f} {t['memory_s']:10.4f} "
              f"{t['collective_s']:10.4f} {t['bottleneck']:>10s} "
              f"{r['memory']['temp_bytes'] / 2**30:9.2f} "
              f"{t['roofline_fraction']:6.3f}")


def main():
    from repro.launch.dryrun import cell_path, run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="exp")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--sp", action="store_true",
                    help="enable sequence-parallel activation hints")
    ap.add_argument("--rule-flag", action="append", default=[],
                    help="sharding-rule flag key=True/False (repeatable)")
    ap.add_argument("--opt", action="append", default=[],
                    help="AdamWConfig override key=value (repeatable)")
    ap.add_argument("--hints", action="store_true",
                    help="enable activation-sharding hints (batch mode)")
    ap.add_argument("--out", default="experiments/hillclimb")
    ap.add_argument("--compare", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.compare:
        compare(args.out, *args.compare)
        return

    os.makedirs(args.out, exist_ok=True)
    overrides = dict(parse_override(kv) for kv in args.set)
    if args.rule_flag:
        from repro.sharding import rules
        for kv in args.rule_flag:
            k, v = parse_override(kv)
            assert k in rules.RULE_FLAGS, k
            rules.RULE_FLAGS[k] = bool(v)
    from repro.configs.registry import ALIASES
    arch = ALIASES.get(args.arch, args.arch)
    path = cell_path(args.out, arch, args.shape, args.mesh, args.tag)
    if os.path.exists(path) and not args.force:
        print(f"[cached] {path}")
    else:
        opt_over = dict(parse_override(kv) for kv in args.opt)
        res = run_cell(arch, args.shape, args.mesh,
                       cfg_overrides=overrides or None, tag=args.tag,
                       seq_parallel=args.sp or None,
                       accum_steps=args.accum,
                       opt_overrides=opt_over or None, hints=args.hints)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        t = res["roofline"]
        print(f"[{args.tag}] bound={t['bottleneck']} "
              f"compute={t['compute_s']:.4f}s mem={t['memory_s']:.4f}s "
              f"coll={t['collective_s']:.4f}s "
              f"temp={res['memory']['temp_bytes'] / 2**30:.2f}GiB")
    compare(args.out, arch, args.shape, args.mesh)


if __name__ == "__main__":
    main()
