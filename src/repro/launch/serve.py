"""Batched serving driver with TEDA decode-stream monitoring.

Serves a (reduced or full) LM: prefills a prompt batch, then decodes with
the KV-cache path while a multichannel TEDA state watches per-request
telemetry (logit entropy, max-logit) — flagged requests are surfaced the
way a production gateway would quarantine degenerate generations
(repetition collapse, NaN logits, prompt-injection-style OOD inputs).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --scale tiny --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import TedaState, teda_init, teda_step
from repro.models import (init_cache, init_lm_params, lm_decode_step,
                          lm_forward)


def serve(cfg, batch: int, prompt_len: int, gen: int, m: float = 3.5,
          seed: int = 0, greedy: bool = True):
    assert cfg.family != "encdec", "serve example targets decoder-only LMs"
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    max_seq = prompt_len + gen
    caches = init_cache(cfg, batch, max_seq, dtype=jnp.float32)
    decode = jax.jit(
        lambda p, t, pos, c: lm_decode_step(p, t, pos, c, cfg),
        donate_argnums=(3,))

    # prefill by teacher-forcing the prompt through the decode path
    # (keeps one compiled program; a production server would lower a
    # separate chunked-prefill program as in launch/specs.py)
    tok = prompts[:, 0]
    t0 = time.perf_counter()
    for i in range(prompt_len - 1):
        logits, caches = decode(params, prompts[:, i], jnp.int32(i), caches)
    prefill_s = time.perf_counter() - t0

    # TEDA monitor: 2 channels (entropy, max-logit) per request
    teda = teda_init((batch, 2), 1)
    flagged = np.zeros(batch, bool)
    outs = []
    tok = prompts[:, -1]
    t0 = time.perf_counter()
    for step in range(gen):
        pos = jnp.int32(prompt_len - 1 + step)
        logits, caches = decode(params, tok, pos, caches)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)  # (B,)
        mx = jnp.max(logits, axis=-1)
        metrics = jnp.stack([ent, mx], axis=-1)[..., None]  # (B, 2, 1)
        teda, verdict = teda_step(teda, metrics, m)
        flagged |= np.asarray(verdict.outlier).any(axis=-1)
        tok = (jnp.argmax(logits, axis=-1) if greedy else
               jax.random.categorical(jax.random.fold_in(key, step),
                                      logits))
        outs.append(np.asarray(tok))
    decode_s = time.perf_counter() - t0

    toks_out = np.stack(outs, axis=1)
    return {
        "tokens": toks_out,
        "flagged_requests": np.flatnonzero(flagged).tolist(),
        "prefill_tok_s": batch * (prompt_len - 1) / prefill_s,
        "decode_tok_s": batch * gen / decode_s,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "tiny":
        cfg = cfg.reduced()
    res = serve(cfg, args.batch, args.prompt_len, args.gen)
    print(f"[serve] prefill {res['prefill_tok_s']:.1f} tok/s, "
          f"decode {res['decode_tok_s']:.1f} tok/s")
    print(f"[serve] TEDA-flagged requests: {res['flagged_requests']}")
    print(f"[serve] sample continuation (req 0): "
          f"{res['tokens'][0][:16].tolist()}")


if __name__ == "__main__":
    main()
