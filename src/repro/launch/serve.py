"""Serving gateway: continuous-batching TEDA detection + LM monitoring.

Two entry points, both driven by the `launch/batching.py` scheduler
(admission queue, chunked prefill, per-request telemetry, backpressure
when every capacity bucket is full):

  * `serve_streams` — the generic detection gateway: tenant streams
    (history + live samples, per-tenant sensitivity `m`) arrive on a
    schedule, attach to engine slots, and are served continuously.
    This is the workload driver behind `benchmarks/bench_serving.py`.

        PYTHONPATH=src python -m repro.launch.serve --mode streams \
            --requests 16 --history 256 --live 32 --backend pallas

  * `serve` — the LM demo: prefills a prompt batch, then decodes while
    per-request telemetry (logit entropy, max-logit) streams through
    the detection gateway — prompt-phase telemetry replays as chunked
    prefill (the monitor is warmed up on the tenant's own history), and
    decode-phase telemetry rides the per-tick trickle.  Flagged
    requests surface the way a production gateway would quarantine
    degenerate generations.

        PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
            --scale tiny --batch 4 --prompt-len 32 --gen 32

The telemetry itself (log-softmax entropy, max-logit) is computed
*inside* the jitted decode step — the Python loop threads device
arrays and hands the host-side scheduler one small (B, 2) array per
generated token.
"""
from __future__ import annotations

import argparse
import functools
import time
from collections import deque
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch.batching import BatchingScheduler, Request
from repro.models import init_cache, init_lm_params, lm_decode_step

N_CHANNELS = 2  # per-request telemetry: (entropy, max-logit)


# --------------------------------------------------------------- gateway
def serve_streams(streams: Sequence[tuple],
                  *, backend: str = "scan",
                  buckets: Tuple[int, ...] = (8, 16, 32, 64),
                  chunk_t: int = 32, m: float = 3.0, fmt=None,
                  interpret: Optional[bool] = None,
                  queue_limit: int = 64,
                  arrivals_per_tick: Optional[int] = None,
                  feed_per_tick: int = 1, collect: bool = False,
                  measure_latency: bool = True,
                  max_ticks: int = 1_000_000,
                  registry=None, tracer=None, on_event=None,
                  **engine_opts) -> dict:
    """Serve tenant streams through the continuous-batching scheduler.

    `streams` is a sequence of (rid, history, live, m) or
    (rid, history, live, m, priority) tuples — history replays as
    chunked prefill on admission, live samples are fed `feed_per_tick`
    per tick (the decode trickle), `m` is the tenant's sensitivity
    (None: the gateway default), `priority` its admission class (see
    `BatchingScheduler(class_weights=)`; weights pass through
    `engine_opts`, e.g. `class_weights={"latency": 4, "bulk": 1}`).
    Under `backend="ensemble"` a tuple may extend to
    (rid, history, live, m, priority, detectors, vote) — the tenant's
    detector subset and vote mode, threaded to its slot at admission.
    `arrivals_per_tick` models offered load (None: everything offered
    up front); arrivals the admission queue rejects are re-offered
    next tick, counted in `rejected_submits` — the backpressure
    measure.

    With `measure_latency=False` the scheduler runs its async
    double-buffered loop (host bookkeeping overlapped with device
    compute); True keeps the synchronous loop so per-chunk wall times
    are honest latencies.  `pipeline_depth` (via `engine_opts`) keeps
    up to that many fused calls in flight with slot fencing —
    gateway results stay bit-exact with depth 1, but
    `measure_latency=True` overrides it back to the synchronous loop,
    so depth and honest per-call latencies are mutually exclusive
    knobs.  `block_c` (also via `engine_opts`) tiles the kernel grid's
    channel axis for multi-core TPU scaling at wide capacities.
    `shards=K` (with optional `rebalance_every`) swaps the single pool
    for a `ShardedPool`: consistent-hash routing over K device shards,
    one fused call per shard per tick, live migration under the
    occupancy rebalancer — gateway verdicts stay bit-exact with the
    single pool (see README §sharding).

    Observability (`repro.obs`): `registry`/`tracer` pass through to
    the scheduler (and down to pool + engines); `on_event` is a
    callback receiving each streamed `Event` (admitted /
    chunk_retired / done / evicted) as it retires — the push side of
    `BatchingScheduler.subscribe()`.

    Returns sustained rates, latency percentiles, queue-wait stats,
    per-priority-class telemetry, per-request telemetry, and a
    `metrics` registry snapshot.
    """
    class _Rec:
        __slots__ = ("req", "live", "fed", "closed")

        def __init__(self, rid, history, live, m_req,
                     priority="default", detectors=None, vote=None):
            self.req = Request(rid, np.asarray(history, np.float32),
                               priority=priority,
                               detectors=(None if detectors is None
                                          else tuple(detectors)),
                               vote=vote)
            self.req.m = m_req
            self.live = np.asarray(live, np.float32).reshape(-1)
            self.fed = 0
            self.closed = False

    recs = {s[0]: _Rec(*s) for s in streams}
    if len(recs) != len(streams):
        raise ValueError("duplicate request ids in streams")
    # retention must cover the whole run: every request's telemetry is
    # read back after the drain, so none may be evicted mid-run
    engine_opts["keep_finished"] = max(
        engine_opts.get("keep_finished", 1024), len(recs))
    sched = BatchingScheduler(
        backend, buckets=buckets, chunk_t=chunk_t, m=m, fmt=fmt,
        interpret=interpret, queue_limit=queue_limit, collect=collect,
        measure_latency=measure_latency, registry=registry,
        tracer=tracer, **engine_opts)
    if on_event is not None:
        sched.events.attach(on_event)
    waiting = deque(recs.values())
    total_samples = sum(len(r.req.history) + len(r.live)
                        for r in recs.values())

    t0 = time.perf_counter()
    while sched.completed < len(recs):
        if sched.tick_no >= max_ticks:
            raise RuntimeError(f"serve_streams exceeded {max_ticks} ticks")
        budget = len(waiting) if arrivals_per_tick is None \
            else arrivals_per_tick
        while waiting and budget > 0:
            rec = waiting[0]
            if not sched.submit(rec.req):
                break  # queue full: re-offer this arrival next tick
            waiting.popleft()
            budget -= 1
            if not len(rec.live):
                sched.close(rec.req.rid)
                rec.closed = True
        for rec in recs.values():
            if rec.closed or rec.req.rid not in sched.stats_by_rid:
                continue
            take = min(feed_per_tick, len(rec.live) - rec.fed)
            if take:
                sched.feed(rec.req.rid, rec.live[rec.fed:rec.fed + take])
                rec.fed += take
            if rec.fed == len(rec.live):
                sched.close(rec.req.rid)
                rec.closed = True
        sched.step()
    wall = time.perf_counter() - t0

    agg = sched.stats()
    waits = [sched.telemetry(rid).queue_wait_ticks for rid in recs]
    per_request = {
        rid: {"samples": st.samples, "flags": st.flags,
              "queue_wait_ticks": st.queue_wait_ticks,
              "prefill_chunks": st.prefill_chunks,
              "decode_steps": st.decode_steps, "slot": st.slot,
              "shard": st.shard, "migrations": st.migrations,
              "priority": st.priority,
              "det_flags": dict(st.det_flags),
              # ensemble backend only: per-detector mean score over the
              # request's retired samples (the kernel's float score
              # streams, threaded engine -> pool -> scheduler events)
              "det_scores": {d: s / max(st.samples, 1)
                             for d, s in st.det_scores.items()}}
        for rid, st in ((rid, sched.telemetry(rid)) for rid in recs)}
    return {
        "backend": backend, "chunk_t": chunk_t,
        "requests": len(recs), "samples": total_samples,
        "wall_s": wall, "ticks": agg["ticks"],
        "requests_per_s": len(recs) / wall,
        "samples_per_s": total_samples / wall,
        "rejected_submits": agg["rejected_submits"],
        "chunk_latency": agg["chunk_latency"],
        "short_ticks": agg["short_ticks"],
        "programs": agg["programs"],
        "classes": agg["classes"],
        "queue_wait_ticks_p50": float(np.percentile(waits, 50)),
        "queue_wait_ticks_p95": float(np.percentile(waits, 95)),
        "flagged": sorted(rid for rid in recs
                          if sched.telemetry(rid).flags),
        "pool": agg["pool"],
        # sharded gateway only (shards > 1 via engine_opts)
        **{k: agg[k] for k in ("shards", "migrations", "imbalance")
           if k in agg},
        "per_request": per_request,
        "metrics": sched.registry.snapshot(),
        "_scheduler": sched,  # for tests; stripped by the benchmark
    }


# --------------------------------------------------------------- LM demo
def make_decode_step(cfg, greedy: bool):
    """Build the jitted decode step with fused telemetry extraction.

    Returns the sampled token plus the (B,) entropy / max-logit rows
    the monitor gateway consumes — no extra host round-trip beyond the
    one that feeds the scheduler.
    """

    @functools.partial(jax.jit, donate_argnums=(3,))
    def step(params, tok, pos, caches, key):
        logits, caches = lm_decode_step(params, tok, pos, caches, cfg)
        ent, mx = _telemetry(logits)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(jax.random.fold_in(key, pos),
                                         logits)
        return nxt, caches, ent, mx

    return step


@jax.jit
def _telemetry(logits):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)  # (B,)
    mx = jnp.max(logits, axis=-1)                  # (B,)
    return ent, mx


def _monitor_buckets(n_slots: int) -> Tuple[int, ...]:
    """Bucket ladder reaching at least n_slots (powers of two from 8)."""
    ladder = [8]
    while ladder[-1] < n_slots:
        ladder.append(ladder[-1] * 2)
    return tuple(ladder)


def serve(cfg, batch: int, prompt_len: int, gen: int, m: float = 3.5,
          seed: int = 0, greedy: bool = True, backend: str = "scan",
          chunk_t: int = 16, fmt=None):
    assert cfg.family != "encdec", "serve example targets decoder-only LMs"
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    max_seq = prompt_len + gen
    caches = init_cache(cfg, batch, max_seq, dtype=jnp.float32)
    decode = jax.jit(
        lambda p, t, pos, c: lm_decode_step(p, t, pos, c, cfg),
        donate_argnums=(3,))
    step = make_decode_step(cfg, greedy)

    # prefill by teacher-forcing the prompt through the decode path,
    # banking per-token telemetry — it becomes the monitor's chunked-
    # prefill history (the gateway warms up on the tenant's own prompt)
    t0 = time.perf_counter()
    prompt_tel = []
    for i in range(prompt_len - 1):
        logits, caches = decode(params, prompts[:, i], jnp.int32(i), caches)
        prompt_tel.append(_telemetry(logits))
    jax.block_until_ready(caches)
    prefill_s = time.perf_counter() - t0
    # (prompt_len-1, B, 2) on host, one request x channel stream each
    # (empty for prompt_len == 1: the monitor starts cold)
    hist = (np.stack([np.stack([np.asarray(e), np.asarray(x)], -1)
                      for e, x in prompt_tel])
            if prompt_tel else np.zeros((0, batch, N_CHANNELS),
                                        np.float32))

    # monitor gateway: one detection request per request x channel,
    # admitted with the prompt history, fed one sample per decoded token
    sched = BatchingScheduler(
        backend, buckets=_monitor_buckets(batch * N_CHANNELS),
        chunk_t=chunk_t, m=m, fmt=fmt,
        queue_limit=batch * N_CHANNELS, collect=True)
    rids = [(b, c) for b in range(batch) for c in range(N_CHANNELS)]

    def rid(b, c):
        return f"req{b}/ch{c}"

    for b, c in rids:
        ok = sched.submit(Request(rid(b, c), hist[:, b, c], m=m))
        assert ok, "monitor queue sized to the request set"

    outs = []
    tok = prompts[:, -1]
    t0 = time.perf_counter()
    for i in range(gen):
        pos = jnp.int32(prompt_len - 1 + i)
        tok, caches, ent, mx = step(params, tok, pos, caches, key)
        outs.append(tok)
        tel = np.stack([np.asarray(ent), np.asarray(mx)], -1)  # (B, 2)
        for b, c in rids:
            sched.feed(rid(b, c), tel[b, c:c + 1])
        sched.step()
    for b, c in rids:
        sched.close(rid(b, c))
    sched.drain()
    toks_out = np.stack([np.asarray(t) for t in outs], axis=1)
    decode_s = time.perf_counter() - t0

    # flag on decode-phase verdicts only (any channel): the prompt is
    # the tenant's own baseline, not the generation under scrutiny
    flagged = [b for b in range(batch)
               if any(sched.results(rid(b, c))["outlier"][-gen:].any()
                      for c in range(N_CHANNELS))]
    return {
        "tokens": toks_out,
        "flagged_requests": flagged,
        "prefill_tok_s": batch * (prompt_len - 1) / prefill_s,
        "decode_tok_s": batch * gen / decode_s,
        "monitor": sched.stats(),
    }


# ------------------------------------------------------------------- CLI
def _demo_streams(n: int, history: int, live: int, seed: int = 0):
    """Synthetic tenant mix: drifting means, one loud anomaly burst,
    every fourth tenant in the latency class (the rest are bulk)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        h = rng.normal(loc=i * 0.1, size=(history,)).astype(np.float32)
        lv = rng.normal(loc=i * 0.1, size=(live,)).astype(np.float32)
        if live and i % 3 == 0:
            lv[live // 2] += 15.0  # anomaly burst mid-stream
        cls = "latency" if i % 4 == 0 else "bulk"
        out.append((f"tenant-{i}", h, lv, 2.0 + (i % 3), cls))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "streams"])
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--backend", default="scan")
    ap.add_argument("--chunk-t", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--history", type=int, default=256)
    ap.add_argument("--live", type=int, default=32)
    ap.add_argument("--arrivals-per-tick", type=int, default=None)
    ap.add_argument("--decode-t", type=int, default=1,
                    help="short program length for decode-only ticks")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="in-flight fused calls (>1 runs the async "
                         "loop: latency measurement switches off)")
    ap.add_argument("--block-c", type=int, default=None,
                    help="channel-block width of the kernel grid "
                         "(multiple of 128; default: one strip)")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the pool over this many devices "
                         "(consistent-hash routing + live migration)")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="run the occupancy rebalancer every N ticks "
                         "(0: never; sharded gateway only)")
    args = ap.parse_args(argv)

    fmt = None
    if args.backend == "pallas-q":
        from repro.fixedpoint import QFormat
        fmt = QFormat(32, 20)  # the README's Q11.20 reference format

    if args.mode == "streams":
        res = serve_streams(
            _demo_streams(args.requests, args.history, args.live),
            backend=args.backend, chunk_t=args.chunk_t, fmt=fmt,
            decode_t=args.decode_t,
            pipeline_depth=args.pipeline_depth,
            block_c=args.block_c,
            shards=args.shards,
            rebalance_every=args.rebalance_every,
            # depth > 1 only pipelines in the async loop
            measure_latency=args.pipeline_depth <= 1,
            class_weights={"latency": 4.0, "bulk": 1.0},
            arrivals_per_tick=args.arrivals_per_tick)
        lat = res["chunk_latency"]
        print(f"[serve] {res['requests']} requests, "
              f"{res['samples']} samples in {res['wall_s']:.2f}s "
              f"({res['requests_per_s']:.1f} req/s, "
              f"{res['samples_per_s']:.0f} samples/s)")
        print(f"[serve] chunk latency p50 {lat.get('p50_ms', 0):.2f}ms "
              f"p95 {lat.get('p95_ms', 0):.2f}ms, "
              f"queue wait p95 {res['queue_wait_ticks_p95']:.0f} ticks, "
              f"{res['rejected_submits']} backpressured submits, "
              f"{res['short_ticks']} decode-short ticks")
        for cls, c in sorted(res["classes"].items()):
            print(f"[serve]   class {cls}: {c['completed']} done, "
                  f"queue wait p95 "
                  f"{c.get('queue_wait_ticks_p95', 0):.0f} ticks")
        if args.shards > 1:
            print(f"[serve] {res['shards']} shards, "
                  f"{res['migrations']} migrations, "
                  f"final imbalance {res['imbalance']}")
        print(f"[serve] flagged tenants: {res['flagged']}")
        return

    cfg = get_config(args.arch)
    if args.scale == "tiny":
        cfg = cfg.reduced()
    res = serve(cfg, args.batch, args.prompt_len, args.gen,
                backend=args.backend, chunk_t=args.chunk_t, fmt=fmt)
    print(f"[serve] prefill {res['prefill_tok_s']:.1f} tok/s, "
          f"decode {res['decode_tok_s']:.1f} tok/s")
    print(f"[serve] TEDA-flagged requests: {res['flagged_requests']}")
    print(f"[serve] monitor: {res['monitor']['ticks']} ticks, "
          f"pool {res['monitor']['pool']}")
    print(f"[serve] sample continuation (req 0): "
          f"{res['tokens'][0][:16].tolist()}")


if __name__ == "__main__":
    main()
