"""Batched serving driver with TEDA decode-stream monitoring.

Serves a (reduced or full) LM: prefills a prompt batch, then decodes with
the KV-cache path while a multichannel TEDA engine watches per-request
telemetry (logit entropy, max-logit) — flagged requests are surfaced the
way a production gateway would quarantine degenerate generations
(repetition collapse, NaN logits, prompt-injection-style OOD inputs).

The telemetry (log-softmax entropy, max-logit), the packed TEDA monitor
update (`repro.engine.engine_step`, one slot per request x channel), the
flag accumulation and the next-token selection all run *inside* the
jitted decode step: the Python loop only threads device arrays, so a
generated token costs one dispatch and no host round-trip.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --scale tiny --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.engine import engine_init, engine_step
from repro.models import init_cache, init_lm_params, lm_decode_step

N_CHANNELS = 2  # per-request telemetry: (entropy, max-logit)


def make_decode_step(cfg, m: float, greedy: bool):
    """Build the fused decode+monitor step (one compiled program).

    Carries (tokens, caches, engine state, per-request flags) on device;
    returns the sampled token plus the advanced monitor state.
    """

    @functools.partial(jax.jit, donate_argnums=(3, 4, 5))
    def step(params, tok, pos, caches, mon, flagged, key):
        logits, caches = lm_decode_step(params, tok, pos, caches, cfg)
        # --- telemetry, fused with the decode step (no host hop) -----
        logp = jax.nn.log_softmax(logits, axis=-1)
        ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)        # (B,)
        mx = jnp.max(logits, axis=-1)                        # (B,)
        metrics = jnp.stack([ent, mx], -1).reshape(-1)       # (B*2,)
        # --- packed TEDA monitor: one slot per request x channel -----
        mon, verdict = engine_step(mon, metrics, m)
        flagged = jnp.logical_or(
            flagged, verdict.outlier.reshape(-1, N_CHANNELS).any(-1))
        if greedy:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(jax.random.fold_in(key, pos),
                                         logits)
        return nxt, caches, mon, flagged

    return step


def serve(cfg, batch: int, prompt_len: int, gen: int, m: float = 3.5,
          seed: int = 0, greedy: bool = True):
    assert cfg.family != "encdec", "serve example targets decoder-only LMs"
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    max_seq = prompt_len + gen
    caches = init_cache(cfg, batch, max_seq, dtype=jnp.float32)
    decode = jax.jit(
        lambda p, t, pos, c: lm_decode_step(p, t, pos, c, cfg),
        donate_argnums=(3,))
    step = make_decode_step(cfg, m, greedy)

    # prefill by teacher-forcing the prompt through the decode path
    # (keeps one compiled program; a production server would lower a
    # separate chunked-prefill program as in launch/specs.py)
    t0 = time.perf_counter()
    for i in range(prompt_len - 1):
        _, caches = decode(params, prompts[:, i], jnp.int32(i), caches)
    jax.block_until_ready(caches)
    prefill_s = time.perf_counter() - t0

    # TEDA monitor: (batch * 2) packed channels, advanced inside `step`
    mon = engine_init(batch * N_CHANNELS)
    flagged = jnp.zeros((batch,), bool)
    outs = []
    tok = prompts[:, -1]
    t0 = time.perf_counter()
    for i in range(gen):
        pos = jnp.int32(prompt_len - 1 + i)
        tok, caches, mon, flagged = step(params, tok, pos, caches, mon,
                                         flagged, key)
        outs.append(tok)
    toks_out = np.stack([np.asarray(t) for t in outs], axis=1)
    decode_s = time.perf_counter() - t0

    return {
        "tokens": toks_out,
        "flagged_requests": np.flatnonzero(np.asarray(flagged)).tolist(),
        "prefill_tok_s": batch * (prompt_len - 1) / prefill_s,
        "decode_tok_s": batch * gen / decode_s,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "tiny":
        cfg = cfg.reduced()
    res = serve(cfg, args.batch, args.prompt_len, args.gen)
    print(f"[serve] prefill {res['prefill_tok_s']:.1f} tok/s, "
          f"decode {res['decode_tok_s']:.1f} tok/s")
    print(f"[serve] TEDA-flagged requests: {res['flagged_requests']}")
    print(f"[serve] sample continuation (req 0): "
          f"{res['tokens'][0][:16].tolist()}")


if __name__ == "__main__":
    main()
