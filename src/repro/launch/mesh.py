"""Production meshes (functions only — importing never touches devices)."""
from __future__ import annotations

import jax

from repro.sharding.rules import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return make_mesh_compat((data, model), ("data", "model"))
