"""Post-compile HLO analysis: collective traffic + roofline terms.

collective_bytes is not in cost_analysis(), so we parse the optimized
(SPMD-partitioned) HLO text and sum per-op traffic with a ring model:

  all-gather         (n-1)/n * result_bytes
  reduce-scatter     (n-1)   * result_bytes      (~operand bytes)
  all-reduce         2(n-1)/n * result_bytes
  all-to-all         (n-1)/n * result_bytes
  collective-permute 1.0     * result_bytes

n = size of the first replica group of the op.

Hardware constants (TPU v5e-class target, per assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

def cost_analysis_compat(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() across JAX versions.

    0.4.x returns a single-element list of dicts; newer releases return
    the dict directly.  Always yields a dict (empty when unavailable).
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_RING_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-kind op counts and ring-model bytes from optimized HLO."""
    stats: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # paired with -start; count once
        shape_txt, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_txt)
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        n = max(n, 2)
        traffic = size * _RING_FACTOR[kind](n)
        stats[kind] = stats.get(kind, 0.0) + traffic
        counts[kind + "_count"] = counts.get(kind + "_count", 0) + 1
    stats["total_bytes"] = sum(v for k, v in stats.items()
                               if not k.endswith("_count"))
    stats.update(counts)
    return stats


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float,
                   links_per_chip: float = 4.0) -> Dict[str, float]:
    """The three roofline terms in seconds/chip + dominant bottleneck."""
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / (ICI_BW * links_per_chip)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["bottleneck"] = dom.replace("_s", "")
    terms["step_time_lower_bound_s"] = bound
    # roofline fraction: how much of the bound is the compute term
    terms["roofline_fraction"] = (compute_s / bound) if bound > 0 else 0.0
    return terms
