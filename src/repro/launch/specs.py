"""Step functions + ShapeDtypeStruct input specs for every (arch, shape).

Everything here is allocation-free: parameters, optimizer state, caches
and batches are jax.ShapeDtypeStruct trees (via jax.eval_shape), and the
matching NamedShardings come from repro.sharding.rules. The dry-run
lowers these directly; train.py/serve.py reuse the same builders with
real arrays.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeSpec, get_config
from repro.core.guard import GuardConfig, guard_init, guard_step
from repro.models import (encdec_decode_step, encdec_loss, init_cache,
                          init_encdec_cache, init_encdec_params,
                          init_lm_params, lm_decode_step, lm_loss, lm_prefill)
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.sharding.rules import (batch_spec, params_shardings,
                                  state_cache_shardings)

GUARD_CFG = GuardConfig(m=3.0, warmup_steps=50, channels=2)


class CellSpec(NamedTuple):
    """Everything needed to lower one (arch x shape x mesh) cell."""
    fn: Callable                  # jit-able step function
    args: Tuple[Any, ...]         # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    token_count: int              # D for 6ND bookkeeping


# ------------------------------------------------------------ builders --
def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    accum_steps: int = 1, unroll_accum: bool = False,
                    guard_cfg: GuardConfig = GUARD_CFG,
                    micro_shardings=None):
    """Train step with optional gradient accumulation (microbatching).

    Accumulation is THE activation-memory lever at 4k-seq/256-batch
    scale: live activations scale with the microbatch, grads accumulate
    into an FSDP-sharded f32 tree. `unroll_accum` replaces the microbatch
    lax.scan with a Python loop for the dry-run flop calibration (HLO
    cost analysis counts loop bodies once).
    """
    loss_fn = encdec_loss if cfg.family == "encdec" else lm_loss

    def micro_grads(params, micro):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, micro, cfg)
        return loss, metrics, grads

    def train_step(params, opt_state, guard_state, batch):
        if accum_steps == 1:
            loss, metrics, grads = micro_grads(params, batch)
        else:
            k = accum_steps
            micros = jax.tree_util.tree_map(
                lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]),
                batch)
            if micro_shardings is not None:
                # the reshape would otherwise drop the batch sharding and
                # replicate each microbatch onto every device
                micros = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, micros,
                    micro_shardings)
            acc_dt = jnp.dtype(opt_cfg.grad_dtype)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)

            def one(carry, micro):
                gacc, lacc = carry
                loss, metrics, grads = micro_grads(params, micro)
                gacc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), gacc, grads)
                return (gacc, lacc + loss), metrics

            if unroll_accum:
                carry = (g0, jnp.zeros(()))
                ms = []
                for i in range(k):
                    micro = jax.tree_util.tree_map(lambda a: a[i], micros)
                    carry, m = one(carry, micro)
                    ms.append(m)
                metrics = jax.tree_util.tree_map(
                    lambda *a: jnp.stack(a).mean(), *ms)
            else:
                carry, metrics = jax.lax.scan(
                    one, (g0, jnp.zeros(())), micros)
                metrics = jax.tree_util.tree_map(jnp.mean, metrics)
            (gacc, lsum) = carry
            grads = jax.tree_util.tree_map(lambda g: g / k, gacc)
            loss = lsum / k
        gnorm = adamw.global_norm(grads)
        # TEDA guard on (loss, grad-norm) telemetry — the paper's
        # detector deciding whether this step may touch the weights
        guard_state, verdict = guard_step(
            guard_state, jnp.stack([loss, gnorm]), guard_cfg)
        params, opt_state, om = adamw.update(
            grads, opt_state, params, opt_cfg, skip=verdict.skip)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, guard_state, metrics

    return train_step


def pick_accum_steps(mesh: Mesh, global_batch: int, seq_len: int,
                     d_model: int = 2048,
                     token_dim_budget: int = 8192 * 2048) -> int:
    """Smallest divisor k of the per-dp-shard batch such that each
    microbatch holds <= budget token-dims (tokens x d_model) per
    data-parallel shard — activation memory scales with that product."""
    target_tokens_per_row = max(1024, token_dim_budget // max(d_model, 1))
    sizes = dict(mesh.shape)
    dp_total = 1
    for a in ("pod", "data"):
        dp_total *= sizes.get(a, 1)
    if global_batch % dp_total:
        dp_total = sizes.get("data", 1)
    per_row = max(global_batch // max(dp_total, 1), 1)
    tokens_row = per_row * seq_len
    k0 = max(1, -(-tokens_row // target_tokens_per_row))
    for k in range(k0, per_row + 1):
        if per_row % k == 0:
            return k
    return per_row


def _param_template(cfg: ModelConfig):
    init = init_encdec_params if cfg.family == "encdec" else init_lm_params
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))


def _batch_template(cfg: ModelConfig, sp: ShapeSpec, per_pod_batch: int):
    b, s = per_pod_batch, sp.seq_len
    if cfg.family == "encdec":
        return {
            "src_emb": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                            jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}


def _batch_shardings(mesh: Mesh, cfg: ModelConfig, batch_tpl):
    bspec = batch_spec(mesh, batch_tpl["tokens"].shape[0])
    out = {"tokens": NamedSharding(mesh, bspec)}
    if "src_emb" in batch_tpl:
        out["src_emb"] = NamedSharding(
            mesh, P(*(tuple(bspec)[:1] + (None, None))))
    return out


def build_train_cell(arch: str, sp: ShapeSpec, mesh: Mesh,
                     cfg: ModelConfig | None = None,
                     accum_steps: int | None = None,
                     unroll_accum: bool = False,
                     opt_cfg: adamw.AdamWConfig | None = None) -> CellSpec:
    cfg = cfg or get_config(arch)
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    if accum_steps is None:
        accum_steps = pick_accum_steps(mesh, sp.global_batch, sp.seq_len,
                                       cfg.d_model)
    micro_sh = None
    if accum_steps > 1:
        bspec = batch_spec(mesh, sp.global_batch // accum_steps)
        micro_sh = {"tokens": NamedSharding(
            mesh, P(*((None,) + tuple(bspec))))}
        if cfg.family == "encdec":
            micro_sh["src_emb"] = NamedSharding(
                mesh, P(None, tuple(bspec)[0], None, None))
    step = make_train_step(cfg, opt_cfg, accum_steps, unroll_accum,
                           micro_shardings=micro_sh)

    params = _param_template(cfg)
    opt = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params)
    guard = jax.eval_shape(lambda: guard_init(GUARD_CFG))
    batch = _batch_template(cfg, sp, sp.global_batch)

    p_sh = params_shardings(mesh, params)
    o_sh = adamw.OptState(m=p_sh, v=p_sh,
                          count=NamedSharding(mesh, P()))
    g_sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), guard)
    b_sh = _batch_shardings(mesh, cfg, batch)
    rep = NamedSharding(mesh, P())
    m_sh = {"ce": rep, "aux": rep, "ppl_proxy": rep, "loss": rep,
            "grad_norm": rep, "lr": rep, "skipped": rep}

    tokens = batch["tokens"].shape[0] * sp.seq_len
    if cfg.family == "encdec":
        tokens *= 2  # encoder + decoder sides
    return CellSpec(
        fn=step, args=(params, opt, guard, batch),
        in_shardings=(p_sh, o_sh, g_sh, b_sh),
        out_shardings=(p_sh, o_sh, g_sh, m_sh),
        donate_argnums=(0, 1, 2),
        token_count=tokens,
    )


def build_prefill_cell(arch: str, sp: ShapeSpec, mesh: Mesh,
                       cfg: ModelConfig | None = None) -> CellSpec:
    cfg = cfg or get_config(arch)
    params = _param_template(cfg)
    b = sp.global_batch

    if cfg.family == "encdec":
        def prefill(params, batch):
            from repro.models import decode_train, encode
            from repro.models.layers import unembed
            enc = encode(params, batch["src_emb"], cfg)
            hid = decode_train(params, enc, batch["tokens"][:, :-1], cfg,
                               return_hidden=True)
            return unembed(params["embed"], hid[:, -1], cfg.vocab)
        batch = _batch_template(cfg, sp, b)
        b_sh = _batch_shardings(mesh, cfg, batch)
        args = (params, batch)
        in_sh = (params_shardings(mesh, params), b_sh)
    else:
        def prefill(params, tokens):
            return lm_prefill(params, tokens, cfg)
        tokens = jax.ShapeDtypeStruct((b, sp.seq_len), jnp.int32)
        args = (params, tokens)
        in_sh = (params_shardings(mesh, params),
                 NamedSharding(mesh, batch_spec(mesh, b)))

    return CellSpec(fn=prefill, args=args, in_shardings=in_sh,
                    out_shardings=None, donate_argnums=(),
                    token_count=b * sp.seq_len * (
                        2 if cfg.family == "encdec" else 1))


def build_decode_cell(arch: str, sp: ShapeSpec, mesh: Mesh,
                      cfg: ModelConfig | None = None) -> CellSpec:
    cfg = cfg or get_config(arch)
    params = _param_template(cfg)
    b, s = sp.global_batch, sp.seq_len

    kvd = jnp.dtype(cfg.kv_dtype)
    if cfg.family == "encdec":
        caches = jax.eval_shape(
            functools.partial(init_encdec_cache, cfg, b, s, s,
                              dtype=kvd))

        def step(params, token, pos, caches):
            return encdec_decode_step(params, token, pos, caches, cfg)
    else:
        caches = jax.eval_shape(
            functools.partial(init_cache, cfg, b, s, dtype=kvd))

        def step(params, token, pos, caches):
            return lm_decode_step(params, token, pos, caches, cfg)

    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = params_shardings(mesh, params)
    c_sh = state_cache_shardings(mesh, caches)
    bspec = batch_spec(mesh, b, kind="decode")
    t_sh = NamedSharding(mesh, bspec)
    b_dim = tuple(bspec)[0] if len(tuple(bspec)) else None
    v_dim = "model" if cfg.vocab % dict(mesh.shape)["model"] == 0 else None
    logits_sh = NamedSharding(mesh, P(b_dim, v_dim))
    return CellSpec(
        fn=step, args=(params, token, pos, caches),
        in_shardings=(p_sh, t_sh, NamedSharding(mesh, P()), c_sh),
        out_shardings=(logits_sh, c_sh),
        donate_argnums=(3,),
        token_count=b,
    )


def build_cell(arch: str, sp: ShapeSpec, mesh: Mesh,
               cfg: ModelConfig | None = None,
               accum_steps: int | None = None,
               unroll_accum: bool = False,
               opt_cfg: adamw.AdamWConfig | None = None) -> CellSpec:
    if sp.kind == "train":
        return build_train_cell(arch, sp, mesh, cfg, accum_steps,
                                unroll_accum, opt_cfg)
    if sp.kind == "prefill":
        return build_prefill_cell(arch, sp, mesh, cfg)
    if sp.kind == "decode":
        return build_decode_cell(arch, sp, mesh, cfg)
    raise ValueError(sp.kind)
