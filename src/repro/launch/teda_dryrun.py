"""Dry-run of the paper's technique itself on the production meshes.

Lowers + compiles the distributed TEDA scan (core/distributed.py) for
the single-pod (256-chip) and multi-pod (512-chip) meshes, recording
per-device flops/bytes and collective traffic — proof that one logical
TEDA stream scales across pods with O(devices * N) communication,
independent of stream length (EXPERIMENTS.md §Dry-run/TEDA).

  PYTHONPATH=src python -m repro.launch.teda_dryrun
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import _local_shard_scan
from repro.launch.hlo_analysis import (collective_stats,
                                       cost_analysis_compat,
                                       roofline_terms)
from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import shard_map_compat


def run(multi_pod: bool, t_total: int, n_feat: int) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    axes = ("pod", "data") if multi_pod else ("data",)

    import functools
    body = functools.partial(_local_shard_scan, m=3.0, axis_name=axes)
    from repro.core.teda import TedaOutput, TedaState
    mapped = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(axes, None),),
        out_specs=(TedaState(k=P(), mean=P(), var=P()),
                   TedaOutput(*([P(axes)] * 6))),
        check=False,
    )
    x = jax.ShapeDtypeStruct((t_total, n_feat), jnp.float32)
    with mesh:
        comp = jax.jit(
            mapped,
            in_shardings=(NamedSharding(mesh, P(axes, None)),),
        ).lower(x).compile()
    cost = cost_analysis_compat(comp)
    coll = collective_stats(comp.as_text())
    mem = comp.memory_analysis()
    terms = roofline_terms(float(cost.get("flops", 0.0)),
                           float(cost.get("bytes accessed", 0.0)),
                           coll.get("total_bytes", 0.0))
    return {
        "mesh": "multi" if multi_pod else "single",
        "devices": n_dev,
        "t_total": t_total, "n_feat": n_feat,
        "t_per_device": t_total // n_dev,
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "temp_bytes": mem.temp_size_in_bytes,
        "roofline": terms,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--t", type=int, default=1 << 24)  # 16.7M samples
    ap.add_argument("--feat", type=int, default=4)
    ap.add_argument("--out", default="experiments/teda_dryrun.json")
    args = ap.parse_args()
    results = []
    for multi in (False, True):
        r = run(multi, args.t, args.feat)
        results.append(r)
        print(f"[{r['mesh']}] devices={r['devices']} "
              f"T/dev={r['t_per_device']} "
              f"coll_bytes={r['collectives'].get('total_bytes', 0):.0f} "
              f"({r['collectives']}) temp={r['temp_bytes'] / 1e6:.1f}MB")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
