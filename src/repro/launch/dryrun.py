"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral_8x7b \
      --shape train_4k --mesh multi                            # one cell
  ... --list  /  --force  /  --out experiments/dryrun

Each cell lowers jit(step).lower(*ShapeDtypeStructs), compiles, and
records memory_analysis / cost_analysis / collective traffic into a JSON
cache (resumable; reruns skip completed cells).
"""
# The first two lines MUST precede any other import: jax locks the device
# count at first initialization.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse
import json
import time
import traceback

import jax

from repro.configs.registry import ALIASES, SHAPES, all_cells, get_config
from repro.launch.hlo_analysis import (collective_stats,
                                       cost_analysis_compat,
                                       roofline_terms)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.models.common import active_param_count

MESHES = ("single", "multi")

# Per-cell fit overrides (see EXPERIMENTS.md §Perf for the derivations):
# dbrx-132b at fp32 Adam carries 12 B/param of optimizer+param state =
# 6.2 GB/chip on 256 chips; bf16 moments + bf16 grad accumulation bring
# the full train step under the 16 GB HBM budget at production fidelity.
FIT_OVERRIDES = {
    ("dbrx_132b", "train_4k"): {
        "opt_overrides": {"grad_dtype": "bfloat16",
                          "m_dtype": "bfloat16", "v_dtype": "bfloat16"},
    },
    # 132B param+opt state cannot replicate per pod: ZeRO-3 across pods
    ("dbrx_132b", "train_4k", "multi"): {
        "opt_overrides": {"grad_dtype": "bfloat16",
                          "m_dtype": "bfloat16", "v_dtype": "bfloat16"},
        "rule_flags": {"fsdp_over_pod": True},
    },
    # GSPMD converges the decoder-scan carry to batch-replicated without
    # the residual-activation constraint (19.5 GB -> 3.6 GB with it)
    ("seamless_m4t_medium", "train_4k"): {"hints": True},
    # GSPMD batch-replication pathology on big-d prefill (EXPERIMENTS
    # §Perf): the residual constraint restores batch sharding
    ("qwen2_7b", "prefill_32k"): {"hints": True},
    ("chameleon_34b", "prefill_32k"): {"hints": True},
    # SSM-family scan carries also converge batch-replicated
    ("zamba2_2p7b", "train_4k"): {"hints": True},
    ("zamba2_2p7b", "prefill_32k"): {"hints": True},
    ("xlstm_350m", "train_4k"): {"hints": True},
    ("mixtral_8x7b", "train_4k", "multi"): {
        "opt_overrides": {"grad_dtype": "bfloat16",
                          "m_dtype": "bfloat16", "v_dtype": "bfloat16"},
        "rule_flags": {"fsdp_over_pod": True},
    },
}


def _calibration_cfg(cfg, groups: int, sp, unchunk: bool):
    """Unrolled variant with `groups` layer-groups (loop calibration).

    HLO cost analysis counts while-loop bodies ONCE, so the scanned full
    model under-reports flops/bytes/collectives. We compile unrolled
    1-group and 2-group variants and extrapolate linearly in the group
    count — everything outside the layer stack (embed, unembed, loss,
    optimizer) is shared and cancels in the difference.

    Two variants are used:
      * unchunk=True  — single-chunk attention/SSD (NO loops at all):
        exact FLOP counting (flops are schedule-invariant).
      * unchunk=False — production chunking kept: collective counting is
        exact (collectives sit at layer boundaries, never inside chunk
        loops) and byte counts reflect the fused/chunked schedule (chunk
        working sets are VMEM-resident on the TPU target, so counting
        chunk-loop bodies once approximates HBM traffic far better than
        the unchunked variant, whose S^2 score tensors would never be
        materialized to HBM).
    """
    import dataclasses

    from repro.models.transformer import block_layout
    grp, n_groups = block_layout(cfg)
    per_group = cfg.n_layers // n_groups if n_groups else 1
    big = 1 << 30
    over = dict(
        scan_layers=False,
        n_layers=per_group * groups,
        # remat inherited: recompute flops must count, matching the real
        # compiled schedule
    )
    if unchunk:
        over.update(q_chunk=big, kv_chunk=big, ssm_chunk=big)
    if cfg.family == "encdec":
        over["enc_layers"] = groups
        over["dec_layers"] = groups
        over["n_layers"] = 2 * groups
    return dataclasses.replace(cfg, **over), n_groups


def analytic_loop_flops(cfg, sp, n_dev: int) -> float:
    """Per-device executed flops living INSIDE chunk loops, which HLO
    cost analysis counts only once (loop bodies): attention S-quadratic
    terms, SSD/mLSTM intra-chunk terms, chunked-MoE expert matmuls,
    chunked-CE read-out, sLSTM recurrence.

    Multipliers approximate the executed schedule: train = fwd + remat
    recompute + backward(2x fwd) [+1 for the extra q-chunk checkpoint on
    attention]; prefill = fwd only; decode = 0 (its path has no chunk
    loops — the layer scan is handled by the group extrapolation).
    Documented in EXPERIMENTS.md §Dry-run methodology.
    """
    from repro.models.transformer import block_layout

    if sp.kind == "decode":
        return 0.0
    train = sp.kind == "train"
    attn_mult = 5.0 if train else 1.0
    other_mult = 4.0 if train else 1.0

    s = sp.seq_len
    b = sp.global_batch
    hd, h = cfg.head_dim, cfg.n_heads
    total = 0.0

    def attn_term(kv_eff, count):
        return 4.0 * b * h * s * kv_eff * hd * count

    if cfg.family == "encdec":
        total += attn_term(s, cfg.enc_layers) * attn_mult        # enc
        total += attn_term(s / 2, cfg.dec_layers) * attn_mult    # dec self
        total += attn_term(s, cfg.dec_layers) * attn_mult        # cross
    else:
        grp, n_groups = block_layout(cfg)
        for bd in grp:
            if bd.kind in ("attn", "moe", "shared"):
                kv_eff = min(bd.window, s) if bd.window else s / 2
                total += attn_term(kv_eff, n_groups) * attn_mult
            if bd.kind == "ssm":
                q = min(cfg.ssm_chunk, s)
                d_in = cfg.ssm_expand * cfg.d_model
                hs = d_in // cfg.ssm_head_dim
                ps = cfg.ssm_head_dim
                n = cfg.ssm_state
                intra = 2.0 * b * s * q * (n + hs * ps)
                inter = 4.0 * b * s * hs * ps * n
                total += (intra + inter) * n_groups * other_mult
            if bd.kind == "mlstm":
                d_in = int(cfg.mlstm_proj_factor * cfg.d_model)
                pm = d_in // cfg.n_heads
                q = min(cfg.ssm_chunk, s)
                intra = 4.0 * b * s * q * d_in
                state = 4.0 * b * s * d_in * pm
                total += (intra + state) * n_groups * other_mult
            if bd.kind == "slstm":
                ph = cfg.d_model // cfg.n_heads
                total += 8.0 * b * s * cfg.d_model * ph                     * n_groups * other_mult
        # chunked MoE expert matmuls (loop present when tokens > chunk)
        if cfg.family == "moe" and cfg.moe_chunk and b * s > cfg.moe_chunk:
            c_total = b * s * cfg.top_k * cfg.capacity_factor
            total += (3 * 2.0 * c_total * cfg.d_model * cfg.d_ff
                      * cfg.n_layers) * other_mult

    # chunked CE (train only; loop enters when S > ce_chunk)
    if train and cfg.ce_chunk and s > cfg.ce_chunk:
        from repro.models.common import vocab_padded
        total += 2.0 * b * s * cfg.d_model * vocab_padded(cfg) * 4.0

    return total / n_dev


def calibrate_cell(arch, sp, mesh, cfg, n_dev, seq_parallel=None,
                   accum_real: int = 1, opt_cfg=None):
    """Extrapolated per-device flops/bytes/collectives.

    Measurement model (train): F(G, K) = opt + K*outm + K*G*bodym,
    where G = layer-group count, K = microbatch count (accumulation),
    outm = per-micro non-layer work (embed/unembed/CE), bodym =
    per-micro per-group work. Three unrolled compiles — (g=1,k=1),
    (g=2,k=1), (g=1,k=2) — identify the three coefficients; for
    prefill/decode K is fixed at 1 and two compiles suffice. Compiles
    keep the production chunking (collectives sit at layer boundaries,
    never inside chunk loops, so their counting is exact; bytes reflect
    the fused/chunked schedule); the flops that live INSIDE chunk loops
    (attention quadratic terms, SSD/mLSTM intra-chunk, chunked MoE/CE)
    are added back analytically via `analytic_loop_flops`.
    """
    from repro.sharding.hints import activation_hints

    is_train = sp.kind == "train"
    micro_b = max(sp.global_batch // accum_real, 1)

    def measure(g, k, unchunk):
        import contextlib
        ccfg, n_groups = _calibration_cfg(cfg, g, sp, unchunk)
        csp = sp._replace(global_batch=micro_b * k) if is_train else sp
        cell = build_cell(arch, csp, mesh, ccfg,
                          accum_steps=k if is_train else None,
                          unroll_accum=True, opt_cfg=opt_cfg)
        hint_ctx = (activation_hints(mesh, sp=seq_parallel)
                    if seq_parallel is not None else
                    contextlib.nullcontext())
        with mesh, hint_ctx:
            comp = jax.jit(
                cell.fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            ).lower(*cell.args).compile()
        cost = cost_analysis_compat(comp)
        coll = collective_stats(comp.as_text())
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll.get("total_bytes", 0.0),
        }, n_groups

    out = {}
    f11, n_groups = measure(1, 1, False)
    f21, _ = measure(2, 1, False)
    if is_train:
        f12, _ = measure(1, 2, False)
    for key in ("flops", "bytes", "coll"):
        bodym = max(f21[key] - f11[key], 0.0)
        if is_train:
            outm = max(f12[key] - f11[key] - bodym, 0.0)
            opt = max(f11[key] - outm - bodym, 0.0)
            out[key] = (opt + accum_real * outm
                        + accum_real * n_groups * bodym)
        else:
            outside = max(f11[key] - bodym, 0.0)
            out[key] = outside + n_groups * bodym
        if key == "flops":
            out["per_group_flops"] = bodym
            out["outside_flops"] = max(f11[key] - bodym, 0.0)
    out["loop_flops_addback"] = analytic_loop_flops(cfg, sp, n_dev)
    out["flops"] += out["loop_flops_addback"]
    out["n_groups"] = n_groups
    out["accum_steps"] = accum_real
    out["micro_batch"] = micro_b
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             cfg_overrides=None, tag: str = "",
             seq_parallel: bool | None = None,
             accum_steps: int | None = None,
             opt_overrides=None, hints: bool = False,
             rule_flags=None) -> dict:

    from repro.launch.specs import pick_accum_steps
    from repro.optim import adamw
    from repro.sharding import rules
    from repro.sharding.hints import activation_hints

    saved_flags = dict(rules.RULE_FLAGS)
    if rule_flags:
        rules.RULE_FLAGS.update(rule_flags)

    sp = next(s for s in SHAPES if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    cfg = get_config(arch)
    if cfg_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    if accum_steps is None and sp.kind == "train":
        accum_steps = pick_accum_steps(mesh, sp.global_batch, sp.seq_len,
                                       cfg.d_model)
    accum_steps = accum_steps or 1
    opt_cfg = adamw.AdamWConfig(**(opt_overrides or {}))
    # activation hints are an opt-in experiment knob (GSPMD's default
    # propagation beat both hint modes on the audited cells)
    use_hints = hints or bool(seq_parallel)

    import contextlib
    t0 = time.time()
    cell = build_cell(arch, sp, mesh, cfg, accum_steps=accum_steps,
                      opt_cfg=opt_cfg)
    hint_ctx = (activation_hints(mesh, sp=bool(seq_parallel))
                if use_hints else contextlib.nullcontext())
    with mesh, hint_ctx:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cal = calibrate_cell(arch, sp, mesh, cfg, n_dev,
                         seq_parallel=bool(seq_parallel) if use_hints
                         else None,
                         accum_real=accum_steps, opt_cfg=opt_cfg)

    mem = compiled.memory_analysis()
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    cost = cost_analysis_compat(compiled)
    flops_raw = float(cost.get("flops", 0.0))  # under-counts loop bodies
    bytes_raw = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    # calibrated per-device numbers (loop-corrected; see calibrate_cell)
    flops = cal["flops"]
    bytes_acc = cal["bytes"]
    coll_bytes = cal["coll"]
    terms = roofline_terms(flops, bytes_acc, coll_bytes)

    model_flops = None
    n_active = active_param_count(cfg)
    if sp.kind == "train":
        model_flops = 6 * n_active * cell.token_count
    elif sp.kind == "prefill":
        model_flops = 2 * n_active * cell.token_count
    else:  # decode: one token per sequence
        model_flops = 2 * n_active * cell.token_count
    useful = model_flops / max(flops * n_dev, 1.0)

    rules.RULE_FLAGS.update(saved_flags)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "rule_flags": rule_flags or {},
        "tag": tag, "devices": n_dev,
        "kind": sp.kind, "seq_len": sp.seq_len,
        "global_batch": sp.global_batch,
        "accum_steps": accum_steps, "seq_parallel": bool(seq_parallel),
        "hints": use_hints, "opt_overrides": opt_overrides or {},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_info,
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "flops_per_device_raw_scanned": flops_raw,
        "bytes_per_device_raw_scanned": bytes_raw,
        "collectives_scanned_hlo": coll,
        "calibration": cal,
        "roofline": terms,
        "model_flops_6nd": model_flops,
        "useful_flop_ratio": useful,
        "active_params": n_active,
        "token_count": cell.token_count,
    }
    return result


def cell_path(out_dir, arch, shape, mesh_kind, tag=""):
    suffix = f"__{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    meshes = MESHES if args.mesh == "both" else (args.mesh,)
    os.makedirs(args.out, exist_ok=True)

    todo = []
    for arch, sp, skip in all_cells():
        if args.arch and ALIASES.get(args.arch, args.arch) != arch:
            continue
        if args.shape and sp.name != args.shape:
            continue
        for mk in meshes:
            todo.append((arch, sp.name, mk, skip))

    if args.list:
        for t in todo:
            print(*t)
        return

    n_ok = n_fail = n_skip = 0
    for arch, shape, mk, skip in todo:
        path = cell_path(args.out, arch, shape, mk)
        if skip:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mk,
                           "skipped": True,
                           "reason": "pure full-attention arch at 500k "
                                     "(DESIGN.md long_500k handling)"}, f)
            n_skip += 1
            continue
        if os.path.exists(path) and not args.force:
            print(f"[cached] {arch} {shape} {mk}")
            n_ok += 1
            continue
        print(f"[run] {arch} {shape} {mk} ...", flush=True)
        try:
            over = FIT_OVERRIDES.get((arch, shape, mk),
                                     FIT_OVERRIDES.get((arch, shape), {}))
            res = run_cell(arch, shape, mk, **over)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(f"  ok compile={res['compile_s']:.1f}s "
                  f"bottleneck={r['bottleneck']} "
                  f"compute={r['compute_s']:.4f}s "
                  f"mem={r['memory_s']:.4f}s "
                  f"coll={r['collective_s']:.4f}s", flush=True)
            n_ok += 1
        except Exception:
            traceback.print_exc()
            with open(path + ".fail", "w") as f:
                f.write(traceback.format_exc())
            n_fail += 1
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
