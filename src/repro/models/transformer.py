"""Decoder-only LM assembly for all assigned families.

A model is a sequence of *groups*, each group a short static list of
blocks; groups are identical in structure, so parameters are stacked with
a leading (n_groups,) axis and the stack is executed with lax.scan
(compile-time containment: HLO size is O(group), not O(L), critical for
the 34B/132B dry-runs at 512 devices).

Block kinds:
  attn      — GQA attention (+RoPE/SWA/softcap/bias variants) + gated MLP
  moe       — attention + mixture-of-experts FFN
  ssm       — Mamba2 (SSD) block
  mlstm     — xLSTM matrix-memory block
  slstm     — xLSTM scalar-memory block
  shared    — zamba2 shared attention+MLP block (one weight set reused
              every group, fed concat(x, embedding residual))

Families map to group layouts in `block_layout(cfg)`.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (KVCache, attend_train, attention_init,
                                    decode_attention)
from repro.models.common import ModelConfig, vocab_padded
from repro.models.layers import (dense, dense_init, embed, embedding_init,
                                 layernorm, layernorm_init, rmsnorm,
                                 rmsnorm_init, softcap, unembed)
from repro.models.mlp import mlp, mlp_init
from repro.models.moe import moe, moe_init
from repro.sharding.hints import maybe_shard
from repro.models.ssm import (ssm_cache_init, ssm_decode_step,
                              ssm_forward, ssm_init)
from repro.models.xlstm import (mlstm_cache_init, mlstm_decode_step,
                                mlstm_forward, mlstm_init, slstm_cache_init,
                                slstm_decode_step, slstm_forward, slstm_init)


# ------------------------------------------------------------- layouts --
class BlockDef(NamedTuple):
    kind: str
    window: Optional[int] = None  # sliding window for this block


def block_layout(cfg: ModelConfig) -> Tuple[List[BlockDef], int]:
    """Returns (blocks-per-group, n_groups)."""
    if cfg.family == "moe":
        return [BlockDef("moe", cfg.window)], cfg.n_layers
    if cfg.family == "ssm":  # xlstm
        if cfg.slstm_every:
            grp = [BlockDef("mlstm")] * (cfg.slstm_every - 1) + [
                BlockDef("slstm")]
            assert cfg.n_layers % cfg.slstm_every == 0
            return grp, cfg.n_layers // cfg.slstm_every
        return [BlockDef("mlstm")], cfg.n_layers
    if cfg.family == "hybrid":  # zamba2
        per = cfg.shared_period
        assert per and cfg.n_layers % per == 0
        grp = [BlockDef("ssm")] * per + [BlockDef("shared")]
        return grp, cfg.n_layers // per
    if cfg.local_global_period:  # gemma2
        grp = [BlockDef("attn", cfg.window), BlockDef("attn", None)]
        assert cfg.n_layers % 2 == 0
        return grp, cfg.n_layers // 2
    return [BlockDef("attn", cfg.window)], cfg.n_layers


def _norm_fns(cfg):
    if getattr(cfg, "norm_type", "rmsnorm") == "layernorm":
        return layernorm_init, layernorm
    return rmsnorm_init, rmsnorm


# ---------------------------------------------------------------- init --
def _block_init(key, bd: BlockDef, cfg: ModelConfig) -> Dict[str, Any]:
    ninit, _ = _norm_fns(cfg)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if bd.kind in ("attn", "moe"):
        p = {
            "ln1": ninit(d, cfg.pdtype),
            "attn": attention_init(ks[0], cfg),
            "ln2": ninit(d, cfg.pdtype),
        }
        if cfg.local_global_period:  # gemma2 sandwich norms
            p["post_ln1"] = ninit(d, cfg.pdtype)
            p["post_ln2"] = ninit(d, cfg.pdtype)
        if bd.kind == "moe":
            p["moe"] = moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.pdtype,
                                cfg.mlp_gated)
        return p
    if bd.kind == "ssm":
        return {"ln1": ninit(d, cfg.pdtype), "ssm": ssm_init(ks[0], cfg)}
    if bd.kind == "mlstm":
        return {"ln1": ninit(d, cfg.pdtype), "mlstm": mlstm_init(ks[0], cfg)}
    if bd.kind == "slstm":
        return {"ln1": ninit(d, cfg.pdtype), "slstm": slstm_init(ks[0], cfg)}
    raise ValueError(bd.kind)


def _shared_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    """zamba2 shared block: concat(x, emb0) -> proj -> attn + mlp -> d."""
    ninit, _ = _norm_fns(cfg)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "ln_in": ninit(2 * d, cfg.pdtype),
        "win": dense_init(ks[0], 2 * d, d, False, cfg.pdtype),
        "attn": attention_init(ks[1], cfg),
        "ln2": ninit(d, cfg.pdtype),
        "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg.pdtype),
    }


def init_lm_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    grp, n_groups = block_layout(cfg)
    ninit, _ = _norm_fns(cfg)
    keys = jax.random.split(key, len(grp) + 3)
    params: Dict[str, Any] = {
        "embed": embedding_init(keys[0], vocab_padded(cfg), cfg.d_model,
                                cfg.pdtype),
        "final_norm": ninit(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], cfg.d_model, cfg.vocab,
                                       False, cfg.pdtype)
    for j, bd in enumerate(grp):
        if bd.kind == "shared":
            continue  # one weight set for all groups, stored under "shared"
        gkeys = jax.random.split(keys[2 + j], n_groups)
        params[f"blocks_{j}"] = jax.vmap(
            lambda k: _block_init(k, bd, cfg))(gkeys)
    if any(b.kind == "shared" for b in grp):
        params["shared"] = _shared_init(keys[-1], cfg)
    return params


# ------------------------------------------------------------- forward --
def _apply_block(bp, bd: BlockDef, x, cfg, *, shared_params=None,
                 emb0=None):
    """Training-path block application. x (B, S, d)."""
    _, norm = _norm_fns(cfg)
    post = cfg.local_global_period > 0
    if bd.kind in ("attn", "moe"):
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, _ = attend_train(bp["attn"], h, cfg, causal=True,
                            window=bd.window)
        if post:
            h = norm(bp["post_ln1"], h, cfg.norm_eps)
        x = x + h
        h = norm(bp["ln2"], x, cfg.norm_eps)
        if bd.kind == "moe":
            h, aux = moe(bp["moe"], h, cfg)
        else:
            h, aux = mlp(bp["mlp"], h, cfg.cdtype,
                         getattr(cfg, "mlp_act", "silu")), {}
        if post:
            h = norm(bp["post_ln2"], h, cfg.norm_eps)
        return x + h, aux
    if bd.kind == "ssm":
        h = norm(bp["ln1"], x, cfg.norm_eps)
        return x + ssm_forward(bp["ssm"], h, cfg), {}
    if bd.kind == "mlstm":
        h = norm(bp["ln1"], x, cfg.norm_eps)
        return x + mlstm_forward(bp["mlstm"], h, cfg), {}
    if bd.kind == "slstm":
        h = norm(bp["ln1"], x, cfg.norm_eps)
        return x + slstm_forward(bp["slstm"], h, cfg), {}
    if bd.kind == "shared":
        sp = shared_params
        h = jnp.concatenate([x, emb0], axis=-1)
        h = norm(sp["ln_in"], h, cfg.norm_eps)
        h = dense(sp["win"], h, cfg.cdtype)
        h, _ = attend_train(sp["attn"], h, cfg, causal=True)
        x = x + h
        h = norm(sp["ln2"], x, cfg.norm_eps)
        return x + mlp(sp["mlp"], h, cfg.cdtype), {}
    raise ValueError(bd.kind)


def lm_backbone(params, tokens, cfg: ModelConfig):
    """tokens (B, S) int32 -> (final-norm hidden (B, S, d), aux)."""
    grp, n_groups = block_layout(cfg)
    _, norm = _norm_fns(cfg)
    x = embed(params["embed"], tokens, cfg.cdtype)
    if cfg.local_global_period:  # gemma scales embeddings
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    x = maybe_shard(x, "residual")
    emb0 = x
    shared = params.get("shared")

    def group_body(x, gp):
        aux_acc = jnp.zeros((), jnp.float32)
        x = x.astype(cfg.cdtype)  # keep the remat-saved carry in bf16
        x = maybe_shard(x, "residual")
        for j, bd in enumerate(grp):
            bp = None if bd.kind == "shared" else gp[f"blocks_{j}"]
            x, aux = _apply_block(
                bp, bd, x, cfg, shared_params=shared, emb0=emb0)
            if aux:
                aux_acc = aux_acc + aux["load_balance"] \
                    + 1e-3 * aux["router_z"]
        return x, aux_acc

    if cfg.remat:
        policy = {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            "everything": jax.checkpoint_policies.everything_saveable,
        }[cfg.remat_policy]
        group_body = jax.checkpoint(group_body, policy=policy)

    stacked = {k: params[k] for k in params if k.startswith("blocks_")}
    if cfg.scan_layers:
        go = cfg.outer_scan
        if go and n_groups % go == 0 and go < n_groups:
            gi = n_groups // go

            def outer_body(x, gp_outer):
                x, aux = jax.lax.scan(group_body, x, gp_outer)
                return x, jnp.sum(aux)

            if cfg.remat:
                outer_body = jax.checkpoint(
                    outer_body,
                    policy=jax.checkpoint_policies.nothing_saveable)
            stacked2 = jax.tree_util.tree_map(
                lambda a: a.reshape((go, gi) + a.shape[1:]), stacked)
            x, aux = jax.lax.scan(outer_body, x, stacked2)
        else:
            x, aux = jax.lax.scan(group_body, x, stacked)
        aux = jnp.sum(aux)
    else:
        aux = jnp.zeros((), jnp.float32)
        for g in range(n_groups):
            gp = jax.tree_util.tree_map(lambda a: a[g], stacked)
            x, a = group_body(x, gp)
            aux = aux + a

    x = norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def lm_logits(params, x, cfg: ModelConfig):
    """Read-out head on hidden x (..., d) -> (..., vocab) f32."""
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cfg.vocab)
    else:
        logits = dense(params["unembed"], x).astype(jnp.float32)
    return softcap(logits, cfg.final_softcap)


def lm_forward(params, tokens, cfg: ModelConfig):
    """tokens (B, S) int32 -> (logits (B, S, vocab) f32, aux)."""
    x, aux = lm_backbone(params, tokens, cfg)
    return lm_logits(params, x, cfg), aux


def chunked_ce(logits_fn, x, tgt, chunk: int):
    """Mean next-token CE without materializing (B, S, V): the read-out
    and log-softmax run per sequence chunk inside a checkpointed scan, so
    the backward recomputes each chunk's logits (flash-CE)."""
    b, s, d = x.shape
    if not chunk or s <= chunk or s % chunk:
        logits = logits_fn(x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)
    nc = s // chunk

    # slice inside the loop (x stays loop-invariant in its original
    # sharded layout — a reshape/transpose into scan xs would drop the
    # batch sharding and replicate every chunk's logits)
    def body(acc, i):
        xi = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ti = jax.lax.dynamic_slice_in_dim(tgt, i * chunk, chunk, axis=1)
        logits = logits_fn(xi)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(nc))
    return total / (b * s)


def lm_loss(params, batch, cfg: ModelConfig):
    """batch: {tokens (B, S+1)} -> (loss, metrics). Next-token CE."""
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x, aux = lm_backbone(params, inp, cfg)
    ce = chunked_ce(lambda h: lm_logits(params, h, cfg), x, tgt,
                    cfg.ce_chunk)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux,
                  "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}


# -------------------------------------------------------------- serving --
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    """Stacked (n_groups, ...) cache pytree matching block_layout."""
    grp, n_groups = block_layout(cfg)
    hd, kvh = cfg.head_dim, cfg.n_kv

    def one(bd: BlockDef):
        if bd.kind in ("attn", "moe", "shared"):
            s = min(max_seq, bd.window) if bd.window else max_seq
            shape = (n_groups, batch, s, kvh, hd)
            # distinct arrays: k and v are donated separately at runtime
            return KVCache(k=jnp.zeros(shape, dtype),
                           v=jnp.zeros(shape, dtype))
        if bd.kind == "ssm":
            c = ssm_cache_init(cfg, batch, dtype=jnp.float32)
            return jax.tree_util.tree_map(
                lambda a: jnp.zeros((n_groups,) + a.shape, a.dtype), c)
        if bd.kind == "mlstm":
            c = mlstm_cache_init(cfg, batch, dtype=jnp.float32)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), c)
        if bd.kind == "slstm":
            c = slstm_cache_init(cfg, batch, dtype=jnp.float32)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), c)
        raise ValueError(bd.kind)

    return {f"cache_{j}": one(bd) for j, bd in enumerate(grp)}


def _decode_block(bp, bd: BlockDef, x, cache, pos, cfg, *,
                  shared_params=None, emb0=None):
    _, norm = _norm_fns(cfg)
    post = cfg.local_global_period > 0
    if bd.kind in ("attn", "moe"):
        ring = bd.window is not None and cache.k.shape[1] == bd.window
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, cache = decode_attention(bp["attn"], h, cache, pos, cfg,
                                    window=bd.window, ring=ring)
        if post:
            h = norm(bp["post_ln1"], h, cfg.norm_eps)
        x = x + h
        h = norm(bp["ln2"], x, cfg.norm_eps)
        if bd.kind == "moe":
            h, _ = moe(bp["moe"], h, cfg)
        else:
            h = mlp(bp["mlp"], h, cfg.cdtype, getattr(cfg, "mlp_act",
                                                      "silu"))
        if post:
            h = norm(bp["post_ln2"], h, cfg.norm_eps)
        return x + h, cache
    if bd.kind == "ssm":
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, cache = ssm_decode_step(bp["ssm"], h, cache, cfg)
        return x + h, cache
    if bd.kind == "mlstm":
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, cache = mlstm_decode_step(bp["mlstm"], h, cache, cfg)
        return x + h, cache
    if bd.kind == "slstm":
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, cache = slstm_decode_step(bp["slstm"], h, cache, cfg)
        return x + h, cache
    if bd.kind == "shared":
        sp = shared_params
        h = jnp.concatenate([x, emb0], axis=-1)
        h = norm(sp["ln_in"], h, cfg.norm_eps)
        h = dense(sp["win"], h, cfg.cdtype)
        h, cache = decode_attention(sp["attn"], h, cache, pos, cfg)
        x = x + h
        h = norm(sp["ln2"], x, cfg.norm_eps)
        return x + mlp(sp["mlp"], h, cfg.cdtype), cache
    raise ValueError(bd.kind)


def lm_decode_step(params, token, pos, caches, cfg: ModelConfig):
    """One decode step. token (B,) int32, pos scalar int32.

    Returns (logits (B, vocab) f32, updated caches).
    """
    grp, n_groups = block_layout(cfg)
    _, norm = _norm_fns(cfg)
    x = embed(params["embed"], token[:, None], cfg.cdtype)  # (B, 1, d)
    if cfg.local_global_period:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    emb0 = x
    shared = params.get("shared")

    stacked_p = {k: params[k] for k in params if k.startswith("blocks_")}

    def group_body(x, slices):
        gp, gc = slices
        new_caches = {}
        for j, bd in enumerate(grp):
            bp = None if bd.kind == "shared" else gp[f"blocks_{j}"]
            x, nc = _decode_block(bp, bd, x,
                                  gc[f"cache_{j}"], pos, cfg,
                                  shared_params=shared, emb0=emb0)
            new_caches[f"cache_{j}"] = nc
        return x, new_caches

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(group_body, x, (stacked_p, caches))
    else:
        outs = []
        for g in range(n_groups):
            gp = jax.tree_util.tree_map(lambda a: a[g], stacked_p)
            gc = jax.tree_util.tree_map(lambda a: a[g], caches)
            x, nc = group_body(x, (gp, gc))
            outs.append(nc)
        new_caches = jax.tree_util.tree_map(
            lambda *a: jnp.stack(a), *outs)

    x = norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.vocab) if cfg.tie_embeddings \
        else dense(params["unembed"], x).astype(jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    return logits[:, 0], new_caches


def lm_prefill(params, tokens, cfg: ModelConfig):
    """Prefill forward: full backbone over the prompt, read-out on the
    LAST position only (a production prefill returns the first sampled
    token's logits + the KV cache; materializing (B, S, V) logits would
    dwarf every other buffer). Returns logits (B, vocab) f32."""
    x, _ = lm_backbone(params, tokens, cfg)
    return lm_logits(params, x[:, -1], cfg)
