"""Encoder-decoder transformer (seamless-m4t backbone).

The modality frontend is a stub by assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d). Encoder blocks are
bidirectional self-attn + MLP; decoder blocks are causal self-attn +
cross-attn + MLP. Both stacks scan over stacked layer params.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.attention import (KVCache, attend_train, attention_init,
                                    decode_attention)
from repro.models.common import ModelConfig, vocab_padded
from repro.models.layers import (dense, embed, embedding_init, layernorm,
                                 layernorm_init, rmsnorm, rmsnorm_init,
                                 unembed)
from repro.models.mlp import mlp, mlp_init
from repro.sharding.hints import maybe_shard


def _norms(cfg):
    if cfg.norm_type == "layernorm":
        return layernorm_init, layernorm
    return rmsnorm_init, rmsnorm


def _enc_block_init(key, cfg):
    ninit, _ = _norms(cfg)
    ks = jax.random.split(key, 2)
    return {
        "ln1": ninit(cfg.d_model, cfg.pdtype),
        "attn": attention_init(ks[0], cfg),
        "ln2": ninit(cfg.d_model, cfg.pdtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype,
                        cfg.mlp_gated),
    }


def _dec_block_init(key, cfg):
    ninit, _ = _norms(cfg)
    ks = jax.random.split(key, 3)
    return {
        "ln1": ninit(cfg.d_model, cfg.pdtype),
        "self_attn": attention_init(ks[0], cfg),
        "ln_x": ninit(cfg.d_model, cfg.pdtype),
        "cross_attn": attention_init(ks[1], cfg),
        "ln2": ninit(cfg.d_model, cfg.pdtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.pdtype,
                        cfg.mlp_gated),
    }


def init_encdec_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ninit, _ = _norms(cfg)
    ks = jax.random.split(key, 4)
    ekeys = jax.random.split(ks[0], cfg.enc_layers)
    dkeys = jax.random.split(ks[1], cfg.dec_layers)
    return {
        "embed": embedding_init(ks[2], vocab_padded(cfg), cfg.d_model,
                                cfg.pdtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(ekeys),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(dkeys),
        "enc_norm": ninit(cfg.d_model, cfg.pdtype),
        "final_norm": ninit(cfg.d_model, cfg.pdtype),
    }


def encode(params, src_emb, cfg: ModelConfig):
    """src_emb (B, Ss, d) -> encoder output (B, Ss, d)."""
    _, norm = _norms(cfg)
    x = src_emb.astype(cfg.cdtype)

    def body(x, bp):
        x = maybe_shard(x, "residual")
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, _ = attend_train(bp["attn"], h, cfg, causal=False)
        x = x + h
        h = norm(bp["ln2"], x, cfg.norm_eps)
        return x + mlp(bp["mlp"], h, cfg.cdtype, cfg.mlp_act), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for g in range(cfg.enc_layers):
            x, _ = body(x, jax.tree_util.tree_map(
                lambda a: a[g], params["enc_blocks"]))
    return norm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(params, enc_out, tgt_tokens, cfg: ModelConfig,
                 return_hidden: bool = False):
    """Teacher-forced decoder. tgt_tokens (B, St) -> logits (or the
    final-norm hidden when return_hidden)."""
    _, norm = _norms(cfg)
    x = embed(params["embed"], tgt_tokens, cfg.cdtype)

    def body(x, bp):
        x = maybe_shard(x, "residual")
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, _ = attend_train(bp["self_attn"], h, cfg, causal=True)
        x = x + h
        h = norm(bp["ln_x"], x, cfg.norm_eps)
        h, _ = attend_train(bp["cross_attn"], h, cfg, causal=False,
                            kv_x=enc_out)
        x = x + h
        h = norm(bp["ln2"], x, cfg.norm_eps)
        return x + mlp(bp["mlp"], h, cfg.cdtype, cfg.mlp_act), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    else:
        for g in range(cfg.dec_layers):
            x, _ = body(x, jax.tree_util.tree_map(
                lambda a: a[g], params["dec_blocks"]))
    x = norm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    return unembed(params["embed"], x, cfg.vocab)


def encdec_loss(params, batch, cfg: ModelConfig):
    """batch: {src_emb (B,Ss,d), tokens (B,St+1)}. Chunked CE."""
    from repro.models.transformer import chunked_ce
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    enc_out = encode(params, batch["src_emb"], cfg)
    x = decode_train(params, enc_out, inp, cfg, return_hidden=True)
    ce = chunked_ce(lambda h: unembed(params["embed"], h, cfg.vocab),
                    x, tgt, cfg.ce_chunk)
    return ce, {"ce": ce, "aux": jnp.zeros(()),
                "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}


# ---------------------------------------------------------------- decode --
def init_encdec_cache(cfg: ModelConfig, batch: int, max_tgt: int,
                      src_len: int, dtype=jnp.bfloat16):
    l = cfg.dec_layers
    s_shape = (l, batch, max_tgt, cfg.n_kv, cfg.head_dim)
    x_shape = (l, batch, src_len, cfg.n_kv, cfg.head_dim)
    return {"self": KVCache(k=jnp.zeros(s_shape, dtype),
                            v=jnp.zeros(s_shape, dtype)),
            "cross": KVCache(k=jnp.zeros(x_shape, dtype),
                             v=jnp.zeros(x_shape, dtype))}


def build_cross_cache(params, enc_out, cfg: ModelConfig, dtype=jnp.bfloat16):
    """Precompute cross-attention K/V from encoder output, per layer."""
    b, ss, _ = enc_out.shape

    def one(bp):
        k = dense(bp["cross_attn"]["wk"], enc_out, cfg.cdtype)
        v = dense(bp["cross_attn"]["wv"], enc_out, cfg.cdtype)
        return KVCache(
            k=k.reshape(b, ss, cfg.n_kv, cfg.head_dim).astype(dtype),
            v=v.reshape(b, ss, cfg.n_kv, cfg.head_dim).astype(dtype))

    return jax.vmap(one)(params["dec_blocks"])


def encdec_decode_step(params, token, pos, caches, cfg: ModelConfig):
    """token (B,), pos scalar; caches {self: KVCache, cross: KVCache}."""
    _, norm = _norms(cfg)
    x = embed(params["embed"], token[:, None], cfg.cdtype)

    def body(x, sl):
        bp, selfc, crossc = sl
        h = norm(bp["ln1"], x, cfg.norm_eps)
        h, selfc = decode_attention(bp["self_attn"], h, selfc, pos, cfg)
        x = x + h
        h = norm(bp["ln_x"], x, cfg.norm_eps)
        h, _ = decode_attention(bp["cross_attn"], h, crossc, pos, cfg,
                                cross=True)
        x = x + h
        h = norm(bp["ln2"], x, cfg.norm_eps)
        return x + mlp(bp["mlp"], h, cfg.cdtype, cfg.mlp_act), selfc

    if cfg.scan_layers:
        x, new_self = jax.lax.scan(
            body, x, (params["dec_blocks"], caches["self"],
                      caches["cross"]))
    else:
        outs = []
        for g in range(cfg.dec_layers):
            sl = jax.tree_util.tree_map(
                lambda a: a[g], (params["dec_blocks"], caches["self"],
                                 caches["cross"]))
            x, nc = body(x, sl)
            outs.append(nc)
        new_self = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *outs)
    x = norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.vocab)
    return logits[:, 0], {"self": new_self, "cross": caches["cross"]}
