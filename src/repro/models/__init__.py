"""Model zoo substrate for the 10 assigned architectures."""
from repro.models.common import ModelConfig, active_param_count, param_count
from repro.models.transformer import (block_layout, chunked_ce, init_cache,
                                      init_lm_params, lm_backbone,
                                      lm_decode_step, lm_forward, lm_logits,
                                      lm_loss, lm_prefill)
from repro.models.encdec import (build_cross_cache, decode_train,
                                 encdec_decode_step, encdec_loss, encode,
                                 init_encdec_cache, init_encdec_params)

__all__ = [
    "ModelConfig", "active_param_count", "param_count", "block_layout",
    "init_cache", "init_lm_params", "lm_decode_step", "lm_forward",
    "lm_loss", "lm_prefill", "build_cross_cache", "encdec_decode_step",
    "encdec_loss", "encode", "init_encdec_cache", "init_encdec_params",
]
