"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM (arXiv:2405.04517).

mLSTM is a matrix-memory linear-attention variant with exponential
input gates and sigmoid forget gates; we implement the log-space
stabilized *chunkwise* form (same chunk-scan pattern as ssm.py / the TEDA
core): intra-chunk via masked-decay matmuls, inter-chunk state
(C tilde (P,P), n tilde (P), log-scale m) carried by lax.scan. Decode is a
single stabilized recurrence step, O(1) in context — which is why
xlstm-350m runs the long_500k cell.

sLSTM keeps per-head scalar memories with a genuine hidden-state
recurrence (R h_{t-1}) — inherently sequential, implemented with lax.scan
over time (it is a small minority of blocks: cfg.slstm_every).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init

CONV_W = 4


# ============================================================== mLSTM ====
class MLSTMCache(NamedTuple):
    c: jnp.ndarray      # (B, H, P, P) stabilized matrix memory
    n: jnp.ndarray      # (B, H, P) stabilized normalizer
    m: jnp.ndarray      # (B, H) log scale
    conv: jnp.ndarray   # (B, CONV_W-1, d_in)


def mlstm_dims(cfg, d=None):
    d = d or cfg.d_model
    d_in = int(cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    p = d_in // h
    return d, d_in, h, p


def mlstm_init(key, cfg, d=None):
    d, d_in, h, p = mlstm_dims(cfg, d)
    ks = jax.random.split(key, 7)
    return {
        "wup": dense_init(ks[0], d, 2 * d_in, False, cfg.pdtype),
        "conv": (jax.random.normal(ks[1], (CONV_W, d_in), jnp.float32)
                 * 0.1).astype(cfg.pdtype),
        "wq": dense_init(ks[2], d_in, d_in, False, cfg.pdtype),
        "wk": dense_init(ks[3], d_in, d_in, False, cfg.pdtype,
                         scale=(d_in ** -0.5) * (p ** -0.25)),
        "wv": dense_init(ks[4], d_in, d_in, False, cfg.pdtype),
        "wif": dense_init(ks[5], d_in, 2 * h, True, cfg.pdtype),
        "norm": rmsnorm_init(d_in, cfg.pdtype),
        "wdown": dense_init(ks[6], d_in, d, False, cfg.pdtype,
                            scale=d_in ** -0.5),
    }


def _conv_causal(w, seq, cache=None):
    if cache is None:
        pad = jnp.zeros((seq.shape[0], CONV_W - 1, seq.shape[2]), seq.dtype)
    else:
        pad = cache.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i][None, None]
              for i in range(CONV_W))
    return jax.nn.silu(out), full[:, -(CONV_W - 1):]


def _mlstm_proj(params, x, cfg, d):
    d, d_in, h, p = mlstm_dims(cfg, d)
    cd = cfg.cdtype
    up = dense(params["wup"], x, cd)
    xm, z = up[..., :d_in], up[..., d_in:]
    return xm, z, (d_in, h, p)


def mlstm_forward(params, x, cfg, d=None):
    """Chunkwise-parallel training path. x (B, T, d)."""
    b, t, _ = x.shape
    cd = cfg.cdtype
    xm, z, (d_in, h, p) = _mlstm_proj(params, x, cfg, d)
    xc, _ = _conv_causal(params["conv"].astype(cd), xm)

    q = dense(params["wq"], xc, cd).reshape(b, t, h, p)
    k = dense(params["wk"], xc, cd).reshape(b, t, h, p)
    v = dense(params["wv"], xm, cd).reshape(b, t, h, p)
    gates = dense(params["wif"], xc, cd).astype(jnp.float32)
    li = gates[..., :h]                       # log input gate (exp gate)
    lf = jax.nn.log_sigmoid(gates[..., h:])   # log forget gate

    qch = min(cfg.ssm_chunk, t)
    assert t % qch == 0
    nc = t // qch
    # chunk-major
    cm = lambda a: a.reshape(b, nc, qch, *a.shape[2:]).transpose(
        1, 0, 2, *range(3, a.ndim + 1))
    qc, kc, vc = cm(q), cm(k), cm(v)
    lic, lfc = cm(li), cm(lf)
    tri = jnp.tril(jnp.ones((qch, qch), bool))

    def chunk(carry, inp):
        ct, nt, mc = carry  # (b,h,p,p), (b,h,p), (b,h)
        qi, ki, vi, lii, lfi = inp
        cum = jnp.cumsum(lfi, axis=1)          # (b, q, h)
        g = lii - cum                          # g_s = li_s - cum_s
        m_row = jax.lax.cummax(g, axis=1)      # (b, q, h)
        stab = jnp.maximum(m_row, mc[:, None])  # per-row stabilizer
        # intra-chunk scores
        sc = jnp.exp(g[:, None] - stab[:, :, None])  # (b, t, s, h)
        sc = jnp.where(tri[None, :, :, None], sc, 0.0)
        qk = jnp.einsum("bthp,bshp->btsh", qi, ki,
                        preferred_element_type=jnp.float32)
        w_ts = sc * qk
        num = jnp.einsum("btsh,bshp->bthp", w_ts.astype(cd), vi,
                         preferred_element_type=jnp.float32)
        den = jnp.sum(w_ts, axis=2)  # (b, t, h)
        # inter-chunk (carried state, scale mc)
        lam = jnp.exp(mc[:, None] - stab)  # (b, q, h)
        num = num + lam[..., None] * jnp.einsum(
            "bthp,bhpr->bthr", qi.astype(jnp.float32), ct,
            preferred_element_type=jnp.float32)
        den = den + lam * jnp.einsum("bthp,bhp->bth",
                                     qi.astype(jnp.float32), nt)
        hmax = jnp.maximum(jnp.abs(den), jnp.exp(-(cum + stab)))
        y = num / hmax[..., None]
        # ---- state update -------------------------------------------------
        cum_last = cum[:, -1]  # (b, h)
        g_last = jax.lax.cummax(g, axis=1)[:, -1]  # max over chunk
        m_new = cum_last + jnp.maximum(mc, g_last)
        scale_old = jnp.exp(mc + cum_last - m_new)  # (b, h)
        w_s = jnp.exp(cum_last[:, None] + g - m_new[:, None])  # (b, q, h)
        c_new = (ct * scale_old[..., None, None]
                 + jnp.einsum("bsh,bshp,bshr->bhpr", w_s,
                              ki.astype(jnp.float32),
                              vi.astype(jnp.float32),
                              preferred_element_type=jnp.float32))
        n_new = (nt * scale_old[..., None]
                 + jnp.einsum("bsh,bshp->bhp", w_s, ki.astype(jnp.float32)))
        return (c_new, n_new, m_new), y

    c0 = jnp.zeros((b, h, p, p), jnp.float32)
    n0 = jnp.zeros((b, h, p), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    if nc == 1:  # loop-free path (dry-run flop calibration)
        _, y = chunk((c0, n0, m0), (qc[0], kc[0], vc[0], lic[0], lfc[0]))
        y = y.reshape(b, t, d_in).astype(cd)
    else:
        _, ys = jax.lax.scan(chunk, (c0, n0, m0), (qc, kc, vc, lic, lfc))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, d_in).astype(cd)

    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return dense(params["wdown"], y, cd)


def mlstm_cache_init(cfg, batch, d=None, dtype=jnp.float32) -> MLSTMCache:
    d, d_in, h, p = mlstm_dims(cfg, d)
    return MLSTMCache(
        c=jnp.zeros((batch, h, p, p), dtype),
        n=jnp.zeros((batch, h, p), dtype),
        m=jnp.full((batch, h), -1e30, dtype),
        conv=jnp.zeros((batch, CONV_W - 1, d_in), dtype),
    )


def mlstm_decode_step(params, x, cache: MLSTMCache, cfg, d=None):
    """Stabilized single-step recurrence. x (B, 1, d)."""
    b = x.shape[0]
    cd = cfg.cdtype
    xm, z, (d_in, h, p) = _mlstm_proj(params, x, cfg, d)
    xc, conv_new = _conv_causal(params["conv"].astype(cd), xm, cache.conv)

    q = dense(params["wq"], xc, cd).reshape(b, h, p).astype(jnp.float32)
    k = dense(params["wk"], xc, cd).reshape(b, h, p).astype(jnp.float32)
    v = dense(params["wv"], xm, cd).reshape(b, h, p).astype(jnp.float32)
    gates = dense(params["wif"], xc, cd).astype(jnp.float32)[:, 0]
    li, lf = gates[..., :h], jax.nn.log_sigmoid(gates[..., h:])

    m_new = jnp.maximum(lf + cache.m, li)
    a = jnp.exp(lf + cache.m - m_new)
    bgt = jnp.exp(li - m_new)
    c_new = (cache.c * a[..., None, None]
             + bgt[..., None, None] * jnp.einsum("bhp,bhr->bhpr", k, v))
    n_new = cache.n * a[..., None] + bgt[..., None] * k
    num = jnp.einsum("bhp,bhpr->bhr", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n_new)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, d_in).astype(cd)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(params["wdown"], y, cd)
    return out, MLSTMCache(c=c_new, n=n_new, m=m_new, conv=conv_new)


# ============================================================== sLSTM ====
class SLSTMCache(NamedTuple):
    c: jnp.ndarray  # (B, d)
    n: jnp.ndarray  # (B, d)
    h: jnp.ndarray  # (B, d)
    m: jnp.ndarray  # (B, d)


def slstm_init(key, cfg, d=None):
    d = d or cfg.d_model
    h = cfg.n_heads
    ph = d // h
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], d, 4 * d, True, cfg.pdtype),  # z i f o
        "r": (jax.random.normal(ks[1], (4, h, ph, ph), jnp.float32)
              * ph ** -0.5).astype(cfg.pdtype),
        "norm": rmsnorm_init(d, cfg.pdtype),
        "wdown": dense_init(ks[2], d, d, False, cfg.pdtype),
    }


def _slstm_cell(params, xw, state: SLSTMCache, cfg, d):
    """One step. xw: precomputed Wx x + b, (B, 4d)."""
    h_heads = cfg.n_heads
    ph = d // h_heads
    hprev = state.h.reshape(-1, h_heads, ph)
    rh = jnp.einsum("ghpr,bhp->gbhr", params["r"].astype(jnp.float32),
                    hprev.astype(jnp.float32)).reshape(4, -1, d)
    pre = xw.astype(jnp.float32).reshape(-1, 4, d).transpose(1, 0, 2) + rh
    zt = jnp.tanh(pre[0])
    li = pre[1]                       # exp input gate (log space)
    lf = jax.nn.log_sigmoid(pre[2])   # sigmoid forget in log space
    ot = jax.nn.sigmoid(pre[3])
    m_new = jnp.maximum(lf + state.m, li)
    a = jnp.exp(lf + state.m - m_new)
    bg = jnp.exp(li - m_new)
    c_new = a * state.c + bg * zt
    n_new = jnp.maximum(a * state.n + bg, jnp.exp(-m_new))
    h_new = ot * c_new / n_new
    return SLSTMCache(c=c_new, n=n_new, h=h_new, m=m_new)


def slstm_cache_init(cfg, batch, d=None, dtype=jnp.float32) -> SLSTMCache:
    d = d or cfg.d_model
    z = jnp.zeros((batch, d), dtype)
    return SLSTMCache(c=z, n=z + 1e-6, h=z, m=jnp.full((batch, d), -1e30,
                                                       dtype))


def slstm_forward(params, x, cfg, d=None):
    """Sequential scan over T (sLSTM is inherently recurrent)."""
    d = d or cfg.d_model
    b, t, _ = x.shape
    cd = cfg.cdtype
    xw = dense(params["wx"], x, cd)  # (B, T, 4d)

    def step(state, xw_t):
        new = _slstm_cell(params, xw_t, state, cfg, d)
        return new, new.h

    state0 = slstm_cache_init(cfg, b, d)
    _, hs = jax.lax.scan(step, state0, xw.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(cd)  # (B, T, d)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return dense(params["wdown"], y, cd)


def slstm_decode_step(params, x, cache: SLSTMCache, cfg, d=None):
    cd = cfg.cdtype
    d = d or cfg.d_model
    xw = dense(params["wx"], x, cd)[:, 0]
    new = _slstm_cell(params, xw, cache, cfg, d)
    y = rmsnorm(params["norm"], new.h[:, None].astype(cd), cfg.norm_eps)
    return dense(params["wdown"], y, cd), new
