"""Shared model configuration covering all 10 assigned architectures.

One dataclass drives every family (dense / moe / ssm / hybrid / encdec /
vlm / audio backbones); family-specific fields are ignored elsewhere.
Configs in `repro.configs` instantiate it with the exact published values.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # attention variants
    rope_theta: float = 10000.0
    qkv_bias: bool = False  # qwen2
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    window: Optional[int] = None  # sliding-window size (mixtral/starcoder2)
    local_global_period: int = 0  # gemma2: 2 => alternate local/global
    attn_scale: Optional[float] = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_chunk: int = 65536  # block-wise dispatch above this token count

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # hybrid (zamba2): a shared attention block every `shared_period` SSM
    # layers, reusing one set of attention weights (the zamba trick)
    shared_period: int = 0

    # xLSTM: one sLSTM block every `slstm_every` mLSTM blocks (0 = none)
    slstm_every: int = 0
    mlstm_proj_factor: float = 2.0

    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0
    tie_embeddings: bool = True

    # layer flavor
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm (starcoder2, seamless)
    mlp_act: str = "silu"  # silu | gelu
    mlp_gated: bool = True  # False: classic 2-matrix MLP

    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots | everything
    scan_layers: bool = True
    # two-level layer scan (sqrt-remat): outer_scan outer steps, each an
    # inner scan of n_groups/outer_scan checkpointed groups — shrinks the
    # saved-residual stack from n_groups to outer_scan (+inner transient)
    outer_scan: int = 0
    norm_eps: float = 1e-6

    # attention chunking (flash-style) — perf-tunable
    q_chunk: int = 512
    kv_chunk: int = 1024
    # chunked cross-entropy: logits are computed (and re-computed in the
    # backward) per sequence chunk, never materializing (B, S, V); 0 = off
    ce_chunk: int = 1024
    # KV-cache storage dtype (decode): bfloat16 | float8_e4m3fn (halves
    # long-context cache traffic; dequant on read)
    kv_dtype: str = "bfloat16"


    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv, 1)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv=max(1, min(self.n_kv, 2)),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            window=min(self.window, 64) if self.window else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=32,
            enc_layers=min(self.enc_layers, 2),
            dec_layers=min(self.dec_layers, 2),
            shared_period=2 if self.shared_period else 0,
            slstm_every=2 if self.slstm_every else 0,
            q_chunk=32,
            kv_chunk=64,
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def vocab_padded(cfg: ModelConfig) -> int:
    """Embedding rows padded to a shardable multiple of 128 (production
    practice: seamless's 256206 would otherwise block vocab sharding and
    replicate multi-GB logits). The pad tail is masked in unembed."""
    return -(-cfg.vocab // 128) * 128


def param_count(cfg: ModelConfig) -> int:
    """Rough total parameter count (for 6ND roofline bookkeeping)."""
    d, h, kv, hd, ff, v = (cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                           cfg.d_ff, cfg.vocab)
    attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
    if cfg.family == "moe":
        mlp = cfg.n_experts * 3 * d * ff + d * cfg.n_experts
    elif cfg.family == "ssm":  # xlstm
        din = int(d * cfg.mlstm_proj_factor)
        mlp = 0
        attn = 2 * d * din + 3 * din * din // 1 + din * d  # per mLSTM block
    else:
        mlp = 3 * d * ff
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        ssm = d * (2 * d_in + 2 * cfg.n_heads * 0) + d_in * d
        per = ssm + 2 * d_in * cfg.ssm_state
        shared = attn + mlp
        n_shared = cfg.n_layers // max(cfg.shared_period, 1)
        return cfg.n_layers * per + shared * 1 + n_shared * 0 + 2 * v * d
    layers = cfg.enc_layers + cfg.dec_layers if cfg.family == "encdec" \
        else cfg.n_layers
    per = attn + mlp + 2 * d
    if cfg.family == "encdec":
        per = per + attn  # cross attention
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return layers * per + emb


def active_param_count(cfg: ModelConfig) -> int:
    """Active-per-token parameters (MoE: top_k of n_experts)."""
    if cfg.family != "moe":
        return param_count(cfg)
    total = param_count(cfg)
    expert_p = cfg.n_experts * 3 * cfg.d_model * cfg.d_ff * cfg.n_layers
    active_p = cfg.top_k * 3 * cfg.d_model * cfg.d_ff * cfg.n_layers
    return total - expert_p + active_p
