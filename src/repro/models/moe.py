"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch is sort-based (megablocks-style, no (T, E, C) one-hot tensors):
token->expert assignments are sorted by expert id, truncated to a capacity
of C = ceil(T * top_k * capacity_factor / E) per expert, gathered into an
(E, C, d) buffer, run through batched expert MLPs (einsum over the expert
axis — shardable over the mesh `model` axis = expert parallelism), and
scattered back weighted by the router probability. Dropped tokens (over
capacity) pass through the residual untouched, as in GShard/Switch.

FLOP count stays proportional to *active* parameters — keeps the 6·N_act·D
roofline bookkeeping honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_init(key, cfg, d: int | None = None):
    d = d or cfg.d_model
    e, ff = cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    scale = d ** -0.5

    def ew(k, a, b, s):
        return (jax.random.normal(k, (e, a, b), jnp.float32) * s
                ).astype(cfg.pdtype)

    return {
        "router": dense_init(ks[0], d, e, False, cfg.pdtype),
        "wi": ew(ks[1], d, ff, scale),
        "wg": ew(ks[2], d, ff, scale),
        "wo": ew(ks[3], ff, d, ff ** -0.5),
    }


def moe(p, x, cfg):
    """x: (B, S, d) -> (B, S, d), plus aux losses dict.

    When cfg.moe_chunk > 0 and the token count exceeds it, dispatch runs
    in token blocks under lax.scan (block-wise MoE): the (E, C, d)
    buffers scale with the block, not the full 1M-token prefill."""
    b, s, d = x.shape
    t = b * s
    chunk = cfg.moe_chunk
    if chunk and t > chunk and t % chunk == 0:
        nc = t // chunk

        def body(_, xi):
            yi, aux = _moe_tokens(p, xi, cfg)
            return None, (yi, aux)

        _, (ys, auxs) = jax.lax.scan(
            body, None, x.reshape(nc, chunk, d))
        aux = jax.tree_util.tree_map(jnp.mean, auxs)
        return ys.reshape(b, s, d).astype(cfg.cdtype), aux
    y, aux = _moe_tokens(p, x.reshape(t, d), cfg)
    return y.reshape(b, s, d).astype(cfg.cdtype), aux


def _moe_tokens(p, xf, cfg):
    """Dispatch-combine for a flat token block xf (T, d)."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    cd = cfg.cdtype
    cap = int(t * k * cfg.capacity_factor / e + 0.999)
    cap = max(8, min(cap, t))
    logits = (xf.astype(jnp.float32)
              @ p["router"]["w"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)  # renormalize

    # ---- flatten assignments and sort by expert --------------------------
    flat_expert = choice.reshape(-1)  # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, stok, sg = (flat_expert[order], flat_token[order], flat_gate[order])

    # position within its expert's run = rank - start_of_expert
    counts = jnp.bincount(se, length=e)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[se]
    keep = pos_in_e < cap

    # ---- gather into (E, C, d) -------------------------------------------
    buf = jnp.zeros((e, cap, d), cd)
    src = jnp.where(keep, stok, 0)
    buf = buf.at[se, jnp.where(keep, pos_in_e, cap - 1)].set(
        jnp.where(keep[:, None], xf[src].astype(cd), 0.0))

    # ---- batched expert MLP (einsum over expert axis => EP shardable) ---
    hi = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(cd))
    hg = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(cd))
    ho = jnp.einsum("ecf,efd->ecd", hi * jax.nn.silu(hg),
                    p["wo"].astype(cd))

    # ---- weighted scatter back -------------------------------------------
    out = jnp.zeros((t, d), jnp.float32)
    contrib = ho[se, jnp.where(keep, pos_in_e, cap - 1)].astype(jnp.float32)
    contrib = contrib * (sg * keep)[:, None]
    out = out.at[stok].add(contrib, mode="drop")

    # ---- aux: load-balancing loss (Switch) + router z-loss ---------------
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        (jax.nn.one_hot(choice, e).sum(1) > 0).astype(jnp.float32), axis=0)
    aux = {
        "load_balance": e * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.astype(cd), aux
