"""Gated MLP (SwiGLU / GeGLU) used by all dense families."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init


def mlp_init(key, d: int, ff: int, dtype=jnp.float32, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d, ff, False, dtype),
        "wo": dense_init(ks[2], ff, d, False, dtype, scale=ff ** -0.5),
    }
    if gated:
        p["wg"] = dense_init(ks[1], d, ff, False, dtype)
    return p


def mlp(p, x, cd, act: str = "silu"):
    h = dense(p["wi"], x, cd)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    if "wg" in p:  # gated (SwiGLU/GeGLU)
        return dense(p["wo"], h * actf(dense(p["wg"], x, cd)), cd)
    return dense(p["wo"], actf(h), cd)  # classic 2-matrix MLP
