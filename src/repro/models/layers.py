"""Primitive layers shared by every architecture (pure functions + pytrees).

Parameters are plain dicts of jnp arrays so they stack cleanly for
scan-over-layers and shard transparently under pjit. Initializers take an
explicit PRNG key; compute runs in cfg.compute_dtype with f32 reductions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------- norms

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- linear

def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None):
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ------------------------------------------------------------- embedding

def embedding_init(key, vocab: int, d: int, dtype=jnp.float32,
                   n_real: Optional[int] = None):
    """vocab = padded table rows; n_real (<= vocab) marks live ids."""
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * (d ** -0.5)).astype(dtype)}


def embed(p, ids, compute_dtype):
    return jnp.take(p["table"], ids, axis=0).astype(compute_dtype)


def unembed(p, x, n_real: Optional[int] = None):
    """Tied read-out: (..., d) @ (d, vocab) in f32 for a stable softmax.

    n_real masks padded table rows to -inf so the softmax/CE matches the
    unpadded vocabulary exactly."""
    logits = x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T
    v = p["table"].shape[0]
    if n_real is not None and n_real < v:
        mask = jnp.arange(v) < n_real
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ------------------------------------------------------------------ rope

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == cos.ndim + 1:  # broadcast over a heads axis
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
