"""Mamba2 (SSD) block — chunked-parallel training, O(1)-state decode.

The state-space recurrence  S_t = a_t S_{t-1} + dt_t x_t ⊗ B_t,
y_t = C_t·S_t + D x_t  is computed with the SSD chunked algorithm:
intra-chunk terms via an attention-like masked-decay matmul, inter-chunk
state carried by a lax.scan over chunks. This is the same
"sequential recurrence -> chunked associative scan" transformation the
TEDA core uses (DESIGN.md §2) — deliberately shared machinery.

Decode keeps (conv buffer (B, W-1, ch), SSM state (B, H, P, N)) — O(1) in
context length, which is what makes zamba2/xlstm the long_500k archs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init

CONV_W = 4  # depthwise causal conv width (mamba2 default)


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # (B, CONV_W-1, conv_ch)
    state: jnp.ndarray  # (B, H, P, N)


def ssm_dims(cfg, d=None):
    d = d or cfg.d_model
    d_in = cfg.ssm_expand * d
    p = cfg.ssm_head_dim
    h = d_in // p
    n = cfg.ssm_state
    return d, d_in, h, p, n


def ssm_init(key, cfg, d=None):
    d, d_in, h, p, n = ssm_dims(cfg, d)
    conv_ch = d_in + 2 * n
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z, x, B, C, dt]
        "win": dense_init(ks[0], d, 2 * d_in + 2 * n + h, False, cfg.pdtype),
        "conv": (jax.random.normal(ks[1], (CONV_W, conv_ch), jnp.float32)
                 * 0.1).astype(cfg.pdtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(d_in, cfg.pdtype),
        "wout": dense_init(ks[2], d_in, d, False, cfg.pdtype,
                           scale=d_in ** -0.5),
    }


def _split(p, u, cfg, d):
    _, d_in, h, _, n = ssm_dims(cfg, d)
    z = u[..., :d_in]
    xbc = u[..., d_in:d_in + d_in + 2 * n]
    dt = u[..., -h:]
    return z, xbc, dt


def _causal_conv(w, seq, cache=None):
    """Depthwise causal conv. seq (B, T, ch), w (W, ch)."""
    if cache is None:
        pad = jnp.zeros((seq.shape[0], CONV_W - 1, seq.shape[2]), seq.dtype)
    else:
        pad = cache.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)  # (B, T+W-1, ch)
    out = sum(full[:, i:i + seq.shape[1]] * w[i][None, None]
              for i in range(CONV_W))
    new_cache = full[:, -(CONV_W - 1):]
    return jax.nn.silu(out), new_cache


def ssm_forward(params, x, cfg, d=None):
    """Training/prefill path. x (B, T, d) -> (B, T, d). T % chunk == 0."""
    d, d_in, h, p, n = ssm_dims(cfg, d)
    b, t, _ = x.shape
    q = min(cfg.ssm_chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q
    cd = cfg.cdtype

    u = dense(params["win"], x, cd)
    z, xbc, dt = _split(params, u, cfg, d)
    xbc, _ = _causal_conv(params["conv"].astype(cd), xbc)
    xs = xbc[..., :d_in].reshape(b, t, h, p)
    bs = xbc[..., d_in:d_in + n]  # (B, T, N)
    cs = xbc[..., d_in + n:]      # (B, T, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])  # (B, T, H)
    a = -jnp.exp(params["a_log"])  # (H,) negative decay rates

    # ---- chunked SSD: lax.scan over chunks, O(q^2 h) working set --------
    # (the all-chunk-parallel form would materialize a (b,nc,q,q,h) decay
    # tensor — per-chunk sequencing is the memory-sane SSD schedule)
    la = (dt * a).reshape(nc, b, q, h)  # chunk-major for scan
    xs_c = xs.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    bs_c = bs.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    cs_c = cs.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    dt_c = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(s_prev, inp):
        la_c, xc, bc, cc, dc = inp  # (b,q,h), (b,q,h,p), (b,q,n)x2, (b,q,h)
        cl = jnp.cumsum(la_c, axis=1)  # (b, q, h)
        # intra: y[t] = sum_{s<=t} exp(cl_t - cl_s) dt_s (C_t.B_s) x_s
        decay = jnp.exp(cl[:, :, None] - cl[:, None])  # (b, t, s, h)
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("btn,bsn->bts", cc, bc,
                        preferred_element_type=jnp.float32)
        w_ts = cb[..., None] * decay * dc[:, None]  # (b, t, s, h)
        y_in = jnp.einsum("btsh,bshp->bthp", w_ts.astype(cd), xc,
                          preferred_element_type=jnp.float32)
        # inter: contribution of the carried state
        y_in = y_in + jnp.einsum("bth,btn,bhpn->bthp", jnp.exp(cl),
                                 cc.astype(jnp.float32), s_prev,
                                 preferred_element_type=jnp.float32)
        # state update for the next chunk
        tail = jnp.exp(cl[:, -1:] - cl)  # (b, q, h)
        zb = jnp.einsum("bth,bthp,btn->bhpn", (tail * dc).astype(cd), xc,
                        bc, preferred_element_type=jnp.float32)
        s_new = s_prev * jnp.exp(cl[:, -1])[..., None, None] + zb
        return s_new, y_in

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    if nc == 1:  # loop-free path (dry-run flop calibration)
        _, y = chunk_step(s0, (la[0], xs_c[0], bs_c[0], cs_c[0], dt_c[0]))
        y = y.reshape(b, t, h, p)
    else:
        _, y_chunks = jax.lax.scan(chunk_step, s0,
                                   (la, xs_c, bs_c, cs_c, dt_c))
        y = y_chunks.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t, d_in).astype(cd)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(params["wout"], y, cd)


def ssm_cache_init(cfg, batch: int, d=None, dtype=jnp.float32) -> SSMCache:
    d, d_in, h, p, n = ssm_dims(cfg, d)
    return SSMCache(
        conv=jnp.zeros((batch, CONV_W - 1, d_in + 2 * n), dtype),
        state=jnp.zeros((batch, h, p, n), dtype),
    )


def ssm_decode_step(params, x, cache: SSMCache, cfg, d=None):
    """x (B, 1, d) -> (B, 1, d), O(1) state update."""
    d, d_in, h, p, n = ssm_dims(cfg, d)
    b = x.shape[0]
    cd = cfg.cdtype

    u = dense(params["win"], x, cd)
    z, xbc, dt = _split(params, u, cfg, d)
    xbc, new_conv = _causal_conv(params["conv"].astype(cd), xbc, cache.conv)
    xs = xbc[:, 0, :d_in].reshape(b, h, p)
    bs = xbc[:, 0, d_in:d_in + n]
    cs = xbc[:, 0, d_in + n:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    dec = jnp.exp(dt * a)  # (B, H)
    s_new = (cache.state * dec[..., None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32),
                          bs.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", cs.astype(jnp.float32), s_new)
    y = y + params["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, d_in).astype(cd)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(params["wout"], y, cd), SSMCache(conv=new_conv,
                                                  state=s_new)
