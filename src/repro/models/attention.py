"""GQA attention: flash-style chunked training path + cached decode path.

Covers every assigned variant: grouped KV heads (all), RoPE, QKV bias
(qwen2), attention-logit softcap (gemma2), sliding window (mixtral,
starcoder2), local/global alternation (gemma2), non-causal cross
attention (seamless enc-dec).

The training/prefill path is an online-softmax (flash) implementation in
pure jnp: lax.scan over query chunks x kv chunks keeps the working set at
O(q_chunk * kv_chunk) regardless of sequence length, with optional causal
chunk skipping (lax.cond) so fully-masked kv chunks cost nothing — both
matter at prefill_32k and are hillclimb knobs (cfg.q_chunk / cfg.kv_chunk).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rope, softcap

NEG = -1e30


def attention_init(key, cfg, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, cfg.qkv_bias, cfg.pdtype),
        "wk": dense_init(ks[1], d, kv * hd, cfg.qkv_bias, cfg.pdtype),
        "wv": dense_init(ks[2], d, kv * hd, cfg.qkv_bias, cfg.pdtype),
        "wo": dense_init(ks[3], h * hd, d, False, cfg.pdtype,
                         scale=(h * hd) ** -0.5),
    }


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, KV, D)
    v: jnp.ndarray  # (B, S, KV, D)


def _mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Sq, Sk) bool; True = attend."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return ok


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    cap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    q_offset: int = 0, skip_masked_chunks: bool = True):
    """Online-softmax attention.

    q: (B, Sq, KV, G, D); k, v: (B, Sk, KV, D). Returns (B, Sq, KV, G, D).
    q_offset: absolute position of q[0] (for decode-with-prefix reuse).
    """
    b, sq, kvh, g, d = q.shape
    sk = k.shape[1]
    scale = d ** -0.5 if scale is None else scale
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    assert sq % q_chunk == 0 and sk % kv_chunk == 0

    qc = q.reshape(b, nq, q_chunk, kvh, g, d)
    kc = k.reshape(b, nk, kv_chunk, kvh, d)
    vc = v.reshape(b, nk, kv_chunk, kvh, d)

    def kv_step(carry, j, qi, iq):
        m, l, acc = carry
        kj = jnp.take(kc, j, axis=1)  # (B, kc, KV, D)
        vj = jnp.take(vc, j, axis=1)

        def run(_):
            s = jnp.einsum("bqkgd,bckd->bkgqc", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, cap)
            q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)
            k_pos = j * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(q_pos, k_pos, causal, window)  # (qc, kc)
            s = jnp.where(msk[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        if skip_masked_chunks and causal:
            # whole kv chunk in the future of the whole q chunk -> skip
            q_hi = q_offset + iq * q_chunk + q_chunk - 1
            live = j * kv_chunk <= q_hi
            if window is not None:
                q_lo = q_offset + iq * q_chunk
                live &= (j + 1) * kv_chunk - 1 >= q_lo - window + 1
            carry = jax.lax.cond(live, run, lambda _: (m, l, acc), None)
        else:
            carry = run(None)
        return carry, None

    def q_step(_, iq):
        qi = jnp.take(qc, iq, axis=1)  # (B, qc, KV, G, D)
        m0 = jnp.full((b, kvh, g, q_chunk), NEG, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            functools.partial(kv_step, qi=qi, iq=iq), (m0, l0, a0),
            jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, qc, D)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, qc, KV, G, D)

    # flash-attention backward: recompute per-chunk probabilities instead
    # of saving the O(S^2) scan intermediates (they would otherwise be
    # stacked over all (nq, nk) chunks by lax.scan's AD rule — the very
    # tensors flash attention exists to avoid materializing)
    q_step = jax.checkpoint(
        q_step, policy=jax.checkpoint_policies.nothing_saveable)

    if nq == 1 and nk == 1:
        # loop-free path (also used by the dry-run flop calibration:
        # HLO cost analysis does not multiply while-loop bodies)
        m0 = jnp.full((b, kvh, g, q_chunk), NEG, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = kv_step((m0, l0, a0), jnp.int32(0),
                                 qi=qc[:, 0], iq=jnp.int32(0))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(
            0, 3, 1, 2, 4)
        return out.reshape(b, sq, kvh, g, d).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq, B, qc, ...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, d)
    return out.astype(q.dtype)


def attend_train(params, x, cfg, *, causal=True, window=None,
                 kv_x: Optional[jnp.ndarray] = None, positions=None):
    """Full attention sub-layer for training/prefill.

    x: (B, S, d). kv_x: source of K/V (cross attention) — defaults to x.
    Returns (out (B, S, d), KVCache of this segment).
    """
    b, s, _ = x.shape
    cross = kv_x is not None
    kv_x = x if kv_x is None else kv_x
    sk = kv_x.shape[1]
    hd, h, kvh = cfg.head_dim, cfg.n_heads, cfg.n_kv
    g = cfg.q_per_kv
    cd = cfg.cdtype

    q = dense(params["wq"], x, cd).reshape(b, s, kvh, g, hd)
    k = dense(params["wk"], kv_x, cd).reshape(b, sk, kvh, hd)
    v = dense(params["wv"], kv_x, cd).reshape(b, sk, kvh, hd)

    if positions is None:
        positions = jnp.arange(s)
    if not cross:  # self-attention (causal or bidirectional): RoPE
        q = rope(q.reshape(b, s, kvh * g, hd), positions[None],
                 cfg.rope_theta).reshape(b, s, kvh, g, hd)
        k = rope(k, jnp.arange(sk)[None], cfg.rope_theta)

    out = flash_attention(
        q, k, v, causal=causal, window=window, cap=cfg.attn_softcap,
        scale=cfg.attn_scale, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = out.reshape(b, s, h * hd)
    return dense(params["wo"], out, cd), KVCache(k=k, v=v)


def decode_attention(params, x, cache: KVCache, pos, cfg, *,
                     window=None, cross: bool = False, ring: bool = False):
    """One-token decode. x: (B, 1, d); cache holds S past positions.

    Returns (out (B, 1, d), updated cache). `pos` is the scalar index of
    this token. Cross attention reads the cache without update or RoPE.
    `ring=True` treats the cache as a rolling window buffer (SWA decode
    with S == window): the new KV overwrites slot pos % S and every slot
    is attendable — KV memory stays O(window) at any context length.
    """
    b = x.shape[0]
    hd, h, kvh, g = cfg.head_dim, cfg.n_heads, cfg.n_kv, cfg.q_per_kv
    cd = cfg.cdtype
    s = cache.k.shape[1]

    q = dense(params["wq"], x, cd).reshape(b, 1, kvh * g, hd)
    if not cross:
        q = rope(q, jnp.full((1, 1), pos), cfg.rope_theta)
        k_new = dense(params["wk"], x, cd).reshape(b, 1, kvh, hd)
        k_new = rope(k_new, jnp.full((1, 1), pos), cfg.rope_theta)
        v_new = dense(params["wv"], x, cd).reshape(b, 1, kvh, hd)
        slot = jax.lax.rem(pos, s) if ring else pos
        k_all = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
        cache = KVCache(k=k_all, v=v_all)

    q = q.reshape(b, kvh, g, hd)
    scale = hd ** -0.5 if cfg.attn_scale is None else cfg.attn_scale
    s_log = jnp.einsum("bkgd,bskd->bkgs", q, cache.k.astype(cd),
                       preferred_element_type=jnp.float32) * scale
    s_log = softcap(s_log, cfg.attn_softcap)
    k_pos = jnp.arange(s)
    if cross or ring:
        ok = jnp.ones((s,), bool)  # ring: caller guarantees a warm buffer
    else:
        ok = k_pos <= pos
        if window is not None:
            ok &= pos - k_pos < window
    s_log = jnp.where(ok[None, None, None], s_log, NEG)
    p = jax.nn.softmax(s_log, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cd), cache.v.astype(cd),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * hd).astype(cd)
    return dense(params["wo"], out, cd), cache
