"""seamless-m4t-medium backbone [arXiv:2308.11596; hf].

12L+12L enc-dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
Audio frontend is a stub per assignment: the encoder consumes precomputed
frame embeddings (B, S_src, d). LayerNorm + non-gated GeLU MLP.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_medium", family="encdec",
        n_layers=24, enc_layers=12, dec_layers=12,
        d_model=1024, n_heads=16, n_kv=16, d_ff=4096,
        vocab=256206, head_dim=64, norm_type="layernorm",
        mlp_act="gelu", mlp_gated=False,
    )
