"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B; unverified].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="llama3_2_1b", family="dense",
        n_layers=16, d_model=2048, n_heads=32, n_kv=8, d_ff=8192,
        vocab=128256, head_dim=64, rope_theta=500000.0,
    )
