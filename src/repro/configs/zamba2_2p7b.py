"""zamba2-2.7b [arXiv:2411.15242; hf].

54 Mamba2 layers d_model=2560, ssm_state=64, with a shared attention+MLP
block (32H kv=32, d_ff=10240) invoked every 6 SSM layers on
concat(x, embedding) — the zamba weight-sharing trick. Adaptation notes
in DESIGN.md (per-invocation LoRA deltas omitted).
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_2p7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240,
        vocab=32000, head_dim=80, ssm_state=64, ssm_head_dim=64,
        ssm_expand=2, shared_period=6, ssm_chunk=128,
    )
