"""Architecture registry: --arch <id> resolves here.

Each module in repro.configs defines make_config() with the exact
published numbers (sources cited per-file) plus input-shape metadata.
"""
from __future__ import annotations

import importlib
from typing import List, NamedTuple

from repro.models.common import ModelConfig

ARCHS: List[str] = [
    "chameleon_34b",
    "starcoder2_3b",
    "llama3_2_1b",
    "gemma2_2b",
    "qwen2_7b",
    "seamless_m4t_medium",
    "dbrx_132b",
    "mixtral_8x7b",
    "zamba2_2p7b",
    "xlstm_350m",
]

ALIASES = {
    "chameleon-34b": "chameleon_34b",
    "starcoder2-3b": "starcoder2_3b",
    "llama3.2-1b": "llama3_2_1b",
    "gemma2-2b": "gemma2_2b",
    "qwen2-7b": "qwen2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "dbrx-132b": "dbrx_132b",
    "mixtral-8x7b": "mixtral_8x7b",
    "zamba2-2.7b": "zamba2_2p7b",
    "xlstm-350m": "xlstm_350m",
}


class ShapeSpec(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: List[ShapeSpec] = [
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
]

# long_500k requires sub-quadratic state; pure full-attention archs skip
# (DESIGN.md §Arch-applicability / long_500k handling)
LONG_OK = {"zamba2_2p7b", "xlstm_350m", "mixtral_8x7b", "starcoder2_3b",
           "gemma2_2b"}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.make_config()


def shape_specs(arch: str) -> List[ShapeSpec]:
    """The shape cells defined for this arch (40 total across the pool)."""
    arch = ALIASES.get(arch, arch)
    out = []
    for sp in SHAPES:
        if sp.name == "long_500k" and arch not in LONG_OK:
            continue
        out.append(sp)
    return out


def all_cells():
    for arch in ARCHS:
        for sp in SHAPES:
            skip = sp.name == "long_500k" and arch not in LONG_OK
            yield arch, sp, skip
