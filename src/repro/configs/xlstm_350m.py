"""xlstm-350m [arXiv:2405.04517; unverified].

24 xLSTM blocks d_model=1024 4H vocab=50304, d_ff=0 (no separate FFN:
mLSTM blocks carry an internal 2x up-projection). One sLSTM block every
4 blocks (mLSTM:sLSTM = 3:1).
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm_350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv=4, d_ff=0,
        vocab=50304, head_dim=256, slstm_every=4, mlstm_proj_factor=2.0,
        ssm_chunk=128,
    )
