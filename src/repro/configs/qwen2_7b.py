"""qwen2-7b [arXiv:2407.10671; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. QKV bias.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv=4, d_ff=18944,
        vocab=152064, head_dim=128, rope_theta=1000000.0, qkv_bias=True,
    )
