"""Architecture configs (one module per assigned arch)."""
from repro.configs.registry import (ALIASES, ARCHS, SHAPES, LONG_OK,
                                    ShapeSpec, all_cells, get_config,
                                    shape_specs)
