"""chameleon-34b — early-fusion VLM backbone [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. Image modality is
VQ tokens in the shared vocabulary, so the backbone is a dense decoder-only
LM; the VQ tokenizer frontend is a stub per assignment (input_specs feeds
token ids). Simplification noted in DESIGN.md: qk-norm omitted.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon_34b", family="dense",
        n_layers=48, d_model=8192, n_heads=64, n_kv=8, d_ff=22016,
        vocab=65536, head_dim=128, rope_theta=10000.0,
        outer_scan=8,  # sqrt-remat: 48 groups -> 8 outer x 6 inner
    )
