"""mixtral-8x7b [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8 experts
top-2, sliding-window attention 4096.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral_8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
        vocab=32000, head_dim=128, rope_theta=1000000.0,
        n_experts=8, top_k=2, window=4096,
    )
