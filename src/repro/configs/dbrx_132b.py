"""dbrx-132b [hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
fine-grained MoE: 16 experts, top-4.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx_132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv=8, d_ff=10752,
        vocab=100352, head_dim=128, rope_theta=500000.0,
        n_experts=16, top_k=4,
        outer_scan=5,  # sqrt-remat: 40 groups -> 5 outer x 8 inner
    )
