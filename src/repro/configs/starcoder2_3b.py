"""starcoder2-3b [arXiv:2402.19173; hf].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152. RoPE, sliding
window 4096, LayerNorm, classic (non-gated) GeLU MLP.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv=2, d_ff=12288,
        vocab=49152, head_dim=128, rope_theta=999999.0,
        window=4096, norm_type="layernorm", mlp_act="gelu",
        mlp_gated=False, qkv_bias=True,
    )
