"""gemma2-2b [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim=256.
Alternating local(4096)/global attention, attn softcap 50, final softcap
30, sandwich norms, GeGLU.
"""
from repro.models.common import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2_2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv=4, d_ff=9216,
        vocab=256000, head_dim=256, rope_theta=10000.0,
        attn_softcap=50.0, final_softcap=30.0,
        window=4096, local_global_period=2, mlp_act="gelu",
        attn_scale=256 ** -0.5,
    )
