"""TEDA core: the paper's contribution as composable JAX modules."""
from repro.core.teda import (TedaOutput, TedaState, teda_init, teda_step,
                             teda_stream, teda_threshold)
from repro.core.scan import teda_scan, linear_recurrence_scan, welford_combine
from repro.core.clouds import (CloudState, clouds_init, clouds_run,
                               clouds_step)
from repro.core.guard import (GuardConfig, GuardState, GuardVerdict,
                              StragglerDetector, apply_guard, guard_init,
                              guard_step)

__all__ = [
    "TedaOutput", "TedaState", "teda_init", "teda_step", "teda_stream",
    "teda_threshold", "teda_scan", "linear_recurrence_scan",
    "welford_combine", "GuardConfig", "GuardState", "GuardVerdict",
    "StragglerDetector", "apply_guard", "guard_init", "guard_step",
    "CloudState", "clouds_init", "clouds_run", "clouds_step",
]
