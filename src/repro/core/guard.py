"""TEDAGuard — the paper's detector as a production training-loop feature.

Wraps any train step with streaming anomaly detection over training
telemetry (loss, global grad norm, per-group grad norms). An outlier
verdict (eq (6)) masks the optimizer update for that step (the gradients
are dropped, the model never sees the bad batch) — the loss-spike /
corrupt-batch / flipped-bit defense used in production LLM training, but
assumption-free and O(1)-state per monitored channel, exactly as TEDA
promises.

Fully jittable: the guard state lives inside the train state and the skip
is a `jnp.where` mask, so it composes with pjit/shard_map and costs a few
hundred scalar flops per step.  The monitored channels are packed
`repro.engine` state (one slot per telemetry channel) advanced with the
engine's single-sample fast path — the same per-stream contract the
serving monitor and the chunked StreamEngine use.

Also provides a host-side `StragglerDetector` (TEDA over per-step wall
times across hosts) used by the launcher for straggler mitigation.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.teda import TedaOutput

if TYPE_CHECKING:  # type-only: repro.core.__init__ <-> engine.state cycle
    from repro.engine.state import EngineState


def _engine():
    """Lazy import of the engine functional core.

    `repro.core.__init__` imports this module while `repro.engine.state`
    may itself be mid-import of `repro.core.teda` (either package can be
    entered first); deferring to call time breaks the cycle.
    """
    from repro.engine import state
    return state

__all__ = ["GuardConfig", "GuardState", "GuardVerdict", "guard_init",
           "guard_step", "apply_guard", "StragglerDetector"]


class GuardConfig(NamedTuple):
    m: float = 3.0           # eq (6) threshold multiplier
    warmup_steps: int = 20   # never skip before statistics stabilize
    exclude_outliers: bool = True  # don't absorb outliers into (mu, var)
    channels: int = 2        # monitored telemetry channels


class GuardState(NamedTuple):
    teda: "EngineState"      # packed per-channel engine state
    skipped: jnp.ndarray     # () int32 — total skipped steps
    last_outlier: jnp.ndarray  # (channels,) bool


class GuardVerdict(NamedTuple):
    skip: jnp.ndarray        # () bool — whether the update was masked
    per_channel: TedaOutput  # raw TEDA verdicts per channel


def guard_init(cfg: GuardConfig) -> GuardState:
    return GuardState(
        teda=_engine().engine_init(cfg.channels),
        skipped=jnp.zeros((), jnp.int32),
        last_outlier=jnp.zeros((cfg.channels,), bool),
    )


def guard_step(state: GuardState, metrics: jnp.ndarray, cfg: GuardConfig
               ) -> Tuple[GuardState, GuardVerdict]:
    """Score one step's telemetry vector metrics (channels,).

    Non-finite telemetry (NaN/inf loss or grad norm) is always an outlier.
    With `exclude_outliers`, flagged samples do not contaminate the TEDA
    statistics (the state update is rolled back), so a run of spikes stays
    detectable — this extends the paper (which always absorbs samples) and
    is ablated in benchmarks/bench_detection.py.
    """
    eng = _engine()
    finite = jnp.isfinite(metrics)
    clean = jnp.where(finite, metrics, state.teda.mean)
    new_teda, out = eng.engine_step(state.teda, clean, cfg.m)

    in_warmup = state.teda.k[0] < cfg.warmup_steps
    outlier = jnp.logical_or(out.outlier, ~finite)
    trip = jnp.logical_and(jnp.any(outlier), ~in_warmup)

    if cfg.exclude_outliers:
        keep = jnp.logical_or(~outlier, in_warmup)
        new_teda = eng.EngineState(
            k=jnp.where(keep, new_teda.k, state.teda.k),
            mean=jnp.where(keep, new_teda.mean, state.teda.mean),
            var=jnp.where(keep, new_teda.var, state.teda.var),
            active=new_teda.active,
        )

    new_state = GuardState(
        teda=new_teda,
        skipped=state.skipped + trip.astype(jnp.int32),
        last_outlier=outlier,
    )
    return new_state, GuardVerdict(skip=trip, per_channel=out)


def apply_guard(skip: jnp.ndarray, new_tree, old_tree):
    """Mask a pytree update: where skip, keep old leaves (grad dropped)."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(skip, o, n), new_tree, old_tree)


class StragglerDetector:
    """Host-side TEDA over per-step wall-times (straggler mitigation).

    The launcher feeds it one duration per step (or per-host durations in
    multi-controller deployments); `check()` returns True when the latest
    step is eccentric per eq (6) — the signal used to trigger host
    replacement / checkpoint handoff at fleet scale.
    """

    def __init__(self, m: float = 3.0, warmup: int = 10):
        self.m = float(m)
        self.warmup = int(warmup)
        self.k = 0
        self.mean = 0.0
        self.var = 0.0
        self.trips = 0
        self._t0: Optional[float] = None

    def tick(self) -> None:
        self._t0 = time.perf_counter()

    def tock(self) -> bool:
        assert self._t0 is not None, "tick() before tock()"
        return self.check(time.perf_counter() - self._t0)

    def check(self, duration_s: float) -> bool:
        self.k += 1
        k = float(self.k)
        if self.k == 1:
            self.mean, self.var = duration_s, 0.0
            return False
        self.mean = (k - 1.0) / k * self.mean + duration_s / k
        d2 = (duration_s - self.mean) ** 2
        self.var = (k - 1.0) / k * self.var + d2 / k
        if self.var <= 0.0 or self.k <= self.warmup:
            return False
        ecc = 1.0 / k + d2 / (k * self.var)
        trip = ecc / 2.0 > (self.m ** 2 + 1.0) / (2.0 * k)
        self.trips += int(trip)
        return bool(trip)
