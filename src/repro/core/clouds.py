"""TEDA data clouds — the evolving classifier built on the paper's core.

The TEDA papers the reproduction builds on ([4] Costa et al. "Unsupervised
classification of data streams based on typicality and eccentricity data
analytics", [15] TEDAClass) extend the detector into an autonomous
classifier: samples are grouped into *data clouds* (granular structures
with no predefined shape), each cloud carrying the same O(1) recursive
state (k, mu, var) as a single TEDA stream. Per sample:

  * compute the sample's normalized eccentricity w.r.t. every cloud
    (eq (5) using that cloud's statistics, sample tentatively included);
  * join every cloud where the sample is typical (zeta <= (m^2+1)/(2k),
    the complement of the paper's outlier rule) — soft labeling;
  * if eccentric to all clouds, found a new cloud at the sample.

Fixed-capacity, fully jittable (clouds live in padded arrays with an
active mask; `lax` control flow only), so it composes with pjit and can
run inside the serving/training loops like the plain guard. This is a
faithful-but-batched implementation: clouds update sequentially per
sample via lax.scan, exactly the online semantics of [4].
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CloudState", "clouds_init", "clouds_step", "clouds_run"]


class CloudState(NamedTuple):
    k: jnp.ndarray      # (C,) samples absorbed per cloud (0 = inactive)
    mean: jnp.ndarray   # (C, N)
    var: jnp.ndarray    # (C,)
    n_active: jnp.ndarray  # () int32


def clouds_init(capacity: int, n_features: int) -> CloudState:
    return CloudState(
        k=jnp.zeros((capacity,), jnp.float32),
        mean=jnp.zeros((capacity, n_features), jnp.float32),
        var=jnp.zeros((capacity,), jnp.float32),
        n_active=jnp.zeros((), jnp.int32),
    )


def _tentative(state: CloudState, x: jnp.ndarray):
    """Eq (2)/(3)/(1)/(5) with x tentatively added to every cloud."""
    k1 = state.k + 1.0
    mean1 = (state.k[:, None] * state.mean + x[None]) / k1[:, None]
    d2 = jnp.sum((x[None] - mean1) ** 2, axis=-1)
    var1 = (k1 - 1.0) / k1 * state.var + d2 / k1
    safe = var1 > 1e-12
    ecc = 1.0 / k1 + jnp.where(safe, d2 / (k1 * jnp.where(safe, var1, 1.0)),
                               0.0)
    zeta = ecc / 2.0
    return k1, mean1, var1, zeta


def clouds_step(state: CloudState, x: jnp.ndarray, m: float = 3.0
                ) -> Tuple[CloudState, jnp.ndarray]:
    """Absorb one sample x (N,). Returns (state, membership (C,) bool).

    A cloud accepts the sample when it is NOT eccentric there (paper's
    eq (6) complement). New clouds spawn in the first inactive slot; at
    capacity the sample joins its least-eccentric cloud (graceful
    saturation, as TEDAClassBDp does for bounded memory).
    """
    cap = state.k.shape[0]
    active = state.k > 0.0
    k1, mean1, var1, zeta = _tentative(state, x)
    thr = (m * m + 1.0) / (2.0 * k1)
    # pure eq (5)/(6)-complement membership. Note the detectability
    # bound (DESIGN.md §7): a cloud younger than m^2 samples cannot
    # reject, so the classifier targets the TEDAClass streaming regime —
    # concept drift with each regime lasting > m^2 samples (as in [4]'s
    # industrial-fault experiments). Rapidly interleaved regimes would
    # need the sigma-gap extension of [6].
    join = jnp.logical_and(active, zeta <= thr)

    any_join = jnp.any(join)
    slot = jnp.argmin(active)  # first inactive slot
    has_room = ~active[slot]
    fallback = jnp.argmin(jnp.where(active, zeta, jnp.inf))  # saturation

    spawn = jnp.logical_and(~any_join, has_room)
    adopt = jnp.logical_and(~any_join, ~has_room)
    join = jnp.logical_or(
        join, jnp.logical_and(adopt,
                              jnp.arange(cap) == fallback))

    # update joined clouds recursively; spawn fresh cloud at x
    new_k = jnp.where(join, k1, state.k)
    new_mean = jnp.where(join[:, None], mean1, state.mean)
    new_var = jnp.where(join, var1, state.var)
    is_slot = jnp.arange(cap) == slot
    new_k = jnp.where(jnp.logical_and(spawn, is_slot), 1.0, new_k)
    new_mean = jnp.where(jnp.logical_and(spawn, is_slot)[:, None],
                         x[None], new_mean)
    new_var = jnp.where(jnp.logical_and(spawn, is_slot), 0.0, new_var)

    membership = jnp.logical_or(join, jnp.logical_and(spawn, is_slot))
    n_active = jnp.sum((new_k > 0).astype(jnp.int32))
    return CloudState(k=new_k, mean=new_mean, var=new_var,
                      n_active=n_active), membership


def clouds_run(x: jnp.ndarray, capacity: int = 16, m: float = 3.0
               ) -> Tuple[CloudState, jnp.ndarray]:
    """Stream x (T, N) through the evolving classifier via lax.scan.

    Returns (final state, memberships (T, C) bool — soft labels)."""
    state = clouds_init(capacity, x.shape[-1])

    def body(s, xi):
        return clouds_step(s, xi, m)

    return jax.lax.scan(body, state, x.astype(jnp.float32))
