"""Multi-device TEDA: one logical stream scanned across a mesh axis.

This is the multi-pod generalization of TEDAClassBDp (block-parallel TEDA,
ref [15] of the paper): the time axis is sharded over a mesh axis, each
device runs the parallel scan of `core/scan.py` on its local block, and
tiny O(N) carries are exchanged with `all_gather` so that every device
fixes its block up to the *global* prefix statistics. Three collectives of
size O(devices * N) total — independent of T.

Usable standalone (monitor streams recorded across thousands of steps,
re-scored in one sharded pass) and as the scalable data-screening stage of
the input pipeline.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.teda import TedaOutput, TedaState, teda_threshold
from repro.sharding.rules import shard_map_compat

__all__ = ["distributed_teda", "make_distributed_teda"]


def _local_shard_scan(x: jnp.ndarray, m, axis_name: str
                      ) -> Tuple[TedaState, TedaOutput]:
    """Body run per-device under shard_map. x: (T_local, N)."""
    t_local = x.shape[0]
    idx = jax.lax.axis_index(axis_name)
    x = x.astype(jnp.float32)

    # ---- pass 1: exclusive prefix of running sums -----------------------
    local_sum = jnp.sum(x, axis=0)  # (N,)
    all_sums = jax.lax.all_gather(local_sum, axis_name)  # (D, N)
    # static device count from the gathered shape (jax.lax.axis_size is
    # not available on older JAX)
    ndev = all_sums.shape[0]
    prefix_mask = (jnp.arange(ndev) < idx).astype(x.dtype)  # exclusive
    s_prev = jnp.einsum("d,dn->n", prefix_mask, all_sums)
    k_prev = idx * t_local  # static per-device sample offset

    # ---- local mean / distance terms with global k -----------------------
    k = (k_prev + jnp.arange(1, t_local + 1)).astype(x.dtype)  # (T_local,)
    s = s_prev[None] + jnp.cumsum(x, axis=0)
    mean = s / k[:, None]
    d2 = jnp.sum((x - mean) ** 2, axis=-1)
    first_row = k <= 1.0
    d2 = jnp.where(first_row, 0.0, d2)

    # ---- pass 2: exclusive prefix of the variance affine maps -----------
    # var_k = a_k var_{k-1} + b_k. Across a block the composed map is
    # (A, B) with A = prod a = k_first-1 ... telescoping: A = k_prev/k_last
    # (0 when k_prev == 0), and B = the block-local scanned b final value.
    a = jnp.where(first_row, 0.0, (k - 1.0) / k)
    b = jnp.where(first_row, 0.0, d2 / k)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    a_scan, b_scan = jax.lax.associative_scan(combine, (a, b), axis=0)
    block_carry = (a_scan[-1], b_scan[-1])  # this block's composed map
    all_a = jax.lax.all_gather(block_carry[0], axis_name)  # (D,)
    all_b = jax.lax.all_gather(block_carry[1], axis_name)  # (D,)

    # Exclusive associative combine over device blocks (D is tiny: <= 512;
    # a sequential fori over gathered scalars costs nothing).
    def body(i, carry):
        av, bv = carry
        take = i < idx
        a2 = jnp.where(take, all_a[i], 1.0)
        b2 = jnp.where(take, all_b[i], 0.0)
        return av * a2, bv * a2 + b2

    a_prev, b_prev = jax.lax.fori_loop(0, ndev, body, (jnp.float32(1.0),
                                                       jnp.float32(0.0)))
    var_in = b_prev  # global var_0 = 0 (fresh stream)
    del a_prev

    var = a_scan * var_in + b_scan
    var = jnp.where(first_row, 0.0, var)

    # ---- replicated global final state -----------------------------------
    # Every device reduces the same gathered carries, so the result is
    # bitwise-identical everywhere (legitimately replicated).
    k_total = jnp.float32(ndev * t_local)
    mean_total = jnp.sum(all_sums, axis=0) / k_total

    def body_all(i, carry):
        av, bv = carry
        return av * all_a[i], bv * all_a[i] + all_b[i]

    _, var_total = jax.lax.fori_loop(0, ndev, body_all,
                                     (jnp.float32(1.0), jnp.float32(0.0)))

    # ---- verdicts ---------------------------------------------------------
    safe = var > 0.0
    ecc = 1.0 / k + jnp.where(safe, d2 / (k * jnp.where(safe, var, 1.0)), 0.0)
    zeta = ecc / 2.0
    thr = teda_threshold(k, m)
    outlier = jnp.logical_and(zeta > thr, k >= 2.0)

    out = TedaOutput(ecc=ecc, typ=1.0 - ecc, zeta=zeta, threshold=thr,
                     outlier=outlier, k=k)
    final = TedaState(k=k_total, mean=mean_total, var=var_total)
    return final, out


def make_distributed_teda(mesh: Mesh, axis_name: str = "data"):
    """Build a jitted sharded-TEDA callable for `mesh`.

    Returns f(x, m) with x (T, N) sharded (axis_name, None); outputs are
    per-sample verdicts with the same T sharding and a replicated final
    state (every device ends with the full-stream statistics).
    """
    body = functools.partial(_local_shard_scan, axis_name=axis_name)
    mapped = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(axis_name, None), P()),
        out_specs=(TedaState(k=P(), mean=P(), var=P()),
                   TedaOutput(*([P(axis_name)] * 6))),
        check=False,
    )
    x_sh = NamedSharding(mesh, P(axis_name, None))
    m_sh = NamedSharding(mesh, P())
    return jax.jit(mapped, in_shardings=(x_sh, m_sh))


def distributed_teda(x: jnp.ndarray, m, mesh: Mesh, axis_name: str = "data"
                     ) -> Tuple[TedaState, TedaOutput]:
    """One-shot convenience wrapper around make_distributed_teda."""
    fn = make_distributed_teda(mesh, axis_name)
    return fn(x, jnp.asarray(m, jnp.float32))
