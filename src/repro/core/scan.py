"""Parallel (associative-scan) formulation of TEDA — the TPU-native form.

The paper's FPGA pipeline retires one sample per cycle because eqs (2)-(3)
look sequential. They are not:

  * eq (2) is a prefix sum:  mu_k = S_k / k,  S_k = sum_{i<=k} x_i.
  * eq (3) is a first-order linear recurrence
        var_k = a_k * var_{k-1} + b_k,
        a_k = (k-1)/k,   b_k = ||x_k - mu_k||^2 / k,
    whose coefficients depend only on prefix sums. The recurrence composes
    associatively under  (a1,b1) o (a2,b2) = (a1*a2, b1*a2 + b2).

So the entire stream is two log-depth scans + elementwise work. This file
is the pure-jnp implementation (used directly, and as the building block of
`core/distributed.py`); `kernels/teda_scan.py` is the chunked Pallas version.

Also provides exact Welford moment combination (`welford_combine`) used for
block-parallel moment merging in the distributed runtime.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.teda import TedaOutput, TedaState, teda_init, teda_threshold

__all__ = [
    "teda_scan",
    "linear_recurrence_scan",
    "welford_combine",
    "WelfordState",
]


def linear_recurrence_scan(a: jnp.ndarray, b: jnp.ndarray, axis: int = 0
                           ) -> jnp.ndarray:
    """All-prefix solutions of y_k = a_k * y_{k-1} + b_k with y_0 = 0.

    Uses jax.lax.associative_scan with the affine-composition monoid.
    Returns y with the same shape as b. O(T log T) work, O(log T) depth.
    """

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    _, y = jax.lax.associative_scan(combine, (a, b), axis=axis)
    return y


def teda_scan(x: jnp.ndarray, m: float | jnp.ndarray = 3.0,
              state: Optional[TedaState] = None,
              valid_lens=None) -> Tuple[TedaState, TedaOutput]:
    """Parallel TEDA over x (T, ..., N): identical results to teda_stream.

    Steady-state identity with `core.teda.teda_stream` is exact in real
    arithmetic; in float32 the two differ only by reassociation rounding
    (tested to ~1e-5 rtol in tests/test_teda.py).

    `valid_lens` (scalar or an array matching the batch shape of
    `state.k`) restricts each stream to its leading vlen rows: the
    counter plateaus there, invalid rows contribute nothing to the sum
    and compose as identity variance maps, so the final state equals a
    run of each stream's own prefix — the kernels' ragged contract
    (`kernels/ops.py`) on the portability backend.  `None` keeps the
    exact uniform computation (no masking applied).
    """
    T = x.shape[0]
    if state is None:
        state = teda_init(x.shape[1:-1], x.shape[-1], jnp.float32)
    x = x.astype(state.mean.dtype)

    k0 = state.k  # (...,)
    # Global iteration index of each row: k0 + 1 .. k0 + T.
    t = jnp.arange(1, T + 1, dtype=x.dtype)
    rows = t.reshape((T,) + (1,) * k0.ndim)
    if valid_lens is None:
        valid = None
        k = k0[None, ...] + rows  # (T, ...)
        kd = k  # always >= 1
    else:
        # clamp to [0, T] — same contract as the kernel wrappers
        # (`kernels/ops.py::_vlen_vec`), so all backends agree on
        # out-of-range input from traced callers
        vlen = jnp.clip(jnp.asarray(valid_lens, x.dtype), 0.0, T)
        valid = rows <= vlen[None]  # this row advances this stream
        # the counter plateaus at each stream's own valid length
        k = k0[None, ...] + jnp.minimum(rows, vlen[None])
        kd = jnp.maximum(k, 1.0)  # k=0 (vlen=0 fresh stream) div guard

    # ---- eq (2): prefix sum --------------------------------------------
    s0 = state.mean * k0[..., None]  # carried running sum
    xs = x if valid is None else jnp.where(valid[..., None], x, 0.0)
    s = s0[None] + jnp.cumsum(xs, axis=0)  # (T, ..., N)
    mean = s / kd[..., None]

    # ---- eq (3): affine recurrence --------------------------------------
    d2 = jnp.sum((x - mean) ** 2, axis=-1)  # (T, ...)
    a = (k - 1.0) / kd
    b = d2 / kd
    if valid is not None:
        # invalid rows are identity maps: the recurrence freezes there
        a = jnp.where(valid, a, 1.0)
        b = jnp.where(valid, b, 0.0)
        d2 = jnp.where(valid, d2, 0.0)
    # Fold the carried variance into the first b: var_in enters through
    # y_1 = a_1 * var0 + b_1; associative_scan solves for y_0 = 0, so add
    # the a-prefix-product * var0 term analytically: prod_{i<=k} a_i =
    # k0 / k (telescoping over the valid rows, so the plateaued k is the
    # right denominator), valid for k0 >= 1; for k0 == 0 it is 0 except
    # the first-sample branch handled below.
    var = linear_recurrence_scan(a, b, axis=0) + state.var[None] * (
        k0[None] / kd)

    # ---- first-sample branch (Algorithm 1 lines 3..5) -------------------
    fresh = (k0 == 0.0)
    first_row = k <= 1.0  # true while a fresh stream has absorbed <= 1 row
    # At k == 1: mu <- x_1 (cumsum already gives that), var <- 0, and the
    # distance term is zero by definition.
    var = jnp.where(first_row, 0.0, var)
    d2 = jnp.where(first_row, 0.0, d2)
    del fresh

    # ---- eqs (1), (4), (5), (6) -----------------------------------------
    safe = var > 0.0
    ecc = 1.0 / kd + jnp.where(safe, d2 / (kd * jnp.where(safe, var, 1.0)),
                               0.0)
    zeta = ecc / 2.0
    thr = teda_threshold(k, m)
    outlier = jnp.logical_and(zeta > thr, k >= 2.0)
    if valid is not None:
        outlier = jnp.logical_and(outlier, valid)

    out = TedaOutput(ecc=ecc, typ=1.0 - ecc, zeta=zeta, threshold=thr,
                     outlier=outlier, k=k)
    final = TedaState(k=k[-1], mean=mean[-1], var=var[-1])
    return final, out


class WelfordState(NamedTuple):
    """Exact first/second moments of a block: count, mean, M2 (= n*var)."""

    count: jnp.ndarray  # (...,)
    mean: jnp.ndarray  # (..., N)
    m2: jnp.ndarray  # (...,)


def welford_of_block(x: jnp.ndarray) -> WelfordState:
    """Exact moments of a block x (T, ..., N) (Chan et al. pairwise form)."""
    n = jnp.asarray(x.shape[0], x.dtype)
    mean = jnp.mean(x, axis=0)
    m2 = jnp.sum(jnp.sum((x - mean[None]) ** 2, axis=-1), axis=0)
    return WelfordState(count=jnp.broadcast_to(n, x.shape[1:-1]), mean=mean,
                        m2=m2)


def welford_combine(a: WelfordState, b: WelfordState) -> WelfordState:
    """Associative merge of two disjoint blocks' exact moments."""
    n = a.count + b.count
    safe_n = jnp.where(n > 0, n, 1.0)
    delta = b.mean - a.mean
    mean = a.mean + delta * (b.count / safe_n)[..., None]
    m2 = a.m2 + b.m2 + jnp.sum(delta ** 2, axis=-1) * a.count * b.count / safe_n
    return WelfordState(count=n, mean=mean, m2=m2)
