"""Paper-faithful TEDA (Typicality and Eccentricity Data Analytics).

Implements Algorithm 1 of da Silva et al., "Hardware Architecture Proposal
for TEDA algorithm to Data Streaming Anomaly Detection", verbatim:

  eq (2)  mu_k    = (k-1)/k * mu_{k-1} + x_k / k
  eq (3)  var_k   = (k-1)/k * var_{k-1} + ||x_k - mu_k||^2 / k
  eq (1)  ecc_k   = 1/k + ||x_k - mu_k||^2 / (k * var_k)
  eq (4)  typ_k   = 1 - ecc_k
  eq (5)  zeta_k  = ecc_k / 2
  eq (6)  outlier = zeta_k > (m^2 + 1) / (2k)

State is O(1) per stream: (k, mu, var). Streams are multivariate with
feature dimension N on the trailing axis; arbitrary leading batch dims are
supported (each leading index is an independent stream).

This module is the *paper-faithful baseline* (sequential recurrence,
`lax.scan` = the FPGA pipeline analog). The beyond-paper parallel forms
live in `core/scan.py` and `kernels/teda_scan.py`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "TedaState",
    "TedaOutput",
    "teda_init",
    "teda_step",
    "teda_stream",
    "teda_threshold",
]


class TedaState(NamedTuple):
    """O(1) recursive TEDA state for one (batch of) stream(s).

    k:    (...,)   float32 — number of samples absorbed so far.
    mean: (..., N) float32 — recursive mean, eq (2).
    var:  (...,)   float32 — recursive variance, eq (3).
    """

    k: jnp.ndarray
    mean: jnp.ndarray
    var: jnp.ndarray


class TedaOutput(NamedTuple):
    """Per-sample verdict, one entry per eq of the paper."""

    ecc: jnp.ndarray  # eq (1) eccentricity xi_k
    typ: jnp.ndarray  # eq (4) typicality tau_k
    zeta: jnp.ndarray  # eq (5) normalized eccentricity
    threshold: jnp.ndarray  # eq (6) RHS, (m^2+1)/(2k)
    outlier: jnp.ndarray  # eq (6) verdict (bool); False while k < 2
    k: jnp.ndarray  # iteration index of this verdict


def teda_init(batch_shape: Tuple[int, ...] = (), n_features: int = 1,
              dtype=jnp.float32) -> TedaState:
    """Fresh state: k=0, mu=0, var=0 (Algorithm 1 initial conditions)."""
    return TedaState(
        k=jnp.zeros(batch_shape, dtype),
        mean=jnp.zeros(batch_shape + (n_features,), dtype),
        var=jnp.zeros(batch_shape, dtype),
    )


def teda_threshold(k: jnp.ndarray, m: float | jnp.ndarray) -> jnp.ndarray:
    """RHS of eq (6): (m^2 + 1) / (2k)."""
    return (jnp.asarray(m, jnp.float32) ** 2 + 1.0) / (2.0 * k)


def teda_step(state: TedaState, x: jnp.ndarray,
              m: float | jnp.ndarray = 3.0) -> Tuple[TedaState, TedaOutput]:
    """One iteration of Algorithm 1 (lines 3..15) for sample x (..., N).

    Matches the paper's MEAN / VARIANCE / ECCENTRICITY / OUTLIER modules.
    The k==1 branch (lines 3..5) sets mu <- x, var <- 0 and emits a
    non-outlier verdict (eq (5) is defined for k >= 2).
    """
    x = x.astype(state.mean.dtype)
    k = state.k + 1.0  # discretization instant of this sample
    first = k <= 1.0

    # --- MEAN module, eq (2); lines 4 / 7 -------------------------------
    mean = jnp.where(first[..., None],
                     x,
                     (k[..., None] - 1.0) / k[..., None] * state.mean
                     + x / k[..., None])

    # --- VARIANCE module, eq (3); lines 5 / 8 ---------------------------
    d2 = jnp.sum((x - mean) ** 2, axis=-1)  # ||x_k - mu_k||^2
    var = jnp.where(first, 0.0, (k - 1.0) / k * state.var + d2 / k)

    # --- ECCENTRICITY module, eq (1); line 9 ----------------------------
    # Guard var > 0 as required by eq (1): with zero variance every sample
    # sits on the mean, so the distance term vanishes.
    safe = var > 0.0
    ecc = 1.0 / k + jnp.where(safe, d2 / (k * jnp.where(safe, var, 1.0)), 0.0)

    # --- OUTLIER module, eqs (5)-(6); lines 10..14 ----------------------
    zeta = ecc / 2.0
    thr = teda_threshold(k, m)
    outlier = jnp.logical_and(zeta > thr, k >= 2.0)

    out = TedaOutput(ecc=ecc, typ=1.0 - ecc, zeta=zeta, threshold=thr,
                     outlier=outlier, k=k)
    return TedaState(k=k, mean=mean, var=var), out


def teda_stream(x: jnp.ndarray, m: float | jnp.ndarray = 3.0,
                state: Optional[TedaState] = None,
                ) -> Tuple[TedaState, TedaOutput]:
    """Run Algorithm 1 over a stream x of shape (T, ..., N) via lax.scan.

    This is the sequential, paper-faithful execution: one sample retires
    per scan step, exactly like one sample per critical-path cycle on the
    FPGA. Returns the final state and per-sample outputs stacked on axis 0.
    """
    if state is None:
        state = teda_init(x.shape[1:-1], x.shape[-1], jnp.float32)

    def body(s, xk):
        return teda_step(s, xk, m)

    return jax.lax.scan(body, state, x)


def teda_numpy_loop(x, m: float = 3.0):
    """Plain-Python reference loop (the paper's 'software platform').

    Used by benchmarks/bench_platforms.py as the Table-5 software baseline
    and by tests as an independent oracle. x: numpy (T, N).
    """
    import numpy as np

    T, _ = x.shape
    mu = np.zeros(x.shape[1], np.float64)
    var = 0.0
    ecc = np.zeros(T, np.float64)
    zeta = np.zeros(T, np.float64)
    thr = np.zeros(T, np.float64)
    outlier = np.zeros(T, bool)
    for i in range(T):
        k = i + 1.0
        xk = x[i].astype(np.float64)
        if i == 0:
            mu = xk.copy()
            var = 0.0
        else:
            mu = (k - 1.0) / k * mu + xk / k
            d2 = float(np.sum((xk - mu) ** 2))
            var = (k - 1.0) / k * var + d2 / k
        d2 = float(np.sum((xk - mu) ** 2))
        ecc[i] = 1.0 / k + (d2 / (k * var) if var > 0.0 else 0.0)
        zeta[i] = ecc[i] / 2.0
        thr[i] = (m * m + 1.0) / (2.0 * k)
        outlier[i] = (zeta[i] > thr[i]) and k >= 2
    return {"ecc": ecc, "zeta": zeta, "threshold": thr, "outlier": outlier,
            "mean": mu, "var": var}
