"""Algorithm 1 re-expressed in Q-format integer arithmetic.

Mirrors the paper's four pipeline modules on the quantized datapath:

  MEAN         mu_k  = (k-1)/k * mu_{k-1} + x_k / k          eq (2)
  VARIANCE     var_k = (k-1)/k * var_{k-1} + ||x-mu||^2 / k  eq (3)
  ECCENTRICITY ecc_k = 1/k + (d2 / var) / k                  eq (1)
  OUTLIER      ecc/2 > (m^2+1) / (2k)                        eqs (5)(6)

All quantities are int32 Q-values of one `QFormat`; the sample counter k
stays a plain integer (the FPGA's counter register).  Division by k uses
the integer-divisor configuration `div_qi`; the two Q/Q quotients
((k-1)/k and d2/var) use the shift-subtract divider `div_qq`.  `zeta` is
a 1-bit arithmetic right shift — free wiring in hardware.

Two drivers:
  * `teda_q_stream`    — multivariate (T, ..., N) streams, returns the
    same `TedaState`/`TedaOutput` contract as `core/teda.py`, with Q
    int32 payloads (dequantize with `QFormat.dequantize`).
  * `teda_q_scan_chan` — (T, C) univariate-channel layout, a `lax.scan`
    over exactly the `_q_step_u` the Pallas kernel runs, making the
    kernel bit-exact with this function by construction.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.teda import TedaOutput, TedaState
from repro.fixedpoint.qformat import (QFormat, div_qi, div_qq, sat,
                                      sat_add, sat_mul, sat_sub)

__all__ = ["teda_q_init", "teda_q_step", "teda_q_stream",
           "teda_q_scan_chan", "msq1_const"]

_I32 = jnp.int32


def msq1_const(fmt: QFormat, m):
    """The OUTLIER module's ROM constant: quantized m^2 + 1.

    Saturates when m^2+1 exceeds the integer range of the format (e.g.
    m=3 needs 4 integer bits) — faithfully degrading detection, which is
    exactly what the word-length sweep measures.  Python scalars and
    concrete (numpy) arrays are quantized exactly on the host in double
    precision — per-slot m vectors produce the same msq1 bits as the
    scalar path.  Integer input is taken as an already-quantized Q
    constant (the engine's host-exact handoff, mirroring how the scan
    drivers take int32 x as pre-quantized).  Only arrays traced under
    jit fall back to the format's float32 quantizer.
    """
    if isinstance(m, (int, float)):
        return fmt.quantize_scalar(float(m) * float(m) + 1.0)
    if jnp.issubdtype(jnp.result_type(m), jnp.integer):
        return jnp.asarray(m, _I32)
    try:
        mv = np.asarray(m, np.float64)  # concrete: exact host quantize
    except Exception:  # traced under jit: float32 quantizer
        m = jnp.asarray(m, jnp.float32)
        return fmt.quantize(m * m + 1.0)
    q = np.clip(np.round((mv * mv + 1.0) * fmt.scale), fmt.qmin,
                fmt.qmax).astype(np.int32)
    return int(q) if q.ndim == 0 else jnp.asarray(q)


def teda_q_init(batch_shape: Tuple[int, ...] = (), n_features: int = 1
                ) -> TedaState:
    """Fresh Q-state: k=0, mu=0, var=0 (all int32)."""
    return TedaState(
        k=jnp.zeros(batch_shape, _I32),
        mean=jnp.zeros(batch_shape + (n_features,), _I32),
        var=jnp.zeros(batch_shape, _I32),
    )


def _q_counter_terms(fmt: QFormat, k, msq1):
    """The three dividers that depend only on the counter k:
    rk=(k-1)/k, inv_k=1/k, thr=(m^2+1)/(2k).

    Data-independent, so drivers precompute them vectorized over all T
    instants instead of re-running three 31..61-cycle bit-serial
    divisions inside every sequential step — bit-identical values (same
    function, same inputs), ~4x less divider work on the critical path
    (only the d2/var divide is data-dependent).
    """
    k = jnp.asarray(k, _I32)
    rk = div_qq(fmt, k - 1, k)
    inv_k = div_qi(fmt, jnp.broadcast_to(_I32(fmt.one), k.shape), k)
    thr = div_qi(fmt, jnp.broadcast_to(jnp.asarray(msq1, _I32), k.shape),
                 2 * k)
    return rk, inv_k, thr


def _q_mean_update(fmt: QFormat, first, rk, k, mean_prev, xq):
    """MEAN module, eq (2): (k-1)/k * mu + x/k with the k=1 override.

    `first`, `rk`, `k` must already be broadcast-ready against the data
    (the multivariate driver passes them with a trailing feature axis).
    """
    return jnp.where(first, xq,
                     sat_add(fmt, sat_mul(fmt, rk, mean_prev),
                             div_qi(fmt, xq, k)))


def _q_post_d2(fmt: QFormat, k, first, terms, d2, var_prev):
    """VARIANCE + ECCENTRICITY + OUTLIER modules from a reduced d2.

    Single implementation of eqs (3), (1), (5), (6) in Q arithmetic,
    shared by the univariate and multivariate steps — one fix location
    for guards/gates, preserving the bit-exactness story.  `terms` is
    the `_q_counter_terms` triple for this instant.
    Returns (var', ecc, zeta, thr, outlier).
    """
    rk, inv_k, thr = terms
    var_n = jnp.where(first, 0,
                      sat_add(fmt, sat_mul(fmt, rk, var_prev),
                              div_qi(fmt, d2, k)))

    # ECCENTRICITY: 1/k + (d2/var)/k, var>0 guard as in the float path
    safe = var_n > 0
    ratio = div_qq(fmt, d2, jnp.where(safe, var_n, 1))
    ecc = sat_add(fmt, inv_k, jnp.where(safe, div_qi(fmt, ratio, k), 0))

    # OUTLIER: zeta = ecc >> 1 (free in hardware), thr = (m^2+1)/(2k)
    zeta = ecc >> 1
    outlier = (zeta > thr) & (k >= 2)
    return var_n, ecc, zeta, thr, outlier


def _q_step_u(fmt: QFormat, k, mean, var, xq, msq1, terms=None):
    """One univariate Q-TEDA step on arrays of identical shape.

    k is the (already incremented) integer instant — scalar or array,
    broadcast against the data.  Single source of truth shared by the
    `lax.scan` driver and the Pallas kernel (bit-exactness guarantee).
    `terms` lets drivers pass precomputed `_q_counter_terms`.
    Returns (mean', var', ecc, zeta, thr, outlier).
    """
    k = jnp.asarray(k, _I32)
    first = k <= 1
    if terms is None:
        terms = _q_counter_terms(fmt, k, msq1)
    rk = terms[0]
    mean_n = _q_mean_update(fmt, first, rk, k, mean, xq)

    # VARIANCE: d2 = (x - mu_k)^2 via the widening multiplier
    d = sat_sub(fmt, xq, mean_n)
    d2 = sat_mul(fmt, d, d)
    var_n, ecc, zeta, thr, outlier = _q_post_d2(
        fmt, k, first, terms, d2, var)
    return mean_n, var_n, ecc, zeta, thr, outlier


def teda_q_step(fmt: QFormat, state: TedaState, xq: jnp.ndarray,
                msq1, terms=None) -> Tuple[TedaState, TedaOutput]:
    """One multivariate Q-TEDA iteration; xq int32 Q of shape (..., N).

    Feature reduction ||x - mu||^2 is a saturating adder tree over the
    per-feature squares (static N); everything after d2 is the shared
    `_q_post_d2` pipeline.  `terms` lets the stream driver pass
    precomputed `_q_counter_terms` for this instant.
    """
    k = state.k + 1
    first = k <= 1

    if terms is None:
        terms = _q_counter_terms(fmt, k, msq1)
    rk = terms[0]
    mean = _q_mean_update(fmt, first[..., None], rk[..., None],
                          k[..., None], state.mean, xq)

    d = sat_sub(fmt, xq, mean)
    n_features = xq.shape[-1]
    d2 = sat_mul(fmt, d[..., 0], d[..., 0])
    for j in range(1, n_features):
        d2 = sat_add(fmt, d2, sat_mul(fmt, d[..., j], d[..., j]))
    var, ecc, zeta, thr, outlier = _q_post_d2(
        fmt, k, first, terms, d2, state.var)

    one = sat(fmt, jnp.asarray(min(fmt.one, fmt.qmax), _I32))
    out = TedaOutput(ecc=ecc, typ=sat_sub(fmt, one, ecc), zeta=zeta,
                     threshold=thr, outlier=outlier, k=k)
    return TedaState(k=k, mean=mean, var=var), out


def teda_q_stream(x: jnp.ndarray, fmt: QFormat, m: float = 3.0,
                  state: Optional[TedaState] = None,
                  ) -> Tuple[TedaState, TedaOutput]:
    """Bit-accurate Q-TEDA over a stream x (T, ..., N) via lax.scan.

    Float input is quantized through the format's ADC front-end;
    pre-quantized int32 input is passed through untouched.  Outputs are
    Q int32 (dequantize for plots); `outlier` is bool.
    """
    fmt.validate()
    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        xq = fmt.quantize(x)
    else:
        xq = jnp.asarray(x, _I32)
    if state is None:
        state = teda_q_init(xq.shape[1:-1], xq.shape[-1])
    msq1 = msq1_const(fmt, m)

    # hoist the counter-only dividers out of the sequential scan,
    # vectorized over all T instants (bit-identical values)
    t_len = xq.shape[0]
    ks = (jnp.arange(1, t_len + 1, dtype=_I32)
          .reshape((t_len,) + (1,) * state.k.ndim) + state.k[None])
    terms = _q_counter_terms(fmt, ks, msq1)

    def body(s, inp):
        xk, rk, inv_k, thr = inp
        return teda_q_step(fmt, s, xk, msq1, terms=(rk, inv_k, thr))

    return jax.lax.scan(body, state, (xq,) + terms)


def teda_q_scan_chan(x: jnp.ndarray, fmt: QFormat, m: float = 3.0,
                     k0=0, mean0: Optional[jnp.ndarray] = None,
                     var0: Optional[jnp.ndarray] = None):
    """Q-TEDA over (T, C) — C independent univariate channels.

    Pure-JAX `lax.scan` over `_q_step_u`, the exact function the integer
    Pallas kernel executes per row: the kernel output must match this
    bit-for-bit.  `k0` may be a scalar or a per-channel (C,) vector —
    multi-tenant slots may sit at different stream positions.  Returns
    (final (k, mean, var), dict of (T, C) arrays).
    """
    fmt.validate()
    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        xq = fmt.quantize(x)
    else:
        xq = jnp.asarray(x, _I32)
    t_len, c = xq.shape
    mean0 = jnp.zeros((c,), _I32) if mean0 is None else mean0.astype(_I32)
    var0 = jnp.zeros((c,), _I32) if var0 is None else var0.astype(_I32)
    k0v = jnp.asarray(k0, _I32)
    if k0v.ndim == 0:
        k0v = jnp.broadcast_to(k0v, (c,))
    msq1 = msq1_const(fmt, m)

    def body(carry, inp):
        mean, var = carry
        kk, xr, rk, inv_k, thr_k = inp
        mean_n, var_n, ecc, zeta, thr, outl = _q_step_u(
            fmt, kk, mean, var, xr, msq1, terms=(rk, inv_k, thr_k))
        return (mean_n, var_n), (mean_n, var_n, ecc, zeta,
                                 jnp.broadcast_to(thr, xr.shape),
                                 jnp.broadcast_to(outl, xr.shape))

    ks = k0v[None, :] + jnp.arange(1, t_len + 1, dtype=_I32)[:, None]
    terms = _q_counter_terms(fmt, ks, msq1)
    (mean_f, var_f), (mean, var, ecc, zeta, thr, outl) = jax.lax.scan(
        body, (mean0, var0), (ks, xq) + terms)
    final = (k0v + t_len, mean_f, var_f)
    outs = {"mean": mean, "var": var, "ecc": ecc, "zeta": zeta,
            "threshold": thr, "outlier": outl}
    return final, outs
