"""Word-length sweep: the repo's analog of the paper's bit-accurate
simulation figures.

For each candidate `QFormat` the quantized datapath runs over a stream
and is compared against the float64 software oracle
(`core.teda.teda_numpy_loop`): max/mean eccentricity error and the
fraction of identical outlier verdicts.  This is exactly the
word-length-vs-detection-efficacy curve the hardware designer needs to
pick WL/FL for the FPGA (cf. Choudhary et al. 2017's runtime-efficacy
trade-off study).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.teda import teda_numpy_loop
from repro.fixedpoint.qformat import QFormat
from repro.fixedpoint.teda_q import teda_q_stream

__all__ = ["DEFAULT_FORMATS", "evaluate_format", "wordlength_sweep"]

# WL in {16, 24, 32} with the FL range a designer would actually sweep.
DEFAULT_FORMATS: List[QFormat] = [
    QFormat(16, 8), QFormat(16, 10), QFormat(16, 12),
    QFormat(24, 12), QFormat(24, 16), QFormat(24, 18),
    QFormat(32, 16), QFormat(32, 20), QFormat(32, 24),
]


def evaluate_format(x: np.ndarray, fmt: QFormat, m: float = 3.0,
                    ref: Optional[dict] = None) -> Dict[str, object]:
    """Run Q-TEDA on x (T, N) and score it against the float64 oracle.

    Metrics are over k >= 2 (eq (5) is undefined at k=1).  Verdict
    agreement counts exact outlier-flag equality; hit/miss counts
    summarize how disagreement splits.
    """
    import jax.numpy as jnp

    x = np.asarray(x, np.float32)
    if ref is None:
        ref = teda_numpy_loop(x.astype(np.float64), m)
    _, out = teda_q_stream(jnp.asarray(x), fmt, m)
    ecc_q = fmt.dequantize_np(np.asarray(out.ecc))
    flag_q = np.asarray(out.outlier, bool)
    flag_ref = np.asarray(ref["outlier"], bool)
    sl = slice(1, None)  # k >= 2
    err = np.abs(ecc_q[sl] - ref["ecc"][sl])
    agree = float((flag_q[sl] == flag_ref[sl]).mean())
    return {
        "word_len": fmt.word_len,
        "frac_len": fmt.frac_len,
        "rounding": fmt.rounding,
        "label": fmt.label(),
        "resolution": fmt.resolution,
        "max_abs_err_ecc": float(err.max()),
        "mean_abs_err_ecc": float(err.mean()),
        "verdict_agreement": agree,
        "n_outliers_q": int(flag_q.sum()),
        "n_outliers_ref": int(flag_ref.sum()),
        "missed": int((flag_ref & ~flag_q).sum()),
        "spurious": int((~flag_ref & flag_q).sum()),
    }


def wordlength_sweep(x: np.ndarray,
                     formats: Optional[Sequence[QFormat]] = None,
                     m: float = 3.0) -> List[Dict[str, object]]:
    """Evaluate every format on one stream; oracle computed once."""
    formats = DEFAULT_FORMATS if formats is None else list(formats)
    x = np.asarray(x, np.float32)
    ref = teda_numpy_loop(x.astype(np.float64), m)
    return [evaluate_format(x, f.validate(), m, ref=ref) for f in formats]
