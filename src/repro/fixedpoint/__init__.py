"""Bit-accurate fixed-point emulation of the paper's FPGA datapath.

The paper's central validation artifact is a *bit-accurate simulation* of
the Virtex-6 fixed-point pipeline (MEAN / VARIANCE / ECCENTRICITY /
OUTLIER modules).  This package re-expresses Algorithm 1 entirely in
Q-format integer arithmetic on int32 so the same results can be
reproduced — and swept over word lengths — inside JAX:

  qformat.py  QFormat spec + saturating add/sub/mul and the
              shift-subtract divider (all int32/uint32, Pallas-safe)
  teda_q.py   Algorithm 1 in Q-format ops, lax.scan stream driver
  analysis.py word-length sweep vs the float64 oracle

The integer Pallas kernel lives in `repro.kernels.teda_q_scan` (wrapped
by `repro.kernels.ops.teda_q_scan_tpu`) and is bit-exact with
`teda_q.teda_q_scan_chan` by construction (shared step function).
"""
from repro.fixedpoint.qformat import (QFormat, div_qi, div_qq, sat_add,
                                      sat_sub, sat_mul)
from repro.fixedpoint.teda_q import (teda_q_init, teda_q_step,
                                     teda_q_stream, teda_q_scan_chan)
from repro.fixedpoint.analysis import evaluate_format, wordlength_sweep

__all__ = [
    "QFormat", "sat_add", "sat_sub", "sat_mul", "div_qq", "div_qi",
    "teda_q_init", "teda_q_step", "teda_q_stream", "teda_q_scan_chan",
    "evaluate_format", "wordlength_sweep",
]
