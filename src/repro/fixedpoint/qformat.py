"""Q-format fixed-point arithmetic emulating the paper's FPGA datapath.

A `QFormat(word_len, frac_len)` value is a signed two's-complement
integer of `word_len` bits with `frac_len` fractional bits, held in an
int32 lane.  All operators reproduce what the synthesized datapath does:

  * saturating add/sub — adder with overflow clamp (symmetric range
    [-(2^(WL-1)-1), 2^(WL-1)-1], the DSP-slice convention that keeps
    |qmin| negatable)
  * `sat_mul` — full 2*WL-bit product (built from 16-bit partial
    products, i.e. exactly the DSP48 cascade), then >> FL with
    truncation-toward-zero (or round-half-away) and saturation
  * `div_qq` / `div_qi` — bit-serial shift-subtract (restoring) divider:
    one quotient bit per clock, the architecture the paper's divider
    module synthesizes to.  The wide dividend `num << FL` is never
    materialized; its bits are streamed MSB-first like hardware does.

Everything is int32/uint32 + shifts + compares, so the same functions
trace inside the Pallas TPU kernel (`repro.kernels.teda_q_scan`) and in
plain `lax.scan` — which is what makes the kernel bit-exact with the
pure-JAX reference by construction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["QFormat", "sat", "sat_add", "sat_sub", "sat_mul",
           "div_qq", "div_qi"]

_U32 = jnp.uint32
_I32 = jnp.int32


class QFormat(NamedTuple):
    """Fixed-point spec: `word_len` total bits, `frac_len` fractional.

    `rounding` is the post-shift policy of mul/div: "trunc" (toward
    zero, the cheap hardware default) or "round" (half away from zero).
    Hashable, so it can be a static jit argument.
    """

    word_len: int = 32
    frac_len: int = 16
    rounding: str = "trunc"

    @property
    def int_len(self) -> int:
        return self.word_len - 1 - self.frac_len

    @property
    def qmax(self) -> int:
        return (1 << (self.word_len - 1)) - 1

    @property
    def qmin(self) -> int:
        return -self.qmax  # symmetric saturation

    @property
    def one(self) -> int:
        """Raw representation of 1.0 (may exceed qmax when FL=WL-1)."""
        return 1 << self.frac_len

    @property
    def scale(self) -> float:
        return float(1 << self.frac_len)

    @property
    def resolution(self) -> float:
        return 1.0 / self.scale

    def validate(self) -> "QFormat":
        if not (2 <= self.word_len <= 32):
            raise ValueError(f"word_len {self.word_len} not in [2, 32]")
        if not (0 <= self.frac_len <= min(self.word_len - 1, 30)):
            raise ValueError(
                f"frac_len {self.frac_len} not in [0, "
                f"{min(self.word_len - 1, 30)}] for word_len "
                f"{self.word_len}")
        if self.rounding not in ("trunc", "round"):
            raise ValueError(f"rounding {self.rounding!r}")
        return self

    def quantize(self, x) -> jnp.ndarray:
        """Float -> Q (round-to-nearest ADC front-end, saturating).

        The clamp happens in the integer domain: float32 cannot
        represent qmin/qmax exactly at word_len=32 (a float clip would
        emit -2^31, outside the symmetric format and every Q op's
        |v| < 2^31 contract).
        """
        v = jnp.round(jnp.asarray(x, jnp.float32) * self.scale)
        v = jnp.where(jnp.isnan(v), 0.0, v)
        # float->int32 convert saturates out-of-range values in XLA
        return jnp.clip(v.astype(_I32), self.qmin, self.qmax)

    def quantize_scalar(self, x: float) -> int:
        """Exact host-side quantization of a Python float constant."""
        v = int(round(float(x) * self.scale))
        return max(self.qmin, min(self.qmax, v))

    def dequantize(self, q) -> jnp.ndarray:
        return jnp.asarray(q, jnp.float32) / self.scale

    def dequantize_np(self, q) -> np.ndarray:
        """Exact float64 dequantization for analysis/oracle comparison."""
        return np.asarray(q, np.float64) / self.scale

    def label(self) -> str:
        return f"Q{self.int_len}.{self.frac_len}(wl={self.word_len})"


# --------------------------------------------------------------- add/sub
def sat(fmt: QFormat, v: jnp.ndarray) -> jnp.ndarray:
    """Clamp an int32 value into the WL-bit symmetric range."""
    return jnp.clip(v, fmt.qmin, fmt.qmax).astype(_I32)


def sat_add(fmt: QFormat, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Saturating Q + Q.  Operands must already be in-format."""
    a = a.astype(_I32)
    b = b.astype(_I32)
    s = a + b  # may wrap only when word_len == 32
    same_sign = (a >= 0) == (b >= 0)
    wrapped = same_sign & ((s >= 0) != (a >= 0))
    ext = jnp.where(a >= 0, fmt.qmax, fmt.qmin).astype(_I32)
    return jnp.where(wrapped, ext, sat(fmt, s))


def sat_sub(fmt: QFormat, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    # symmetric range: -b never overflows
    return sat_add(fmt, a, -jnp.asarray(b, _I32))


# -------------------------------------------------------------- multiply
def _mul_wide(ua: jnp.ndarray, ub: jnp.ndarray):
    """Exact 64-bit product of uint32 magnitudes (< 2^31) as (hi, lo).

    Four 16x16 partial products — literally the DSP48 decomposition the
    FPGA multiplier uses.  Every intermediate fits in uint32.
    """
    al, ah = ua & 0xFFFF, ua >> 16            # ah < 2^15
    bl, bh = ub & 0xFFFF, ub >> 16
    ll = al * bl                              # < 2^32, exact in uint32
    lh = al * bh                              # < 2^31
    hl = ah * bl                              # < 2^31
    hh = ah * bh                              # < 2^30
    t = (ll >> 16) + (lh & 0xFFFF) + (hl & 0xFFFF)   # < 3*2^16
    lo = (ll & 0xFFFF) | ((t & 0xFFFF) << 16)
    hi = hh + (lh >> 16) + (hl >> 16) + (t >> 16)
    return hi, lo


def sat_mul(fmt: QFormat, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Saturating Q * Q -> Q: full product, >> FL, round/trunc, clamp."""
    a = jnp.asarray(a, _I32)
    b = jnp.asarray(b, _I32)
    a, b = jnp.broadcast_arrays(a, b)
    neg = (a < 0) != (b < 0)
    ua = jnp.abs(a).astype(_U32)
    ub = jnp.abs(b).astype(_U32)
    hi, lo = _mul_wide(ua, ub)
    fl = fmt.frac_len
    if fmt.rounding == "round" and fl > 0:
        add = _U32(1 << (fl - 1))
        lo2 = lo + add
        hi = hi + (lo2 < lo).astype(_U32)
        lo = lo2
    # saturate iff product >= 2^(WL-1+FL)  (i.e. (P >> FL) > qmax)
    p_star = fmt.word_len - 1 + fl
    if p_star >= 32:
        over = hi >= _U32(1 << (p_star - 32))
    else:
        over = (hi > 0) | (lo >= _U32(1 << p_star))
    if fl == 0:
        q = lo
    else:
        q = (lo >> _U32(fl)) | (hi << _U32(32 - fl))
    q = jnp.where(over, _U32(fmt.qmax), q).astype(_I32)
    return jnp.where(neg, -q, q)


# ---------------------------------------------------------------- divide
def _div_mag(n: jnp.ndarray, d: jnp.ndarray, shift: int,
             rounding: str, qmax: int):
    """floor((n << shift) / d) on uint32 magnitudes, bit-serial.

    Restoring shift-subtract long division, one quotient bit per
    iteration (= per divider clock on the FPGA).  The (31+shift)-bit
    dividend is never materialized: bit i of (n << shift) is bit
    (i - shift) of n, streamed MSB-first.  d == 0 saturates to qmax
    (every trial subtraction succeeds), matching a guard-free divider.
    Returns the quotient already saturated to [0, qmax].
    """
    n, d = jnp.broadcast_arrays(n, d)
    nbits = 31 + shift  # dividend width; n < 2^31

    def body(j, carry):
        r, q, lost = carry
        # dividend bit at position nbits-1-j  ==  bit (30 - j) of n
        sh = jnp.maximum(30 - j, 0).astype(_U32)
        bit = jnp.where(j <= 30, (n >> sh) & _U32(1), _U32(0))
        lost = lost | (r >> _U32(31))
        r = (r << _U32(1)) | bit
        ge = r >= d
        lost = lost | (q >> _U32(31))
        q = (q << _U32(1)) | ge.astype(_U32)
        r = jnp.where(ge, r - d, r)
        return r, q, lost

    zero = jnp.zeros_like(n)
    r, q, lost = jax.lax.fori_loop(0, nbits, body, (zero, zero, zero))
    if rounding == "round":
        half_up = (r >= (d >> _U32(1)) + (d & _U32(1))) & (d > 0)
        q2 = q + half_up.astype(_U32)
        lost = lost | ((q2 < q).astype(_U32))
        q = q2
    return jnp.where((lost > 0) | (q > _U32(qmax)), _U32(qmax), q)


def div_qq(fmt: QFormat, num: jnp.ndarray, den: jnp.ndarray) -> jnp.ndarray:
    """Saturating Q / Q -> Q: computes (num << FL) / den bit-serially.

    Also correct for raw-integer operand pairs in the *same* implicit
    format (e.g. the counters (k-1, k): (k-1)<<FL / k is exactly the
    Q-representation of (k-1)/k).
    """
    num = jnp.asarray(num, _I32)
    den = jnp.asarray(den, _I32)
    num, den = jnp.broadcast_arrays(num, den)
    neg = (num < 0) != (den < 0)
    q = _div_mag(jnp.abs(num).astype(_U32), jnp.abs(den).astype(_U32),
                 fmt.frac_len, fmt.rounding, fmt.qmax)
    q = q.astype(_I32)
    return jnp.where(neg, -q, q)


def div_qi(fmt: QFormat, num: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Saturating Q / int -> Q (no FL pre-shift: (X/2^FL)/k = (X/k)/2^FL).

    This is the divider configuration the pipeline uses for all
    divisions by the sample counter k.
    """
    num = jnp.asarray(num, _I32)
    k = jnp.asarray(k, _I32)
    num, k = jnp.broadcast_arrays(num, k)
    neg = (num < 0) != (k < 0)
    q = _div_mag(jnp.abs(num).astype(_U32), jnp.abs(k).astype(_U32),
                 0, fmt.rounding, fmt.qmax)
    q = q.astype(_I32)
    return jnp.where(neg, -q, q)
