"""Sliding-window z-score detector: local moments over the last W samples.

TEDA and RDE carry whole-stream moments, so a slow drift eventually
absorbs into the baseline; the windowed z-score is the complementary
lens — moments over only the last `window` samples, so it tracks drift
and flags *local* excursions:

  n_k     = min(k, W)
  mu_k    = (S_k  - S_{k-W})  / n_k        (window sum via prefix sums)
  X_k     = (S2_k - S2_{k-W}) / n_k
  sig_k   = X_k - mu_k^2                   (biased window variance)
  flag when (x_k - mu_k)^2 > m^2 * sig_k,  gated on k >= 2, sig_k > 0
  score   = (x_k - mu_k)^2 / sig_k         (the squared z-score)

The oracle carries the classic ring buffer of the last W samples; the
fused kernel carries the algebraically identical W-deep *prefix-sum
tail* (S_{k-W+1} .. S_k and the S2 twin) instead — a windowed sum is a
difference of two prefix sums, so the kernel's doubling scans already
produce everything and the ragged-prefix freeze works exactly like the
running-sum carry (validity is prefix-only, so the tail stays
contiguous).  For k <= W the window spans the whole stream
(S_{k-W} = 0) and the z-score moments coincide with RDE's.

This module is the pure-JAX `lax.scan` oracle the fused kernel is
conformance-checked against.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ZscoreState", "zscore_init", "zscore_scan"]


class ZscoreState(NamedTuple):
    """Per-channel carried window state.

    k: (C,) samples absorbed; ring: (W, C) the last min(k, W) samples
    (slot j holds the sample whose 1-based index i satisfies
    (i - 1) % W == j; unwritten slots are zero and fall outside the
    window sum because only min(k, W) entries are ever populated).
    """

    k: jnp.ndarray
    ring: jnp.ndarray


def zscore_init(c: int, window: int, dtype=jnp.float32) -> ZscoreState:
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    return ZscoreState(k=jnp.zeros((c,), dtype),
                       ring=jnp.zeros((window, c), dtype))


def zscore_scan(x: jnp.ndarray, m=3.0,
                state: Optional[ZscoreState] = None, *,
                window: int = 8,
                valid_lens=None) -> Tuple[ZscoreState, dict]:
    """Windowed z-score over x (T, C) — C independent channel streams.

    Returns (final ZscoreState, {"outlier": (T, C) bool, "score":
    (T, C) squared z-score}).  `m` is a scalar or per-channel (C,)
    sensitivity; `window` is static (it shapes the carried ring; when
    `state` is given its ring width wins).  `valid_lens` freezes each
    channel after its own leading prefix — the engine's ragged
    contract.  Chunk-exact: the carry is the exact last-W ring, so any
    chunking reproduces the single-shot run bit-for-bit.
    """
    x = jnp.asarray(x, jnp.float32)
    t_len, c = x.shape
    if state is None:
        state = zscore_init(c, window)
    w = state.ring.shape[0]
    m2 = jnp.broadcast_to(jnp.asarray(m, jnp.float32) ** 2, (c,))
    if valid_lens is None:
        valid = jnp.ones((t_len, c), bool)
    else:
        vlen = jnp.clip(jnp.asarray(valid_lens, jnp.float32), 0.0, t_len)
        vlen = jnp.broadcast_to(vlen.reshape(-1) if vlen.ndim else vlen,
                                (c,))
        valid = (jnp.arange(t_len, dtype=jnp.float32)[:, None]
                 < vlen[None, :])
    slots = jnp.arange(w, dtype=jnp.float32)[:, None]  # (W, 1)

    def step(carry, inp):
        k, ring = carry
        xr, v = inp
        k1 = jnp.where(v, k + 1.0, k)
        # overwrite the oldest slot, per channel: 1-based index k1 lands
        # in ring slot (k1 - 1) mod W (exact in f32 for k < 2^24)
        pos = jnp.mod(k1 - 1.0, float(w))
        hit = (slots == pos[None, :]) & v[None, :]
        ring1 = jnp.where(hit, xr[None, :], ring)
        n = jnp.minimum(jnp.maximum(k1, 1.0), float(w))
        mu = jnp.sum(ring1, axis=0) / n
        sig = jnp.sum(ring1 * ring1, axis=0) / n - mu * mu
        d2 = (xr - mu) ** 2
        ok = sig > 0.0
        z2 = jnp.where(ok, d2 / jnp.where(ok, sig, 1.0), 0.0)
        flag = v & (k1 >= 2.0) & ok & (d2 > m2 * sig)
        return (k1, ring1), (flag, z2)

    (k, ring), (outlier, score) = jax.lax.scan(
        step, (state.k, state.ring), (x, valid))
    return (ZscoreState(k=k, ring=ring),
            {"outlier": outlier, "score": score})
