"""Jitted public wrapper around the fused ensemble kernel + its oracle.

`ensemble_scan` is the contract layer (`kernels/ops.py`'s role for the
TEDA kernels): it owns the lane/sublane padding via the shared
`kernels/ragged.py` helpers, normalizes carried state to the packed
`EnsembleState(k, aux)` layout — whose row structure is the
`StateSpec` of `detectors/spec.py`, not a fixed formula — defaults the
per-channel selection weights and vote threshold, and returns
per-sample detector bitmasks, fused vote verdicts and per-detector
float score streams alongside the advanced state.

`ensemble_ref` is the conformance target: it composes the per-detector
pure-JAX `lax.scan` oracles (each carrying its own natural state — the
RDE moments, the z-score ring buffer, the TEDA recursion, the HST mass
tables, the Q registers) and fuses their flags on host with the same
float32 detector-order accumulation the kernel uses.  The fused kernel
must agree with it on every flag for well-separated data (and
*bit-exactly* for the hst / teda-q members), and with the standalone
TEDA "pallas" backend bit-for-bit on the TEDA lane (equal block_t).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.detectors import (DEFAULT_DETECTORS, DEFAULT_WINDOW, DETECTORS,
                             ensemble_spec)
from repro.detectors.hst import hst_init, hst_scan
from repro.detectors.teda_q import teda_q_member_scan
from repro.detectors.zscore import zscore_init
from repro.kernels.ensemble_scan import ensemble_pallas_call
from repro.kernels.ragged import default_interpret, norm_block_c, pad_layout

__all__ = ["EnsembleState", "ensemble_init", "ensemble_scan",
           "ensemble_ref"]


class EnsembleState(NamedTuple):
    """Packed shared state of the fused ensemble over C channels.

    k:   (C,) samples absorbed per channel (shared by every detector).
    aux: (spec.rows, C) — the `ensemble_spec(detectors, window)` block:
         the shared moment fabric in rows [0, 2W] (W-deep running-sum
         prefix tail, W-deep sum-of-squares tail, TEDA variance carry),
         then each non-moment member's opaque regions in detector
         order (see `repro.detectors.spec`).
    """

    k: jnp.ndarray
    aux: jnp.ndarray


def ensemble_init(c: int, window: int = DEFAULT_WINDOW,
                  dtype=jnp.float32,
                  detectors=DEFAULT_DETECTORS) -> EnsembleState:
    spec = ensemble_spec(detectors, window)
    return EnsembleState(k=jnp.zeros((c,), dtype),
                         aux=spec.init_aux(c, dtype))


def _check_detectors(detectors) -> Tuple[str, ...]:
    detectors = tuple(detectors)
    unknown = [d for d in detectors if d not in DETECTORS]
    if unknown or not detectors or len(set(detectors)) != len(detectors):
        raise ValueError(
            f"detectors must be a non-empty unique subset of "
            f"{sorted(DETECTORS)}, got {detectors!r}")
    return detectors


@functools.partial(jax.jit,
                   static_argnames=("window", "detectors", "fmt",
                                    "block_t", "block_c", "interpret",
                                    "lane_pad"))
def _padded_ensemble_call(x, vlen, k0, m, thr, sel, aux, *, window,
                          detectors, fmt, block_t, block_c, interpret,
                          lane_pad):
    # lane-padded channels get vlen=0 from the zero pad: frozen at
    # state 0, weight 0 (no votes) — same convention as the TEDA path
    t_len, c = x.shape
    xp, (vlp, kp, mp, thp), sl = pad_layout(x, (vlen, k0, m, thr),
                                            block_t, lane_pad, block_c)
    cp = xp.shape[1]
    selp = jnp.pad(sel, ((0, 0), (0, cp - c)))
    auxp = jnp.pad(aux, ((0, 0), (0, cp - c)))
    outs = ensemble_pallas_call(
        xp, vlp, kp, mp, thp, selp, auxp, block_t=block_t,
        block_c=block_c, window=window, detectors=detectors, fmt=fmt,
        interpret=interpret)
    bits, vote, fk, auxf = outs[:4]
    scores = jnp.stack([s[sl] for s in outs[4:]])  # (K, T, C)
    return bits[sl], vote[sl], fk[0, :c], auxf[:, :c], scores


def _sel_thr(sel, thr, n_det: int, c: int):
    """Normalize selection weights to (K, C) and the vote threshold to
    (C,); `thr=None` defaults to majority over the selected weights."""
    if sel is None:
        sel = jnp.ones((n_det, c), jnp.float32)
    else:
        sel = jnp.asarray(sel, jnp.float32)
        sel = sel[:, None] if sel.ndim == 1 else sel
        sel = jnp.broadcast_to(sel, (n_det, c))
    if thr is None:
        thr = jnp.sum(sel, axis=0) / 2.0  # majority (ties flag)
    else:
        thr = jnp.broadcast_to(jnp.asarray(thr, jnp.float32).reshape(-1)
                               if jnp.asarray(thr).ndim else
                               jnp.asarray(thr, jnp.float32), (c,))
    return sel, thr


def _check_fmt(detectors, fmt):
    if "teda-q" in detectors and fmt is None:
        raise ValueError(
            "the teda-q ensemble member needs fmt=QFormat(...) — the "
            "Q datapath's word/fraction lengths are part of the "
            "detector's definition")
    return fmt if "teda-q" in detectors else None


def ensemble_scan(x: jnp.ndarray, m=3.0,
                  state: Optional[EnsembleState] = None, *,
                  detectors=DEFAULT_DETECTORS,
                  window: int = DEFAULT_WINDOW, sel=None, thr=None,
                  fmt=None, valid_lens=None, block_t: int = 256,
                  block_c: Optional[int] = None,
                  interpret: Optional[bool] = None,
                  lane_pad: int = 128) -> Tuple[EnsembleState, dict]:
    """Fused K-detector ensemble over x (T, C) channel streams.

    Returns (final EnsembleState, {"det_flags": (T, C) int32 bitmask —
    bit d set iff detectors[d] flagged the sample on a channel where it
    is selected, "vote": (T, C) bool fused verdict, "scores": (K, T, C)
    f32 per-detector score streams — row d is detectors[d]'s native
    score (eccentricity / Cauchy density / squared z-score / HST cell
    mass / dequantized Q eccentricity), zero beyond a channel's valid
    prefix and NOT selection-gated}).  `m` is a scalar or per-channel
    (C,) sensitivity shared by every detector; `sel` the (K,) or (K, C)
    selection weights (0 = unselected; None = all selected at unit
    weight); `thr` the per-channel vote threshold (None: majority of
    the selected weight — see `detectors.vote_threshold` for the named
    modes); `fmt` the QFormat of the "teda-q" member (required iff it
    is in `detectors`).  `valid_lens` is the per-channel ragged prefix,
    `block_t`/`block_c`/`lane_pad` the kernel grid knobs — all with the
    exact semantics of the TEDA wrappers in `kernels/ops.py`.
    """
    detectors = _check_detectors(detectors)
    fmt = _check_fmt(detectors, fmt)
    if interpret is None:
        interpret = default_interpret()
    x = jnp.asarray(x, jnp.float32)
    t_len, c = x.shape
    if state is None:
        state = ensemble_init(c, window, detectors=detectors)
    spec = ensemble_spec(detectors, window)
    if state.aux.shape != (spec.rows, c):
        raise ValueError(
            f"state.aux must be ({spec.rows}, {c}) for window={window} "
            f"and layout {spec.names()}, got {state.aux.shape}")
    k0 = jnp.broadcast_to(jnp.asarray(state.k, jnp.float32).reshape(-1)
                          if jnp.asarray(state.k).ndim else
                          jnp.asarray(state.k, jnp.float32), (c,))
    if valid_lens is None:
        vlen = jnp.full((c,), t_len, jnp.float32)
    else:
        vl = jnp.clip(jnp.asarray(valid_lens, jnp.float32), 0, t_len)
        vlen = jnp.broadcast_to(vl.reshape(-1) if vl.ndim else vl, (c,))
    mv = jnp.broadcast_to(jnp.asarray(m, jnp.float32).reshape(-1)
                          if jnp.asarray(m).ndim else
                          jnp.asarray(m, jnp.float32), (c,))
    sel, thr = _sel_thr(sel, thr, len(detectors), c)
    bits, vote, fk, auxf, scores = _padded_ensemble_call(
        x, vlen, k0, mv, thr, sel, jnp.asarray(state.aux, jnp.float32),
        window=window, detectors=detectors, fmt=fmt, block_t=block_t,
        block_c=norm_block_c(block_c), interpret=interpret,
        lane_pad=lane_pad)
    final = EnsembleState(k=fk, aux=auxf)
    return final, {"det_flags": bits, "vote": vote.astype(bool),
                   "scores": scores}


def ensemble_ref(x: jnp.ndarray, m=3.0, *,
                 detectors=DEFAULT_DETECTORS,
                 window: int = DEFAULT_WINDOW, sel=None, thr=None,
                 fmt=None, valid_lens=None) -> dict:
    """Oracle composition: per-detector `lax.scan` results + host vote.

    Runs every detector's pure-JAX oracle from a fresh stream start and
    fuses flags exactly the way the kernel documents: bit d of
    `det_flags` is detectors[d] (selection-masked), the vote weight sum
    accumulates in detector order in float32.  Returns {"det_flags",
    "vote", "per_detector": {name: (T, C) bool}, "per_score":
    {name: (T, C) f32}}.
    """
    detectors = _check_detectors(detectors)
    fmt = _check_fmt(detectors, fmt)
    x = jnp.asarray(x, jnp.float32)
    t_len, c = x.shape
    sel, thr = _sel_thr(sel, thr, len(detectors), c)
    per, per_score = {}, {}
    for name in detectors:
        if name == "zscore":
            _, out = DETECTORS[name](x, m, zscore_init(c, window),
                                     valid_lens=valid_lens)
        elif name == "hst":
            _, out = hst_scan(x, m, hst_init(c), window=window,
                              valid_lens=valid_lens)
        elif name == "teda-q":
            _, out = teda_q_member_scan(x, fmt, m, None,
                                        valid_lens=valid_lens)
        else:
            _, out = DETECTORS[name](x, m, None, valid_lens=valid_lens)
        per[name] = out["outlier"]
        per_score[name] = out["score"]
    if valid_lens is not None:
        # the kernel zeroes score streams beyond a channel's valid
        # prefix; the moment oracles emit unspecified values there
        vl = jnp.clip(jnp.asarray(valid_lens, jnp.float32), 0, t_len)
        vl = jnp.broadcast_to(vl.reshape(-1) if vl.ndim else vl, (c,))
        live = jnp.arange(t_len, dtype=jnp.float32)[:, None] < vl[None, :]
        per_score = {n: jnp.where(live, s, 0.0)
                     for n, s in per_score.items()}
    bits = jnp.zeros((t_len, c), jnp.int32)
    votew = jnp.zeros((t_len, c), jnp.float32)
    for d, name in enumerate(detectors):
        f = per[name] & (sel[d] > 0.0)[None, :]
        bits = bits + f.astype(jnp.int32) * (1 << d)
        votew = votew + f.astype(jnp.float32) * sel[d][None, :]
    totw = jnp.sum(sel, axis=0)
    vote = (votew >= thr[None, :]) & (totw > 0.0)[None, :]
    if valid_lens is not None:
        vl = jnp.clip(jnp.asarray(valid_lens, jnp.float32), 0, t_len)
        vl = jnp.broadcast_to(vl.reshape(-1) if vl.ndim else vl, (c,))
        vote = vote & (jnp.arange(t_len)[:, None] < vl[None, :])
    return {"det_flags": bits, "vote": vote, "per_detector": per,
            "per_score": per_score}
