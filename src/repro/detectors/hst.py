"""Streaming half-space-tree detector — the first non-moment member.

A fixed-depth half-space tree over a static input range is, for
univariate streams, a perfect-binary partition of [lo, hi) into
`HST_LEAVES` equal cells: the leaf index of a sample is the depth-3
path of halving decisions, computable in closed form as
`floor((x - lo) / cell)`.  The detector is the streaming-HS-tree mass
scheme (Tan et al.; the fSEAD ensemble's tree member): two per-leaf
mass tables per channel — the *reference* window's counts and the
*currently filling* window's — plus a phase counter.  Each sample:

  score  = ref[leaf(x)]         (mass of the reference window's cell)
  flag   = filled & score * m < window     (low-mass cell = anomalous;
           `filled` gates until the first full reference window exists)
  cur[leaf(x)] += 1;  phase += 1
  when phase == window * HST_LEAVES:  ref <- cur; cur <- 0; phase <- 0

Every carried quantity is an exact small integer in float32 (counts
never exceed `window * HST_LEAVES`), so this `lax.scan` oracle and the
fused Pallas kernel's per-row loop produce *identical* bits — the
conformance tests assert exact equality, not allclose.  State is not a
running moment: in the packed `EngineState.aux` block the member owns
the opaque `hst:ref` / `hst:cur` / `hst:phase` regions declared by
`detectors/spec.py` — the point of the declarative state fabric.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.detectors.spec import HST_LEAVES, HST_RANGE

__all__ = ["HstState", "hst_init", "hst_scan", "hst_leaf"]


class HstState(NamedTuple):
    """Per-channel carried window-mass state.

    ref: (L, C) leaf masses of the last completed reference window;
    cur: (L, C) masses of the window currently filling; phase: (C,)
    samples absorbed into `cur` so far (0 .. window*L - 1).
    """

    ref: jnp.ndarray
    cur: jnp.ndarray
    phase: jnp.ndarray


def hst_init(c: int, dtype=jnp.float32) -> HstState:
    return HstState(ref=jnp.zeros((HST_LEAVES, c), dtype),
                    cur=jnp.zeros((HST_LEAVES, c), dtype),
                    phase=jnp.zeros((c,), dtype))


def hst_leaf(x: jnp.ndarray) -> jnp.ndarray:
    """Leaf index of each sample: the depth-log2(L) half-space path over
    the static [lo, hi) range, clamped at the boundary cells (f32)."""
    lo, hi = HST_RANGE
    scale = float(HST_LEAVES) / (hi - lo)
    return jnp.clip(jnp.floor((x - lo) * scale), 0.0,
                    float(HST_LEAVES - 1))


def hst_scan(x: jnp.ndarray, m=3.0, state: Optional[HstState] = None, *,
             window: int = 8,
             valid_lens=None) -> Tuple[HstState, dict]:
    """Streaming HS-tree over x (T, C) — C independent channel streams.

    Returns (final HstState, {"outlier": (T, C) bool, "score": (T, C)
    reference-window leaf mass}).  `m` is a scalar or per-channel (C,)
    sensitivity (flag when score * m < window, i.e. the sample's cell
    held fewer than window/m of the reference window's window*L
    samples).  `window` sizes the mass windows (window * HST_LEAVES
    samples each).  `valid_lens` freezes each channel after its own
    leading prefix — the engine's ragged contract.  Chunk-exact: the
    carry is the exact table pair + phase, so any chunking reproduces
    the single-shot run bit-for-bit.
    """
    x = jnp.asarray(x, jnp.float32)
    t_len, c = x.shape
    if state is None:
        state = hst_init(c)
    wn = float(int(window) * HST_LEAVES)
    mv = jnp.broadcast_to(jnp.asarray(m, jnp.float32), (c,))
    if valid_lens is None:
        valid = jnp.ones((t_len, c), bool)
    else:
        vlen = jnp.clip(jnp.asarray(valid_lens, jnp.float32), 0.0, t_len)
        vlen = jnp.broadcast_to(vlen.reshape(-1) if vlen.ndim else vlen,
                                (c,))
        valid = (jnp.arange(t_len, dtype=jnp.float32)[:, None]
                 < vlen[None, :])
    leaves = jnp.arange(HST_LEAVES, dtype=jnp.float32)[:, None]  # (L, 1)

    def step(carry, inp):
        ref, cur, phase = carry
        xr, v = inp
        onehot = leaves == hst_leaf(xr)[None, :]          # (L, C)
        score = jnp.sum(jnp.where(onehot, ref, 0.0), axis=0)
        filled = jnp.sum(ref, axis=0) > 0.0
        flag = v & filled & (score * mv < float(window))
        cur1 = cur + jnp.where(onehot & v[None, :], 1.0, 0.0)
        ph1 = phase + v.astype(jnp.float32)
        flip = ph1 == wn
        ref1 = jnp.where(flip[None, :], cur1, ref)
        cur2 = jnp.where(flip[None, :], 0.0, cur1)
        ph2 = jnp.where(flip, 0.0, ph1)
        return (ref1, cur2, ph2), (flag, jnp.where(v, score, 0.0))

    (ref, cur, phase), (outlier, score) = jax.lax.scan(
        step, (state.ref, state.cur, state.phase), (x, valid))
    return (HstState(ref=ref, cur=cur, phase=phase),
            {"outlier": outlier, "score": score})
