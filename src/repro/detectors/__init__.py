"""Composable streaming anomaly detectors behind one state-carry contract.

fSEAD's FPGA-streaming result (PAPERS.md) is that the win comes from
*composable* ensembles of detectors sharing one streaming fabric, and
the runtime-efficacy survey (Choudhary et al.) shows no single detector
dominates across stream shapes.  This package is that composability for
the repro's serving stack: K detectors evaluated per channel in ONE
fused Pallas call (`kernels/ensemble_scan.py`), selected per slot at
`attach(detectors=...)`, fused into a verdict by majority/weighted
vote.

Every detector speaks the engine's contract — (T, C) chunks of C
independent univariate channel streams, per-channel carried state,
ragged `valid_lens` prefixes — and ships a pure-JAX `lax.scan` oracle
the fused kernel is checked against (the bit-exactness methodology the
TEDA kernels established):

  * "teda"   — the paper's eccentricity detector (eq (6)); shares the
               running-sum mean with the other detectors and reuses the
               TEDA kernel's affine-scan variance recursion verbatim,
               so its ensemble flags are bit-identical to the "pallas"
               backend at equal block_t.
  * "rde"    — recursive density estimation (Angelov's RDE, the close
               TEDA cousin): biased variance from running sum/sum-of-
               squares, flag when (x-mean)^2 > m^2 * var_b.
  * "zscore" — sliding-window z-score over the last `window` samples,
               carried as a prefix-sum tail (the ring buffer of the
               oracle, re-expressed so the fused kernel needs no
               sequential row loop).

Shared-state layout (the `EngineState.aux` rows, `aux_rows(window)` =
2*window + 1 per channel):

  rows [0, W)    — running-sum prefix tail: row W-1+j-W.. holds
                   S_{k-(W-1)+j}; row W-1 is the running sum S_k that
                   the TEDA/RDE mean is derived from.
  rows [W, 2W)   — the same tail for the running sum of squares.
  row  2W        — the TEDA variance recursion carry (eq (3)).

All selected-or-not detectors always advance this shared state (it is
one fabric); per-slot selection weights gate only flags and the vote,
which is what makes a detector-masked slot bit-identical to a
single-detector run of the same stream.
"""
from __future__ import annotations

import numpy as np

from repro.detectors.hst import HstState, hst_scan
from repro.detectors.rde import RdeState, rde_scan
from repro.detectors.spec import (MOMENT_MEMBERS, Region, StateSpec,
                                  ensemble_spec)
from repro.detectors.teda import teda_detector_scan
from repro.detectors.teda_q import TedaQMemberState, teda_q_member_scan
from repro.detectors.zscore import ZscoreState, zscore_scan

__all__ = ["DETECTORS", "DEFAULT_DETECTORS", "DEFAULT_WINDOW",
           "MOMENT_MEMBERS", "Region", "StateSpec", "ensemble_spec",
           "aux_rows", "vote_threshold", "RdeState", "ZscoreState",
           "HstState", "TedaQMemberState", "rde_scan", "zscore_scan",
           "teda_detector_scan", "hst_scan", "teda_q_member_scan"]

#: canonical detector order — index d is bit d of the fused kernel's
#: per-sample detector bitmask.  "teda"/"rde"/"zscore" share the moment
#: fabric; "hst" and "teda-q" carry opaque `StateSpec` regions (the
#: teda-q member additionally needs the backend's `fmt=QFormat(...)`).
DETECTORS = {"teda": teda_detector_scan, "rde": rde_scan,
             "zscore": zscore_scan, "hst": hst_scan,
             "teda-q": teda_q_member_scan}
DEFAULT_DETECTORS = ("teda", "rde", "zscore")
DEFAULT_WINDOW = 8
VOTE_MODES = ("any", "majority", "all")


def aux_rows(window: int = DEFAULT_WINDOW, detectors=None) -> int:
    """Per-channel packed aux rows.

    With `detectors=None` (the historical form): the shared moment
    fabric alone — W-deep S tail + W-deep S2 tail + the TEDA variance
    carry (see module docs).  With an ensemble tuple, the full
    `StateSpec` row count including every member's opaque regions.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if detectors is None:
        return 2 * int(window) + 1
    return ensemble_spec(detectors, window).rows


def vote_threshold(vote, weights) -> float:
    """The weighted-vote decision threshold for one slot.

    `weights` are the slot's per-detector selection weights (0 =
    detector unselected); the verdict fires when the weight-sum of
    flagging detectors is >= the returned threshold (and at least one
    detector is selected).  `vote` is "any" / "majority" / "all", or a
    float fraction f in (0, 1] meaning f * total selected weight.
    Ties count: "majority" of 2 unit-weight detectors fires on 1 flag
    being half the weight — the >= comparison is the documented
    semantics, chosen so the threshold is exactly representable in
    float32 for unit weights.
    """
    w = np.asarray(weights, np.float32).reshape(-1)
    w = w[w > 0]
    tot = float(np.float32(w.sum(dtype=np.float32))) if w.size else 0.0
    if isinstance(vote, bool) or vote is None:
        raise ValueError(f"vote must be a mode or fraction, got {vote!r}")
    if isinstance(vote, (int, float)):
        if not 0.0 < float(vote) <= 1.0:
            raise ValueError(
                f"fractional vote must lie in (0, 1], got {vote}")
        return float(np.float32(vote)) * tot
    if vote == "any":
        return float(w.min()) if w.size else 0.0
    if vote == "majority":
        return tot / 2.0
    if vote == "all":
        return tot
    raise ValueError(
        f"unknown vote mode {vote!r}; expected one of {VOTE_MODES} "
        "or a fraction in (0, 1]")
