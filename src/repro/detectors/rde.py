"""Recursive density estimation (RDE) — Angelov's close TEDA cousin.

RDE keeps the same O(1) per-stream recursion as TEDA but scores each
sample by the Cauchy-kernel density around the running mean with the
*biased* variance from running moments:

  mu_k    = S_k / k,          S_k  = sum_{i<=k} x_i
  X_k     = S2_k / k,         S2_k = sum_{i<=k} x_i^2
  sigma_k = X_k - mu_k^2      (biased variance; >= 0 in real arithmetic)
  D_k     = 1 / (1 + (x_k - mu_k)^2 / sigma_k)

The flag mirrors TEDA's eq (6) structure as an m-sigma gate on the same
moments: outlier when (x_k - mu_k)^2 > m^2 * sigma_k, gated on k >= 2
and sigma_k > 0 (a constant prefix never flags — same guard the TEDA
kernel applies to var=0).  Both carried moments are plain prefix sums,
which is exactly why RDE fuses into the ensemble kernel for free: the
running S the TEDA mean needs is also RDE's S, and S2 is one more
doubling scan.

This module is the pure-JAX `lax.scan` oracle — sequential in time,
per-channel carried state, the conformance target the fused kernel is
checked against.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["RdeState", "rde_init", "rde_scan"]


class RdeState(NamedTuple):
    """Per-channel carried RDE moments.

    k:  (C,) samples absorbed; s: (C,) running sum; s2: (C,) running
    sum of squares.  All float32.
    """

    k: jnp.ndarray
    s: jnp.ndarray
    s2: jnp.ndarray


def rde_init(c: int, dtype=jnp.float32) -> RdeState:
    z = jnp.zeros((c,), dtype)
    return RdeState(k=z, s=z, s2=z)


def rde_scan(x: jnp.ndarray, m=3.0, state: Optional[RdeState] = None, *,
             valid_lens=None) -> Tuple[RdeState, dict]:
    """RDE over x (T, C) — C independent univariate streams.

    Returns (final RdeState, {"outlier": (T, C) bool, "score": (T, C)
    Cauchy density in (0, 1]}).  `m` is a scalar or per-channel (C,)
    sensitivity.  `valid_lens` (scalar or per-channel (C,) vector,
    clamped to [0, T]) freezes each channel after its own leading
    prefix and masks its flags beyond it — the engine's ragged
    contract.  Chunked calls carrying the state reproduce the
    single-shot run bit-for-bit (the carry is the exact running
    moments, and each row's update reads only them).
    """
    x = jnp.asarray(x, jnp.float32)
    t_len, c = x.shape
    if state is None:
        state = rde_init(c)
    m2 = jnp.broadcast_to(jnp.asarray(m, jnp.float32) ** 2, (c,))
    if valid_lens is None:
        valid = jnp.ones((t_len, c), bool)
    else:
        vlen = jnp.clip(jnp.asarray(valid_lens, jnp.float32), 0.0, t_len)
        vlen = jnp.broadcast_to(vlen.reshape(-1) if vlen.ndim else vlen,
                                (c,))
        valid = (jnp.arange(t_len, dtype=jnp.float32)[:, None]
                 < vlen[None, :])

    def step(carry, inp):
        k, s, s2 = carry
        xr, v = inp
        k1 = jnp.where(v, k + 1.0, k)
        s1 = jnp.where(v, s + xr, s)
        s21 = jnp.where(v, s2 + xr * xr, s2)
        kd = jnp.maximum(k1, 1.0)
        mean = s1 / kd
        varb = s21 / kd - mean * mean
        d2 = (xr - mean) ** 2
        ok = varb > 0.0
        dens = 1.0 / (1.0 + jnp.where(ok, d2 / jnp.where(ok, varb, 1.0),
                                      0.0))
        flag = v & (k1 >= 2.0) & ok & (d2 > m2 * varb)
        return (k1, s1, s21), (flag, dens)

    (k, s, s2), (outlier, score) = jax.lax.scan(
        step, (state.k, state.s, state.s2), (x, valid))
    return RdeState(k=k, s=s, s2=s2), {"outlier": outlier, "score": score}
