"""TEDA-Q ensemble member: the bit-accurate Q-format path as a voter.

The fused float ensemble could not include the paper's actual
fixed-point datapath — its state (Q int32 MEAN/VARIANCE registers) is
not a float moment, and `fixedpoint.teda_q_scan_chan` speaks neither
the ragged `valid_lens` contract nor the detector `(state, {"outlier",
"score"})` contract.  This module is both: a `lax.scan` over exactly
the `_q_step_u` the Q kernels execute, with per-channel prefix freeze,
returning the dequantized eccentricity as the member's score stream.

In the fused kernel the member owns the opaque `teda-q:mean` /
`teda-q:var` aux regions (int32 payloads bitcast into the f32 block —
`detectors/spec.py`), and its lane replays the `teda_q_scan` kernel's
divider-hoisted schedule through `kernels/qdiv.py`; this oracle is the
bit-exactness target for that lane (exact equality on flags and on the
raw Q eccentricity, hence on the dequantized score).

The m^2+1 ROM constant is quantized through the format's *float32*
quantizer from the per-channel f32 `m` carry — the kernel receives m
the same way, so both sides compute identical msq1 bits by
construction (`msq1_const`'s host-double path is unreachable from
inside a kernel).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.fixedpoint.qformat import QFormat
from repro.fixedpoint.teda_q import _q_counter_terms, _q_step_u

__all__ = ["TedaQMemberState", "teda_q_member_init", "teda_q_member_scan",
           "member_msq1"]

_I32 = jnp.int32


class TedaQMemberState(NamedTuple):
    """Per-channel carried Q registers: k (C,) int32 sample count,
    mean/var (C,) int32 Q-values."""

    k: jnp.ndarray
    mean: jnp.ndarray
    var: jnp.ndarray


def teda_q_member_init(c: int) -> TedaQMemberState:
    z = jnp.zeros((c,), _I32)
    return TedaQMemberState(k=z, mean=z, var=z)


def member_msq1(fmt: QFormat, m) -> jnp.ndarray:
    """The OUTLIER ROM constant exactly as the fused kernel derives it:
    float32 quantization of m^2 + 1 from the f32 m carry."""
    mf = jnp.asarray(m, jnp.float32)
    return fmt.quantize(mf * mf + 1.0)


def teda_q_member_scan(x: jnp.ndarray, fmt: QFormat, m=3.0,
                       state: Optional[TedaQMemberState] = None, *,
                       valid_lens=None
                       ) -> Tuple[TedaQMemberState, dict]:
    """Q-format TEDA over x (T, C) with the engine's ragged contract.

    Returns (final TedaQMemberState, {"outlier": (T, C) bool, "score":
    (T, C) f32 dequantized eccentricity, "ecc": (T, C) raw Q int32}).
    Float input is quantized through `fmt`; int32 input is taken as
    already-quantized Q values.  `m` is a scalar or per-channel (C,)
    f32 sensitivity.  `valid_lens` freezes each channel's Q registers
    after its own leading prefix; flags and scores are zero beyond it.
    Chunk-exact and bit-exact: the carry is the exact register pair,
    every row's update is `_q_step_u`.
    """
    fmt.validate()
    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        xq = fmt.quantize(jnp.asarray(x, jnp.float32))
    else:
        xq = jnp.asarray(x, _I32)
    t_len, c = xq.shape
    if state is None:
        state = teda_q_member_init(c)
    msq1 = jnp.broadcast_to(member_msq1(fmt, m), (c,))
    if valid_lens is None:
        valid = jnp.ones((t_len, c), bool)
    else:
        vlen = jnp.clip(jnp.asarray(valid_lens, _I32), 0, t_len)
        vlen = jnp.broadcast_to(vlen.reshape(-1) if vlen.ndim else vlen,
                                (c,))
        valid = jnp.arange(t_len, dtype=_I32)[:, None] < vlen[None, :]

    # hoist the counter-only dividers (the Q kernels' schedule): the
    # instant of row t is k0 + t + 1 — validity is a leading prefix, so
    # within it the row index *is* the sample count, and beyond it the
    # frozen carry masks every output anyway
    ks = state.k[None, :] + jnp.arange(1, t_len + 1, dtype=_I32)[:, None]
    terms = _q_counter_terms(fmt, ks, msq1)

    def body(carry, inp):
        mean, var = carry
        kk, xr, v, rk, inv_k, thr_k = inp
        mean_n, var_n, ecc, _zeta, _thr, outl = _q_step_u(
            fmt, kk, mean, var, xr, msq1, terms=(rk, inv_k, thr_k))
        flag = jnp.broadcast_to(outl, xr.shape) & v
        score = jnp.where(v, fmt.dequantize(ecc), 0.0)
        eccq = jnp.where(v, ecc, 0)
        return ((jnp.where(v, mean_n, mean), jnp.where(v, var_n, var)),
                (flag, score, eccq))

    (mean_f, var_f), (outlier, score, eccq) = jax.lax.scan(
        body, (state.mean, state.var), (ks, xq, valid) + terms)
    n_valid = jnp.sum(valid.astype(_I32), axis=0)
    final = TedaQMemberState(k=state.k + n_valid, mean=mean_f, var=var_f)
    return final, {"outlier": outlier, "score": score, "ecc": eccq}
