"""The "ensemble" engine backend: K detectors behind the one-slot
streaming contract.

Registered in `engine/backends.py` as an *unlisted* backend (it is a
different detection algorithm, not another TEDA executor, so it must
not appear in `list_backends()` — the TEDA-semantics conformance
matrix parametrizes over that list).  Construct it through the normal
engine options:

    eng = StreamEngine(64, "ensemble", detectors=("teda", "rde"),
                       vote="majority", window=8)
    eng.attach([3], detectors=("rde",))   # slot 3 runs RDE alone

The backend's packed state grows the `aux` block (`EngineState.aux`,
`aux_rows` rows per channel — see `repro.detectors`); the packed
`mean`/`var` vectors are derived mirrors (running mean, TEDA variance)
kept for introspection parity with the TEDA backends.  `process`
returns a 6-tuple `(k', mean', var', aux', det_bits, vote)` — the
engine routes `det_bits` out on the "ecc" channel (the backend-native
score stream) and `vote` on "outlier", so the serving stack above the
engine is structurally unchanged.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.detectors import (DEFAULT_DETECTORS, DEFAULT_WINDOW, aux_rows,
                             vote_threshold)
from repro.detectors.ensemble import (EnsembleState, _check_detectors,
                                      ensemble_scan)
from repro.engine.backends import Backend

__all__ = ["EnsembleBackend"]


class EnsembleBackend(Backend):
    """Fused multi-detector ensemble executor (float Pallas kernel).

    `detectors` fixes the ensemble's members and their bitmask order
    (bit d = detectors[d]); per-slot *selection* among them is the
    runtime `sel` weight matrix the engine threads through
    `attach(detectors=...)`.  `vote` / `weights` set the default vote
    mode and per-detector weights (see `detectors.vote_threshold`);
    `window` sizes the z-score window and the carried aux block.
    """

    name = "ensemble"
    state_dtype = jnp.float32

    def __init__(self, m: float = 3.0,
                 detectors=DEFAULT_DETECTORS,
                 window: int = DEFAULT_WINDOW, vote="majority",
                 weights=None, block_t: int = 256,
                 block_c: Optional[int] = None,
                 interpret: Optional[bool] = None, lane_pad: int = 128,
                 **_ignored):
        self.detectors = _check_detectors(detectors)
        self.window = int(window)
        self.aux_rows = aux_rows(self.window)
        self.vote = vote
        if weights is None:
            w = np.ones((len(self.detectors),), np.float32)
        elif isinstance(weights, dict):
            unknown = sorted(set(weights) - set(self.detectors))
            if unknown:
                raise ValueError(
                    f"weights for unknown detectors {unknown}; ensemble "
                    f"members: {list(self.detectors)}")
            w = np.asarray([weights.get(d, 1.0) for d in self.detectors],
                           np.float32)
        else:
            w = np.asarray(weights, np.float32).reshape(-1)
            if w.shape != (len(self.detectors),):
                raise ValueError(
                    f"weights must have one entry per detector "
                    f"{list(self.detectors)}, got shape {w.shape}")
        if (w <= 0).any():
            raise ValueError(f"detector weights must be positive: {w}")
        self.weights = w
        # validates the mode (and the weights) eagerly at construction
        self.default_threshold = vote_threshold(vote, w)
        self.m = m
        self.block_t = block_t
        self.block_c = block_c
        self.interpret = interpret
        self.lane_pad = lane_pad

    def process(self, x, k, mean, var, aux=None, m=None, valid_lens=None,
                sel=None, thr=None) -> Tuple[jnp.ndarray, ...]:
        """One fused (T, C) ensemble call.

        `aux` is the packed shared-state block ((aux_rows, C)); `sel`
        the (K, C) per-slot selection weights and `thr` the (C,) vote
        thresholds (None: every detector at its default weight, the
        backend's vote mode).  Returns (k', mean', var', aux',
        det_bits, vote) — mean'/var' are the derived mirrors of the
        aux rows (running mean; TEDA variance).
        """
        if aux is None:
            raise ValueError(
                "the ensemble backend needs the packed aux state "
                "(engine_init(aux_rows=backend.aux_rows))")
        c = x.shape[1]
        if sel is None:
            sel = jnp.broadcast_to(
                jnp.asarray(self.weights)[:, None],
                (len(self.detectors), c))
        if thr is None:
            thr = jnp.full((c,), self.default_threshold, jnp.float32)
        final, out = ensemble_scan(
            x, self._m(m), EnsembleState(k=k, aux=aux),
            detectors=self.detectors, window=self.window, sel=sel,
            thr=thr, valid_lens=valid_lens, block_t=self.block_t,
            block_c=self.block_c, interpret=self.interpret,
            lane_pad=self.lane_pad)
        meanf = final.aux[self.window - 1] / jnp.maximum(final.k, 1.0)
        varf = final.aux[2 * self.window]
        return (final.k, meanf, varf, final.aux, out["det_flags"],
                out["vote"])
