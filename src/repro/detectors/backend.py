"""The "ensemble" engine backend: K detectors behind the one-slot
streaming contract.

Registered in `engine/backends.py` as an *unlisted* backend (it is a
different detection algorithm, not another TEDA executor, so it must
not appear in `list_backends()` — the TEDA-semantics conformance
matrix parametrizes over that list).  Construct it through the normal
engine options:

    eng = StreamEngine(64, "ensemble", detectors=("teda", "rde"),
                       vote="majority", window=8)
    eng.attach([3], detectors=("rde",))   # slot 3 runs RDE alone

The backend's packed state grows the `aux` block (`EngineState.aux`)
whose per-channel row layout is the backend's `state_spec` — the
`StateSpec` of `detectors/spec.py`: the shared moment fabric plus each
non-moment member's opaque regions ("hst" mass tables, "teda-q" Q
registers; the latter requires `fmt=QFormat(...)`).  The packed
`mean`/`var` vectors are derived mirrors (running mean, TEDA variance)
kept for introspection parity with the TEDA backends.  `process`
returns a 7-tuple `(k', mean', var', aux', det_bits, vote, scores)` —
the engine routes `det_bits` out on the "ecc" channel (the
backend-native bit stream), `vote` on "outlier", and the (K, T, C)
per-detector float `scores` on the new "scores" channel, so the
serving stack above the engine stays structurally unchanged for
existing callers while score streams ride along.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.detectors import (DEFAULT_DETECTORS, DEFAULT_WINDOW,
                             ensemble_spec, vote_threshold)
from repro.detectors.ensemble import (EnsembleState, _check_detectors,
                                      _check_fmt, ensemble_scan)
from repro.engine.backends import Backend

__all__ = ["EnsembleBackend"]


class EnsembleBackend(Backend):
    """Fused multi-detector ensemble executor (float Pallas kernel).

    `detectors` fixes the ensemble's members and their bitmask order
    (bit d = detectors[d]); per-slot *selection* among them is the
    runtime `sel` weight matrix the engine threads through
    `attach(detectors=...)`.  `vote` / `weights` set the default vote
    mode and per-detector weights (see `detectors.vote_threshold`);
    `window` sizes the z-score/HST windows and the carried aux block;
    `fmt` is the "teda-q" member's QFormat (required iff present).
    """

    name = "ensemble"
    state_dtype = jnp.float32

    def __init__(self, m: float = 3.0,
                 detectors=DEFAULT_DETECTORS,
                 window: int = DEFAULT_WINDOW, vote="majority",
                 weights=None, fmt=None, block_t: int = 256,
                 block_c: Optional[int] = None,
                 interpret: Optional[bool] = None, lane_pad: int = 128,
                 **_ignored):
        self.detectors = _check_detectors(detectors)
        self.window = int(window)
        self.fmt = _check_fmt(self.detectors, fmt)
        #: the declarative per-member aux layout this backend carries —
        #: engine init/reset, pool resize and shard migration are all
        #: driven by it (raw element bits, opaque to those layers)
        self.state_spec = ensemble_spec(self.detectors, self.window)
        self.aux_rows = self.state_spec.rows
        self.vote = vote
        if weights is None:
            w = np.ones((len(self.detectors),), np.float32)
        elif isinstance(weights, dict):
            unknown = sorted(set(weights) - set(self.detectors))
            if unknown:
                raise ValueError(
                    f"weights for unknown detectors {unknown}; ensemble "
                    f"members: {list(self.detectors)}")
            w = np.asarray([weights.get(d, 1.0) for d in self.detectors],
                           np.float32)
        else:
            w = np.asarray(weights, np.float32).reshape(-1)
            if w.shape != (len(self.detectors),):
                raise ValueError(
                    f"weights must have one entry per detector "
                    f"{list(self.detectors)}, got shape {w.shape}")
        if (w <= 0).any():
            raise ValueError(f"detector weights must be positive: {w}")
        self.weights = w
        # validates the mode (and the weights) eagerly at construction
        self.default_threshold = vote_threshold(vote, w)
        self.m = m
        self.block_t = block_t
        self.block_c = block_c
        self.interpret = interpret
        self.lane_pad = lane_pad

    def process(self, x, k, mean, var, aux=None, m=None, valid_lens=None,
                sel=None, thr=None) -> Tuple[jnp.ndarray, ...]:
        """One fused (T, C) ensemble call.

        `aux` is the packed shared-state block ((state_spec.rows, C));
        `sel` the (K, C) per-slot selection weights and `thr` the (C,)
        vote thresholds (None: every detector at its default weight,
        the backend's vote mode).  Returns (k', mean', var', aux',
        det_bits, vote, scores) — mean'/var' are the derived mirrors of
        the moment-fabric rows (running mean; TEDA variance), `scores`
        the (K, T, C) per-detector float score streams.
        """
        if aux is None:
            raise ValueError(
                "the ensemble backend needs the packed aux state "
                "(engine_init(aux_rows=backend.aux_rows))")
        c = x.shape[1]
        if sel is None:
            sel = jnp.broadcast_to(
                jnp.asarray(self.weights)[:, None],
                (len(self.detectors), c))
        if thr is None:
            thr = jnp.full((c,), self.default_threshold, jnp.float32)
        final, out = ensemble_scan(
            x, self._m(m), EnsembleState(k=k, aux=aux),
            detectors=self.detectors, window=self.window, sel=sel,
            thr=thr, fmt=self.fmt, valid_lens=valid_lens,
            block_t=self.block_t, block_c=self.block_c,
            interpret=self.interpret, lane_pad=self.lane_pad)
        meanf = final.aux[self.window - 1] / jnp.maximum(final.k, 1.0)
        varf = final.aux[2 * self.window]
        return (final.k, meanf, varf, final.aux, out["det_flags"],
                out["vote"], out["scores"])
