"""TEDA as an ensemble detector: the paper's eq (6) behind the shared
detector contract.

Thin adapter over the existing associative-scan oracle
(`core/scan.teda_scan`) so the conformance suite can treat every
detector uniformly: `(state', {"outlier", "score"})` per (T, C) chunk,
with `score` the eccentricity stream.  Inside the fused ensemble kernel
TEDA is not re-implemented — the kernel reuses `teda_scan.py`'s exact
prefix-sum mean and affine-scan variance arithmetic, which is why its
ensemble flags are bit-identical to the standalone "pallas" backend at
equal block_t.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.scan import teda_scan
from repro.core.teda import TedaState

__all__ = ["teda_detector_scan"]


def teda_detector_scan(x: jnp.ndarray, m=3.0,
                       state: Optional[TedaState] = None, *,
                       valid_lens=None) -> Tuple[TedaState, dict]:
    """TEDA oracle over x (T, C) in the detector contract.

    Returns (final TedaState, {"outlier": (T, C) bool, "score": (T, C)
    eccentricity}).  `m` is a scalar or per-channel (C,) sensitivity;
    `valid_lens` the per-channel ragged prefix (see `core/scan.py`).
    """
    x = jnp.asarray(x, jnp.float32)
    final, out = teda_scan(x[..., None], m, state, valid_lens=valid_lens)
    return final, {"outlier": out.outlier, "score": out.ecc}
