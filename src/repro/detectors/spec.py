"""Declarative per-member detector-state layout (`StateSpec`).

PR 8's fused ensemble kernel hard-coded one aux-row formula
(`aux_rows(window) = 2*window + 1`): the shared moment fabric every
member consumed.  A detector whose state is *not* a running moment — a
half-space-tree node table, a quantized TEDA register pair — could not
join the ensemble because nothing in the stack could describe, carry,
or migrate its rows.  This module is that description.

A `StateSpec` is an ordered tuple of named `Region`s, each a contiguous
strip of per-channel rows inside the packed `EngineState.aux` block:

  * `rows`  — the region's row count (static).
  * `tag`   — the *element* dtype of the payload: "f32" rows hold plain
    float32 values; "i32" rows hold int32 payloads stored **bitcast**
    into the float32 aux array (`i32_to_f32_bits` / `f32_to_i32_bits`).
    The bitcast convention is what makes migration trivial: every layer
    that moves aux columns (bucket resize in `engine/pool.py`, shard
    moves in `engine/sharded.py`, the engine's `jnp.where` reset/freeze
    selects) is a raw element copy, so opaque regions ride along
    bit-exactly with no per-member code anywhere outside the kernel.

`ensemble_spec(detectors, window)` builds the layout for one ensemble:
the shared moment fabric first (rows [0, 2W] — the PR 8 layout, kept
byte-identical so moment-only ensembles carry exactly the same aux
block as before), then one opaque region group per non-moment member in
detector order.  Region init is zeros for every member (a fresh stream
has absorbed nothing), so `init_aux` = raw zero bits for both tags.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Region", "StateSpec", "ensemble_spec", "member_regions",
           "MOMENT_MEMBERS", "HST_LEAVES", "HST_RANGE",
           "f32_to_i32_bits", "i32_to_f32_bits"]

#: members whose state *is* the shared moment fabric (prefix-sum tails
#: + the TEDA variance recursion) — they own no opaque region
MOMENT_MEMBERS = ("teda", "rde", "zscore")

#: half-space-tree histogram resolution: leaves per channel (depth-3
#: balanced tree over a static input range), and that range
HST_LEAVES = 8
HST_RANGE = (-4.0, 4.0)


class Region(NamedTuple):
    """One named contiguous strip of per-channel aux rows.

    `tag` is the payload element dtype: "f32" (plain float rows) or
    "i32" (int32 payload bitcast into the f32 aux array — see module
    docs).  Migration is always a raw element copy regardless of tag.
    """

    name: str
    rows: int
    tag: str = "f32"


class StateSpec(NamedTuple):
    """Ordered, hashable layout of one ensemble's packed aux block."""

    regions: Tuple[Region, ...]

    @property
    def rows(self) -> int:
        """Total per-channel aux rows."""
        return sum(r.rows for r in self.regions)

    def offset(self, name: str) -> int:
        """Start row of region `name` (raises KeyError when absent)."""
        off = 0
        for r in self.regions:
            if r.name == name:
                return off
            off += r.rows
        raise KeyError(f"no region {name!r} in {self.names()}")

    def region(self, name: str) -> Region:
        for r in self.regions:
            if r.name == name:
                return r
        raise KeyError(f"no region {name!r} in {self.names()}")

    def slc(self, name: str) -> slice:
        """Static row slice of region `name` inside the aux block."""
        off = self.offset(name)
        return slice(off, off + self.region(name).rows)

    def names(self) -> Tuple[str, ...]:
        return tuple(r.name for r in self.regions)

    def has(self, name: str) -> bool:
        return any(r.name == name for r in self.regions)

    def init_aux(self, c: int, dtype=jnp.float32) -> jnp.ndarray:
        """Fresh packed aux block for C channels.

        Every region initializes to zeros — and the zero bit pattern is
        0 for both f32 and i32 payloads, so the block is plain f32
        zeros regardless of tags (the property the pool's column-fill
        and the engine's `jnp.where` reset rely on).
        """
        return jnp.zeros((self.rows, c), dtype)

    def validate_aux(self, aux, c: int) -> None:
        """Raise unless `aux` has this layout's (rows, C) shape."""
        shape = tuple(jnp.shape(aux))
        if shape != (self.rows, c):
            raise ValueError(
                f"state.aux must be ({self.rows}, {c}) for layout "
                f"{self.names()}, got {shape}")


def f32_to_i32_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Reinterpret f32 aux rows as their int32 payload (no conversion)."""
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def i32_to_f32_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Reinterpret an int32 payload as raw f32 aux rows (no conversion)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.float32)


def _moment_regions(window: int) -> Tuple[Region, ...]:
    """The PR 8 shared fabric, byte-identical row order: W rows of
    running-sum prefix tail, W rows of the sum-of-squares twin, one
    TEDA variance-recursion carry row."""
    w = int(window)
    if w < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    return (Region("moment:s", w), Region("moment:s2", w),
            Region("moment:var", 1))


def _hst_regions(window: int) -> Tuple[Region, ...]:
    """Streaming half-space-tree member: two leaf-mass tables per
    channel (reference window + currently-filling window) plus the
    within-window phase counter, all exact small-integer counts held in
    f32 rows (no value ever exceeds window * HST_LEAVES)."""
    return (Region("hst:ref", HST_LEAVES), Region("hst:cur", HST_LEAVES),
            Region("hst:phase", 1))


def _teda_q_regions(window: int) -> Tuple[Region, ...]:
    """Bit-accurate Q-format TEDA member: the MEAN and VARIANCE module
    registers as int32 Q-values, bitcast into the f32 aux block."""
    return (Region("teda-q:mean", 1, "i32"), Region("teda-q:var", 1, "i32"))


#: per-member opaque-region builders; moment members are absent (their
#: state is the shared fabric)
MEMBER_REGIONS: Dict[str, Callable[[int], Tuple[Region, ...]]] = {
    "hst": _hst_regions,
    "teda-q": _teda_q_regions,
}


def member_regions(name: str, window: int) -> Tuple[Region, ...]:
    """Opaque regions member `name` owns (empty for moment members)."""
    if name in MOMENT_MEMBERS:
        return ()
    try:
        return MEMBER_REGIONS[name](window)
    except KeyError:
        raise KeyError(f"unknown ensemble member {name!r}") from None


def ensemble_spec(detectors, window: int) -> StateSpec:
    """The packed aux layout of one ensemble.

    The shared moment fabric always occupies rows [0, 2W] — even for
    ensembles with no moment member, so the engine's derived mean/var
    mirrors and the kernel's carry discipline stay unconditional — and
    each non-moment member's opaque regions follow in detector order.
    Moment-only ensembles therefore keep the exact PR 8 aux shape
    (`2*window + 1` rows).
    """
    regions = list(_moment_regions(window))
    for name in detectors:
        regions.extend(member_regions(name, window))
    return StateSpec(regions=tuple(regions))
