"""repro.engine — unified stateful multi-stream TEDA engine.

`StreamEngine` carries exact per-stream state across arbitrary-length
chunks for every registered backend ("scan" pure-JAX, "pallas" float
kernel, "pallas-q" bit-accurate Q-format), with ragged multi-tenant
attach/detach/reset slots and optional shard_map channel fan-out.
See README §engine.
"""
# `state` is a leaf (core/teda.py only) and must load first: core/guard.py
# imports it mid-way through `repro.core.__init__`, before the backends
# (which pull in kernels) are importable.
from repro.engine.state import (EngineState, engine_attach, engine_detach,
                                engine_init, engine_process, engine_reset,
                                engine_step, slot_mask)
from repro.engine.backends import (Backend, get_backend, list_backends,
                                   register_backend)
from repro.engine.engine import StreamEngine
from repro.engine.pool import PoolFull, SlotPool
from repro.engine.sharded import HashRing, ShardedPool, stable_hash

__all__ = [
    "Backend", "get_backend", "list_backends", "register_backend",
    "EngineState", "StreamEngine", "SlotPool", "PoolFull",
    "HashRing", "ShardedPool", "stable_hash",
    "engine_init", "engine_process", "engine_step", "engine_reset",
    "engine_attach", "engine_detach", "slot_mask",
]
