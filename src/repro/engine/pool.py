"""Autoscaling slot pool: bucketed capacities over `StreamEngine`.

Growing or shrinking tenancy must not recompile every shape: a JAX
program is specialized on the (T, C) chunk shape, so an engine whose
capacity tracked occupancy exactly would pay a fresh compile on every
attach/detach.  `SlotPool` quantizes capacity to a fixed bucket ladder
(e.g. 8/16/32/64): acquiring a slot beyond the current bucket re-pads
the packed state up to the next bucket, releasing the last tenants of a
bucket re-pads it down — and every bucket's engine (with its compiled
chunk programs) is cached, so a tenancy level seen before costs zero
compiles.  Slot indices are stable across resizes (state is padded at
the tail, never compacted), which is what lets a scheduler treat a slot
as a request lifecycle (`launch/batching.py`).

`PoolFull` (capacity exhausted at the top bucket) is the backpressure
signal — explicit, with occupancy attached, never a silent drop.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.engine.engine import StreamEngine
from repro.engine.state import EngineState
from repro.obs import NULL_TRACER, MetricsRegistry, auto_name

__all__ = ["SlotPool", "PoolFull"]


class PoolFull(RuntimeError):
    """All buckets are full: acquisition must wait for a release."""

    def __init__(self, msg: str, occupancy: int, capacity: int):
        super().__init__(msg)
        self.occupancy = occupancy
        self.capacity = capacity


class SlotPool:
    """Bucketed autoscaling pool of TEDA engine slots.

    >>> pool = SlotPool("pallas", buckets=(8, 16, 32, 64))
    >>> a, b = pool.acquire(2, m=2.5)       # capacity snaps to 8
    >>> out = pool.process(chunk)           # chunk: (T, pool.capacity)
    >>> pool.release([a])                   # may shrink back a bucket

    All engine options (`fmt`, `block_t`, `interpret`, ...) pass
    through to the per-bucket `StreamEngine`s.
    """

    def __init__(self, backend: str = "scan", *,
                 buckets: Tuple[int, ...] = (8, 16, 32, 64),
                 m: float = 3.0, registry=None, tracer=None,
                 name: Optional[str] = None, **engine_opts):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive: {buckets}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.backend_name = backend
        self.default_m = float(m)
        self._opts = dict(engine_opts, m=m)
        self._engines: dict[int, StreamEngine] = {}
        self._bucket = self.buckets[0]
        # observability (repro.obs): the registry/tracer are shared
        # with every per-bucket engine (engine series are labelled
        # `<pool>/capN`), so one snapshot covers the whole pool
        self.registry = (MetricsRegistry() if registry is None
                         else registry)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.name = auto_name("pool") if name is None else str(name)
        lbl = {"pool": self.name}
        self._g_occupancy = self.registry.gauge(
            "pool_occupancy", "attached tenant slots",
            ("pool",)).labels(**lbl)
        self._g_capacity = self.registry.gauge(
            "pool_capacity", "current bucket capacity",
            ("pool",)).labels(**lbl)
        self._g_capacity.set(self._bucket)
        self._c_grows = self.registry.counter(
            "pool_grows_total", "bucket grow transitions",
            ("pool",)).labels(**lbl)
        self._c_shrinks = self.registry.counter(
            "pool_shrinks_total", "bucket shrink transitions",
            ("pool",)).labels(**lbl)
        self._c_full = self.registry.counter(
            "pool_full_total",
            "PoolFull backpressure raises (acquire beyond top bucket)",
            ("pool",)).labels(**lbl)

    # ------------------------------------------------------- engines
    def _engine_for(self, bucket: int) -> StreamEngine:
        eng = self._engines.get(bucket)
        if eng is None:
            eng = StreamEngine(bucket, self.backend_name,
                               auto_attach=False,
                               registry=self.registry,
                               tracer=self.tracer,
                               name=f"{self.name}/cap{bucket}",
                               **self._opts)
            self._engines[bucket] = eng
        return eng

    @property
    def engine(self) -> StreamEngine:
        """The live engine at the current bucket capacity."""
        return self._engine_for(self._bucket)

    @property
    def capacity(self) -> int:
        return self._bucket

    @property
    def max_capacity(self) -> int:
        return self.buckets[-1]

    @property
    def resizes(self) -> int:
        """Grow + shrink transitions (read from the obs registry)."""
        return int(self._c_grows.value + self._c_shrinks.value)

    @property
    def occupancy(self) -> int:
        return len(self.engine.active_slots)

    @property
    def free_slots(self) -> np.ndarray:
        act = np.asarray(self.engine.state.active)
        return np.flatnonzero(~act)

    # ------------------------------------------------------- resizing
    def _resize(self, bucket: int) -> None:
        """Re-pad the packed state into `bucket`'s cached engine."""
        if bucket == self._bucket:
            return
        src, dst = self.engine, self._engine_for(bucket)
        st, keep = src.state, min(self._bucket, bucket)

        def pad(v, fill):
            v = np.asarray(v)[:keep]
            out = np.full((bucket,), fill, v.dtype)
            out[:keep] = v
            return jnp.asarray(out)

        def pad2(v):
            # (R, C) detector-axis aux: pad the slot axis.  A raw host
            # copy of whatever rows the backend's StateSpec declares —
            # element *bits* carry over untouched, which is the aux
            # migration contract: opaque regions (e.g. the teda-q
            # member's int32 Q registers bitcast into the f32 block,
            # some of which alias NaN patterns) survive resizes exactly.
            v = np.asarray(v)[:, :keep]
            out = np.zeros((v.shape[0], bucket), v.dtype)
            out[:, :keep] = v
            return jnp.asarray(out)

        dst.state = EngineState(k=pad(st.k, 0), mean=pad(st.mean, 0),
                                var=pad(st.var, 0),
                                active=pad(st.active, False),
                                aux=(None if st.aux is None
                                     else pad2(st.aux)))
        new_m = np.full((bucket,), self.default_m, np.float32)
        new_m[:keep] = src.slot_m[:keep]
        dst.set_m(None, new_m)
        if getattr(src, "_ensemble", False):
            # per-slot detector selection rides along with the state
            dst._det_w[:, :keep] = src._det_w[:, :keep]
            dst._det_w[:, keep:] = np.asarray(
                dst.backend.weights, np.float32)[:, None]
            dst._det_thr[:keep] = src._det_thr[:keep]
            dst._det_thr[keep:] = dst.backend.default_threshold
            src._reset_detectors(np.ones((self._bucket,), bool))
        # the old engine keeps only its compiled programs, not tenants
        src.state = EngineState(
            k=jnp.zeros_like(st.k), mean=jnp.zeros_like(st.mean),
            var=jnp.zeros_like(st.var),
            active=jnp.zeros_like(st.active),
            aux=None if st.aux is None else jnp.zeros_like(st.aux))
        (self._c_grows if bucket > self._bucket
         else self._c_shrinks).inc()
        if self.tracer.enabled:
            self.tracer.instant("pool.resize", pool=self.name,
                                frm=self._bucket, to=bucket)
        self._bucket = bucket
        self._g_capacity.set(bucket)

    def _bucket_holding(self, n_slots: int, max_idx: int) -> Optional[int]:
        """Smallest bucket with room for `n_slots` keeping index
        `max_idx` addressable; None if even the top bucket is too small."""
        for b in self.buckets:
            if b >= n_slots and b > max_idx:
                return b
        return None

    # ------------------------------------------------------- tenancy
    def acquire(self, n: int = 1, *, m: Optional[float] = None,
                detectors=None, vote=None) -> np.ndarray:
        """Attach `n` new tenants, growing the bucket if needed.

        Returns the acquired slot indices (stable across resizes).
        Raises `PoolFull` when the top bucket cannot hold them — the
        scheduler's backpressure signal.  `detectors` / `vote` select
        the new tenants' detector subset and vote mode under the
        ensemble backend (`StreamEngine.attach`).
        """
        act = np.asarray(self.engine.state.active)
        need = int(act.sum()) + n
        if need > self._bucket:
            max_idx = int(np.flatnonzero(act).max()) if act.any() else -1
            target = self._bucket_holding(need, max_idx)
            if target is None:
                self._c_full.inc()
                raise PoolFull(
                    f"pool full: want {n} more slots with "
                    f"{int(act.sum())}/{self.max_capacity} active at the "
                    f"top bucket", int(act.sum()), self.max_capacity)
            self._resize(target)
        idx = self.engine.attach(n=n, m=m, detectors=detectors,
                                 vote=vote)
        self._g_occupancy.set(need)
        return idx

    def release(self, slots) -> None:
        """Detach tenants; shrink to the smallest bucket that still
        addresses every remaining active slot."""
        self.engine.detach(slots)
        act = np.asarray(self.engine.state.active)
        self._g_occupancy.set(int(act.sum()))
        max_idx = int(np.flatnonzero(act).max()) if act.any() else -1
        target = self._bucket_holding(int(act.sum()), max_idx)
        if target is not None and target < self._bucket:
            self._resize(target)

    # ------------------------------------------------------- processing
    def process(self, x, active=None, valid_lens=None) -> dict:
        """Feed one (T, capacity) chunk to the current bucket's engine.

        `active` is the per-call participation mask and `valid_lens`
        the per-slot ragged retire counts (see `StreamEngine.process`);
        chunk width — and the `valid_lens` vector length — must equal
        the *current* `pool.capacity`: schedulers re-read it after
        acquire/release (`_resize` re-pads the packed *state* across
        buckets, but per-call vectors are built fresh each tick).

        Non-blocking: the returned verdicts are async-dispatch futures
        (see `StreamEngine.process`).  A later `_resize` is the one
        state-dependent sync point — it fetches the packed state to
        re-pad it, so it waits for in-flight calls; resizes are rare
        (bucket transitions only) and never invalidate outputs already
        dispatched at the old capacity.
        """
        return self.engine.process(x, active=active,
                                   valid_lens=valid_lens)

    def programs(self) -> list:
        """Every (capacity, T) program-cache key executed so far,
        across all cached bucket engines.  Flat after warmup = the
        adaptive-chunk path recompiles nothing."""
        return sorted((cap, t) for cap, eng in self._engines.items()
                      for t in eng.program_shapes)

    def stats(self) -> dict:
        return {"bucket": self._bucket, "buckets": list(self.buckets),
                "occupancy": self.occupancy, "resizes": self.resizes,
                "compiled_buckets": sorted(self._engines),
                "programs": self.programs()}
