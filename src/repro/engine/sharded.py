"""Fleet-scale sharded slot pool: one logical pool over N device shards.

The paper sizes a single TEDA pipeline for one FPGA; the ROADMAP
north-star is one logical pool spanning devices, so that "millions of
streams" is a config value (`shards=N`) rather than N hand-glued
`SlotPool`s.  `ShardedPool` composes N per-shard `SlotPool`s and adds
the three things a fleet needs that a single pool does not have:

  * **Consistent-hash routing** — `HashRing` maps request ids onto the
    shard set through a ring of virtual nodes (a stable 64-bit content
    hash, never Python's salted `hash()`), so the rid→shard assignment
    is deterministic across processes and growing the fleet N→N+1
    remaps only ~1/N of the stream population instead of reshuffling
    everyone (`tests/test_sharded.py` pins the remap fraction <= 2/N).

  * **Live slot migration** — `migrate(rid, dst_shard)` extracts one
    slot's packed state vectors (k / mean / var and the ensemble aux
    column) plus its per-slot sensitivity and detector config from the
    source bucket and re-attaches them on the destination *bit-exactly*
    (the state rows are copied as raw int32/float32 element bits, the
    same values `SlotPool._resize` re-pads across buckets), so a stream
    continues mid-window on another shard with identical verdicts.

  * **Occupancy rebalancing** — `rebalance()` migrates streams from the
    most- to the least-loaded shard until the occupancy spread drops
    under `rebalance_threshold`, skipping rids the caller marks in
    flight (`avoid=`); each move is counted, gauged and published as a
    `shard_migrated` event on the wired `EventBus`.

With `devices=`, each shard gets its own single-axis `jax.sharding.Mesh`
over its device group and the per-shard engines fan processing out over
the channel axis via `sharding.rules.make_channel_fanout` — the bucket
ladder must stay divisible by the per-shard device count so every
bucket capacity shards evenly.  Without `devices=`, shards share the
default device (the CPU-only CI case: `XLA_FLAGS=
--xla_force_host_platform_device_count=8` makes 8 virtual devices).

Behavior contract (tests/test_sharded.py): a K-shard pool is bit-exact
with a single-device pool on the pallas-q path for any routing and any
migration schedule — sharding moves *placement*, never arithmetic.
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.engine.pool import PoolFull, SlotPool
from repro.engine.state import EngineState
from repro.obs import NULL_TRACER, MetricsRegistry, auto_name

__all__ = ["HashRing", "ShardedPool", "stable_hash"]


def stable_hash(key: str) -> int:
    """64-bit content hash, stable across processes and Python runs
    (PYTHONHASHSEED randomizes `hash()`, which would re-route every
    stream on restart)."""
    digest = hashlib.blake2b(str(key).encode("utf-8"),
                             digest_size=8).digest()
    return int.from_bytes(digest, "big")


class HashRing:
    """Consistent-hash ring: stable key→shard assignment over vnodes.

    Each shard owns `vnodes` points on a 2^64 ring; a key lands on the
    first point clockwise of its own hash.  Adding a shard steals only
    the arcs its new points cover (~1/N of keys for N+1 shards), so a
    fleet can grow without re-routing the whole stream population.
    """

    def __init__(self, shards: Sequence[int] = (), vnodes: int = 128):
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.vnodes = int(vnodes)
        self._hashes: List[int] = []   # sorted ring point positions
        self._owners: List[int] = []   # shard owning each point
        self._shards: set = set()
        for s in shards:
            self.add(int(s))

    @property
    def shards(self) -> Tuple[int, ...]:
        return tuple(sorted(self._shards))

    def _points(self, shard: int) -> List[int]:
        return [stable_hash(f"shard:{shard}#vn{v}")
                for v in range(self.vnodes)]

    def add(self, shard: int) -> None:
        if shard in self._shards:
            raise ValueError(f"shard {shard} already on the ring")
        for h in self._points(shard):
            i = bisect.bisect_left(self._hashes, h)
            self._hashes.insert(i, h)
            self._owners.insert(i, shard)
        self._shards.add(shard)

    def remove(self, shard: int) -> None:
        if shard not in self._shards:
            raise ValueError(f"shard {shard} not on the ring")
        keep = [(h, o) for h, o in zip(self._hashes, self._owners)
                if o != shard]
        self._hashes = [h for h, _ in keep]
        self._owners = [o for _, o in keep]
        self._shards.discard(shard)

    def assign(self, key: str) -> int:
        """The shard owning `key` (first ring point clockwise)."""
        if not self._shards:
            raise ValueError("empty ring: no shards to assign to")
        i = bisect.bisect_right(self._hashes, stable_hash(key))
        return self._owners[i % len(self._owners)]


class ShardedPool:
    """One logical slot pool composed of N per-shard `SlotPool`s.

    >>> pool = ShardedPool("pallas-q", shards=4, fmt=fmt)
    >>> shard, slot = pool.acquire("tenant-a", m=2.5)
    >>> out = pool.process_shard(shard, chunk, valid_lens=vlens)
    >>> pool.migrate("tenant-a", dst_shard=2)   # live, bit-exact
    >>> pool.release("tenant-a")

    Slots are addressed by request id: `acquire(rid)` routes through
    the consistent-hash ring (or an explicit `shard=`), records the
    placement, and returns `(shard, local_slot)`.  `PoolFull` raised by
    one shard's bucket ladder is backpressure for the streams routed
    *there*; other shards keep serving untouched.  All engine options
    (`fmt`, `block_t`, `interpret`, ...) pass through to the per-shard
    pools.
    """

    def __init__(self, backend: str = "scan", *, shards: int = 2,
                 buckets: Tuple[int, ...] = (8, 16, 32, 64),
                 m: float = 3.0, vnodes: int = 128,
                 devices: Optional[Sequence] = None,
                 axis_name: str = "data",
                 rebalance_threshold: int = 2,
                 registry=None, tracer=None, events=None,
                 name: Optional[str] = None, **engine_opts):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if rebalance_threshold < 2:
            # moving a stream across a spread of 1 just flips the
            # imbalance forever; 2 is the smallest stable threshold
            raise ValueError(
                f"rebalance_threshold must be >= 2, got "
                f"{rebalance_threshold}")
        self.n_shards = int(shards)
        self.rebalance_threshold = int(rebalance_threshold)
        self.registry = (MetricsRegistry() if registry is None
                         else registry)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.events = events  # optional EventBus for shard_migrated
        self.name = auto_name("shpool") if name is None else str(name)
        self.ring = HashRing(range(self.n_shards), vnodes=vnodes)
        meshes = self._shard_meshes(devices, buckets, axis_name)
        self.pools: List[SlotPool] = []
        for s in range(self.n_shards):
            opts = dict(engine_opts)
            if meshes[s] is not None:
                opts.update(mesh=meshes[s], axis_name=axis_name)
            self.pools.append(SlotPool(
                backend, buckets=buckets, m=m, registry=self.registry,
                tracer=self.tracer, name=f"{self.name}/s{s}", **opts))
        self._placement: Dict[str, Tuple[int, int]] = {}
        lbl = {"pool": self.name}
        self._c_migrations = self.registry.counter(
            "sharded_migrations_total",
            "live slot migrations between shards", ("pool",)).labels(**lbl)
        self._g_imbalance = self.registry.gauge(
            "sharded_imbalance",
            "max-min shard occupancy spread", ("pool",)).labels(**lbl)
        self._f_shard_occ = self.registry.gauge(
            "sharded_shard_occupancy", "attached streams per shard",
            ("pool", "shard"))
        self._g_shard_occ = [
            self._f_shard_occ.labels(pool=self.name, shard=str(s))
            for s in range(self.n_shards)]

    def _shard_meshes(self, devices, buckets, axis_name):
        """Per-shard 1-axis meshes over equal device groups (None per
        shard when no devices are pinned)."""
        if devices is None:
            return [None] * self.n_shards
        devices = list(devices)
        if not devices or len(devices) % self.n_shards:
            raise ValueError(
                f"{len(devices)} devices do not split evenly over "
                f"{self.n_shards} shards")
        per = len(devices) // self.n_shards
        bad = [b for b in buckets if b % per]
        if bad:
            raise ValueError(
                f"buckets {bad} not divisible by the {per}-device "
                f"shard mesh (the channel fan-out needs capacity % "
                f"devices == 0)")
        from jax.sharding import Mesh
        return [Mesh(np.asarray(devices[s * per:(s + 1) * per]),
                     (axis_name,))
                for s in range(self.n_shards)]

    # ------------------------------------------------------- topology
    def route(self, rid: str) -> int:
        """The shard the consistent-hash ring assigns to `rid`."""
        return self.ring.assign(rid)

    def lookup(self, rid: str) -> Tuple[int, int]:
        """Current placement of a live stream: (shard, local slot)."""
        try:
            return self._placement[rid]
        except KeyError:
            raise KeyError(f"unknown stream {rid!r}") from None

    @property
    def engine(self):
        """Shard 0's live engine (backend/introspection reference —
        every shard runs the identical backend configuration)."""
        return self.pools[0].engine

    @property
    def capacity(self) -> int:
        return sum(p.capacity for p in self.pools)

    @property
    def max_capacity(self) -> int:
        return sum(p.max_capacity for p in self.pools)

    @property
    def occupancy(self) -> int:
        return len(self._placement)

    def shard_capacity(self, shard: int) -> int:
        return self.pools[shard].capacity

    def shard_free(self, shard: int) -> int:
        """Slots still acquirable on one shard (down its bucket ladder)."""
        p = self.pools[shard]
        return p.max_capacity - p.occupancy

    def occupancies(self) -> List[int]:
        counts = [0] * self.n_shards
        for s, _ in self._placement.values():
            counts[s] += 1
        return counts

    @property
    def imbalance(self) -> int:
        occ = self.occupancies()
        return max(occ) - min(occ)

    def _update_gauges(self) -> None:
        occ = self.occupancies()
        for s, g in enumerate(self._g_shard_occ):
            g.set(occ[s])
        self._g_imbalance.set(max(occ) - min(occ))

    # -------------------------------------------------------- tenancy
    def acquire(self, rid: str, *, m: Optional[float] = None,
                shard: Optional[int] = None, detectors=None,
                vote=None) -> Tuple[int, int]:
        """Attach `rid` on its routed shard; returns (shard, slot).

        `shard=` overrides the ring (explicit placement — tests and
        the rebalancer use it).  `PoolFull` from the target shard's
        bucket ladder propagates with the shard named: backpressure
        for streams routed there, invisible to the other shards.
        """
        if rid in self._placement:
            raise ValueError(f"stream {rid!r} already attached at "
                             f"{self._placement[rid]}")
        s = self.route(rid) if shard is None else int(shard)
        if not 0 <= s < self.n_shards:
            raise ValueError(f"shard {s} out of range "
                             f"[0, {self.n_shards})")
        try:
            slot = int(self.pools[s].acquire(
                1, m=m, detectors=detectors, vote=vote)[0])
        except PoolFull as e:
            raise PoolFull(f"shard {s}: {e}", e.occupancy,
                           e.capacity) from None
        self._placement[rid] = (s, slot)
        self._update_gauges()
        return s, slot

    def release(self, rid: str) -> None:
        s, slot = self.lookup(rid)
        del self._placement[rid]
        self.pools[s].release([slot])
        self._update_gauges()

    # ----------------------------------------------------- processing
    def process_shard(self, shard: int, x, active=None,
                      valid_lens=None) -> dict:
        """Feed one (T, shard_capacity(shard)) chunk to one shard.

        Per-shard calls are independent JAX async dispatches — a
        scheduler ticks every shard without a barrier between them
        (`launch/batching.py` keeps each shard's call fenced exactly
        like a single pool's).
        """
        return self.pools[shard].process(x, active=active,
                                         valid_lens=valid_lens)

    # ------------------------------------------------------ migration
    def migrate(self, rid: str, dst_shard: int, *, tick: int = 0) -> int:
        """Move a live stream to `dst_shard` bit-exactly; returns its
        new local slot.

        The slot's packed state (k / mean / var, the ensemble aux
        column), per-slot sensitivity and detector selection are
        fetched from the source bucket and written element-for-element
        into a freshly acquired destination slot — int32 Q bits and
        float32 words copy exactly, so the stream's future verdicts
        are identical to never having moved (the same re-pad guarantee
        `SlotPool._resize` gives across buckets, across shards).  The
        aux column is opaque here: whatever regions the backend's
        `StateSpec` declares (moment tails, HST mass tables, bitcast
        int32 Q registers — including payloads that alias f32 NaN
        patterns) move as raw element bits, never through arithmetic.  The
        destination is acquired *before* the source releases: a full
        destination raises `PoolFull` and leaves the stream in place.
        """
        src_s, slot = self.lookup(rid)
        dst_shard = int(dst_shard)
        if not 0 <= dst_shard < self.n_shards:
            raise ValueError(f"shard {dst_shard} out of range "
                             f"[0, {self.n_shards})")
        if dst_shard == src_s:
            return slot
        src_pool, dst_pool = self.pools[src_s], self.pools[dst_shard]
        eng = src_pool.engine
        st = eng.state
        # exact per-slot state bits (int32 on the Q path; np.asarray is
        # the fetch/sync point — the caller keeps in-flight calls off
        # migrating slots, exactly like a resize)
        k = np.asarray(st.k)[slot]
        mean = np.asarray(st.mean)[slot]
        var = np.asarray(st.var)[slot]
        aux = (None if st.aux is None
               else np.asarray(st.aux)[:, slot].copy())
        m_val = eng._m[slot]
        ens = getattr(eng, "_ensemble", False)
        det_w = eng._det_w[:, slot].copy() if ens else None
        det_thr = eng._det_thr[slot] if ens else None

        try:
            new_slot = int(dst_pool.acquire(1)[0])
        except PoolFull as e:
            raise PoolFull(f"migration target shard {dst_shard}: {e}",
                           e.occupancy, e.capacity) from None
        deng = dst_pool.engine
        dst_st = deng.state
        deng.state = EngineState(
            k=dst_st.k.at[new_slot].set(jnp.asarray(k)),
            mean=dst_st.mean.at[new_slot].set(jnp.asarray(mean)),
            var=dst_st.var.at[new_slot].set(jnp.asarray(var)),
            active=dst_st.active,
            aux=(dst_st.aux if aux is None
                 else dst_st.aux.at[:, new_slot].set(jnp.asarray(aux))))
        deng._m[new_slot] = m_val
        if ens:
            deng._det_w[:, new_slot] = det_w
            deng._det_thr[new_slot] = det_thr
        src_pool.release([slot])
        self._placement[rid] = (dst_shard, new_slot)
        self._c_migrations.inc()
        self._update_gauges()
        if self.tracer.enabled:
            self.tracer.instant("shard.migrate", pool=self.name,
                                rid=rid, src=src_s, dst=dst_shard,
                                slot=new_slot)
        if self.events is not None:
            self.events.publish("shard_migrated", tick, rid,
                                src=src_s, dst=dst_shard, slot=new_slot)
        return new_slot

    def rebalance(self, *, avoid=(), max_moves: Optional[int] = None,
                  tick: int = 0) -> List[Tuple[str, int, int, int]]:
        """Migrate streams hottest-shard -> coldest-shard until the
        occupancy spread drops under `rebalance_threshold`.

        `avoid` names rids that must not move (the scheduler passes
        streams with in-flight calls — migration's state fetch must
        not race a dispatched chunk).  Candidate choice is
        deterministic (lexicographically smallest movable rid on the
        hottest shard), so a fixed workload produces a fixed migration
        schedule.  Returns the executed moves as
        (rid, src_shard, dst_shard, new_slot).
        """
        avoid = set(avoid)
        moves: List[Tuple[str, int, int, int]] = []
        if self.n_shards < 2:
            return moves
        while max_moves is None or len(moves) < max_moves:
            occ = self.occupancies()
            hi = max(range(self.n_shards), key=lambda s: (occ[s], s))
            lo = min(range(self.n_shards), key=lambda s: (occ[s], s))
            if occ[hi] - occ[lo] < self.rebalance_threshold:
                break
            cands = sorted(r for r, (s, _) in self._placement.items()
                           if s == hi and r not in avoid)
            if not cands:
                break  # everything movable is in flight: next tick
            rid = cands[0]
            try:
                slot = self.migrate(rid, lo, tick=tick)
            except PoolFull:
                break  # cold shard's ladder is full at this bucket mix
            moves.append((rid, hi, lo, slot))
        return moves

    # -------------------------------------------------- introspection
    @property
    def migrations(self) -> int:
        return int(self._c_migrations.value)

    def programs(self) -> list:
        """Union of every shard's (capacity, T) program-cache keys —
        flat after warmup means no shard recompiles per tick."""
        return sorted({key for p in self.pools for key in p.programs()})

    def stats(self) -> dict:
        occ = self.occupancies()
        return {"shards": self.n_shards, "occupancy": self.occupancy,
                "shard_occupancy": occ,
                "imbalance": max(occ) - min(occ),
                "migrations": self.migrations,
                "resizes": sum(p.resizes for p in self.pools),
                "programs": self.programs(),
                "per_shard": [p.stats() for p in self.pools]}
