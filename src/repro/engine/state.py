"""Packed per-stream engine state + the pure functional core.

The paper's FPGA pipeline is a *stateful online* detector — one sample
in, one verdict out, O(1) state carried forever.  `EngineState` packs
that state for C independent univariate TEDA modules (the paper's
replicated-module scaling) as per-channel `k` / mean / var vectors plus
an `active` occupancy mask, so every slot is ragged: its own stream
position, recyclable for a new tenant mid-flight via
`engine_attach` / `engine_detach` / `engine_reset`.

Everything here is pure and jittable — `core/guard.py` and
`launch/serve.py` run `engine_step` inside compiled train/decode steps.
This module is a leaf (it depends only on `core/teda.py`): the backend
registry and the stateful `StreamEngine` wrapper live one level up in
`engine/backends.py` / `engine/engine.py`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.teda import TedaOutput, TedaState, teda_step

__all__ = ["EngineState", "engine_init", "engine_process", "engine_step",
           "engine_reset", "engine_attach", "engine_detach", "slot_mask"]


class EngineState(NamedTuple):
    """Packed per-stream state: C independent univariate TEDA modules.

    k:      (C,) — samples absorbed per slot (honest per-channel count).
    mean:   (C,) — recursive mean, eq (2).
    var:    (C,) — recursive variance, eq (3).
    active: (C,) bool — slot occupancy; inactive slots never advance.
    aux:    (R, C) detector-axis carry rows, or None.  The "ensemble"
            backend packs its K-detector shared fabric here (prefix-sum
            tails + variance carry, R = backend.aux_rows — see
            `repro.detectors`); the TEDA backends carry no aux and the
            field stays None.  `mean`/`var` are derived mirrors of the
            aux rows under the ensemble backend.

    dtype is float32, or int32 Q-values under the "pallas-q" backend.
    """

    k: jnp.ndarray
    mean: jnp.ndarray
    var: jnp.ndarray
    active: jnp.ndarray
    aux: Optional[jnp.ndarray] = None


def engine_init(capacity: int, dtype=jnp.float32,
                active: bool = True, aux_rows: int = 0) -> EngineState:
    """Fresh packed state for `capacity` slots (Algorithm 1 init).

    Each field gets its own buffer — aliased zeros would break buffer
    donation when the state is carried through a jitted step.
    `aux_rows` > 0 allocates the detector-axis carry block (the
    ensemble backend's `backend.aux_rows`).
    """
    return EngineState(k=jnp.zeros((capacity,), dtype),
                       mean=jnp.zeros((capacity,), dtype),
                       var=jnp.zeros((capacity,), dtype),
                       active=jnp.full((capacity,), active),
                       aux=(jnp.zeros((aux_rows, capacity), dtype)
                            if aux_rows else None))


def slot_mask(slots, capacity: int) -> jnp.ndarray:
    """Normalize a slot selector to a (C,) bool mask.

    `slots` may be None (all slots), a bool mask, or integer indices.
    Concrete indices are bounds-checked — JAX scatter silently drops
    out-of-range indices, which would turn attach/reset on a bad slot
    into a successful-looking no-op.  (Traced indices inside jit skip
    the check.)
    """
    if slots is None:
        return jnp.ones((capacity,), bool)
    slots = jnp.asarray(slots)
    if slots.dtype == bool:
        return slots.reshape((capacity,))
    try:
        idx = np.asarray(slots)
    except Exception:  # traced under jit: not concretizable
        idx = None
    if idx is not None and idx.size and (
            idx.min() < 0 or idx.max() >= capacity):
        raise IndexError(
            f"slot indices {np.unique(idx).tolist()} out of range for "
            f"capacity {capacity}")
    return jnp.zeros((capacity,), bool).at[slots].set(True)


def engine_reset(state: EngineState, slots=None) -> EngineState:
    """Zero the TEDA state of the selected slots (k=mean=var=0), keeping
    occupancy — the mid-flight recycle for a new tenant on a live slot."""
    m = slot_mask(slots, state.k.shape[0])
    zero = jnp.zeros((), state.k.dtype)
    return EngineState(k=jnp.where(m, zero, state.k),
                       mean=jnp.where(m, zero, state.mean),
                       var=jnp.where(m, zero, state.var),
                       active=state.active,
                       aux=(None if state.aux is None
                            else jnp.where(m[None, :], zero, state.aux)))


def engine_attach(state: EngineState, slots) -> EngineState:
    """Activate (and zero) the selected slots for new streams."""
    m = slot_mask(slots, state.k.shape[0])
    state = engine_reset(state, m)
    return state._replace(active=jnp.logical_or(state.active, m))


def engine_detach(state: EngineState, slots) -> EngineState:
    """Deactivate the selected slots; their state is cleared and they
    stop advancing (and flagging) until re-attached."""
    m = slot_mask(slots, state.k.shape[0])
    state = engine_reset(state, m)
    return state._replace(active=jnp.logical_and(state.active, ~m))


def engine_process(state: EngineState, x: jnp.ndarray, backend,
                   m=None, valid_lens=None, sel=None,
                   thr=None) -> Tuple[EngineState, dict]:
    """Advance the packed state through one (T, C) chunk.

    `backend` follows the `engine.backends.Backend` contract (duck-typed
    so this module stays a leaf).  Inactive slots are frozen (their
    state does not advance) and never flag.  `m` optionally overrides
    the backend's constructed threshold — a scalar or per-slot (C,)
    vector (tenants at different sensitivity levels in one batch).

    `valid_lens` (per-slot (C,) int vector) makes the call ragged: slot
    c retires exactly valid_lens[c] leading rows (0..T) of its column —
    the backend freezes each slot's state after its own prefix, slots
    with vlen=0 are frozen bit-exactly at the packed state (no float
    round-trip through the backend), and no slot flags at rows beyond
    its valid length.  The caller owns folding occupancy/participation
    into the vector (inactive slot => vlen 0).  `None` is the uniform
    path: every active slot retires all T rows.

    Returns (state', {"ecc": (T, C), "outlier": (T, C) bool}) — `ecc`
    is in the backend's native domain (Q int32 for "pallas-q").

    Aux-carrying backends (`backend.aux_rows > 0`, i.e. the ensemble)
    take the extra per-slot `sel` selection weights / `thr` vote
    thresholds and return a 7-tuple — `ecc` is then the per-detector
    flag bitmask, `outlier` the fused vote, and the output dict grows
    "scores": the (K, T, C) per-detector float score streams (zeroed
    on frozen/inactive slots); the aux block freezes with the same
    masks as k/mean/var.
    """
    if getattr(backend, "aux_rows", 0):
        return _engine_process_aux(state, x, backend, m, valid_lens,
                                   sel, thr)
    if valid_lens is None:
        kf, mf, vf, ecc, outlier = backend.process(x, state.k, state.mean,
                                                   state.var, m=m)
        act = state.active
        new = EngineState(
            k=jnp.where(act, kf.astype(state.k.dtype), state.k),
            mean=jnp.where(act, mf, state.mean),
            var=jnp.where(act, vf, state.var),
            active=act,
        )
        outs = {"ecc": ecc,
                "outlier": jnp.logical_and(outlier, act[None, :])}
        return new, outs

    vl = jnp.asarray(valid_lens, jnp.int32)
    kf, mf, vf, ecc, outlier = backend.process(
        x, state.k, state.mean, state.var, m=m, valid_lens=vl)
    adv = vl > 0  # fully-suspended slots: exact engine-level freeze
    new = EngineState(
        k=jnp.where(adv, kf.astype(state.k.dtype), state.k),
        mean=jnp.where(adv, mf, state.mean),
        var=jnp.where(adv, vf, state.var),
        active=state.active,
    )
    rows = jnp.arange(x.shape[0], dtype=vl.dtype)[:, None]
    outs = {"ecc": ecc,
            "outlier": jnp.logical_and(outlier, rows < vl[None, :])}
    return new, outs


def _engine_process_aux(state: EngineState, x, backend, m, valid_lens,
                        sel, thr) -> Tuple[EngineState, dict]:
    """The aux-carrying (ensemble) leg of `engine_process`.

    The backend's kernel already zeroes flags and votes beyond each
    slot's valid prefix, so the ragged leg passes the verdicts through;
    the uniform leg gates on `active` exactly like the TEDA leg.
    """
    if valid_lens is None:
        kf, mf, vf, auxf, bits, vote, scores = backend.process(
            x, state.k, state.mean, state.var, aux=state.aux, m=m,
            sel=sel, thr=thr)
        act = state.active
        new = EngineState(
            k=jnp.where(act, kf.astype(state.k.dtype), state.k),
            mean=jnp.where(act, mf, state.mean),
            var=jnp.where(act, vf, state.var),
            active=act,
            aux=jnp.where(act[None, :], auxf, state.aux))
        outs = {"ecc": jnp.where(act[None, :], bits, 0),
                "outlier": jnp.logical_and(vote, act[None, :]),
                "scores": jnp.where(act[None, None, :], scores, 0.0)}
        return new, outs

    vl = jnp.asarray(valid_lens, jnp.int32)
    kf, mf, vf, auxf, bits, vote, scores = backend.process(
        x, state.k, state.mean, state.var, aux=state.aux, m=m,
        valid_lens=vl, sel=sel, thr=thr)
    adv = vl > 0
    new = EngineState(
        k=jnp.where(adv, kf.astype(state.k.dtype), state.k),
        mean=jnp.where(adv, mf, state.mean),
        var=jnp.where(adv, vf, state.var),
        active=state.active,
        aux=jnp.where(adv[None, :], auxf, state.aux))
    rows = jnp.arange(x.shape[0], dtype=vl.dtype)[:, None]
    live = rows < vl[None, :]
    outs = {"ecc": jnp.where(live, bits, 0),
            "outlier": jnp.logical_and(vote, live),
            "scores": jnp.where(live[None], scores, 0.0)}
    return new, outs


def engine_step(state: EngineState, x: jnp.ndarray,
                m: float | jnp.ndarray = 3.0
                ) -> Tuple[EngineState, TedaOutput]:
    """Single-sample fast path: one packed update for x (C,).

    The T=1 analog of `engine_process` for in-loop monitors (the train
    guard, the decode monitor) — one `teda_step` on the packed vectors,
    cheap enough to live inside a jitted train/decode step.  Float-state
    only (the Q datapath goes through `engine_process`).
    """
    if jnp.issubdtype(state.k.dtype, jnp.integer):
        raise TypeError(
            "engine_step is float-state only; Q-format (int32) state "
            "advances through engine_process with the 'pallas-q' backend")
    ts, out = teda_step(
        TedaState(k=state.k, mean=state.mean[:, None], var=state.var),
        x[:, None], m)
    act = state.active
    new = EngineState(k=jnp.where(act, ts.k, state.k),
                      mean=jnp.where(act, ts.mean[:, 0], state.mean),
                      var=jnp.where(act, ts.var, state.var),
                      active=act)
    out = out._replace(outlier=jnp.logical_and(out.outlier, act))
    return new, out
