"""Stateful multi-stream TEDA engine with ragged multi-tenant slots.

`StreamEngine` owns packed per-stream state (`engine/state.py`) and
processes arbitrary-length (T, C) chunks as they arrive, carrying exact
state across calls for every backend in the registry
(`engine/backends.py`).  Multi-tenancy is ragged by construction: every
slot has its own `k`, an `active` mask gates state advancement, and
`attach` / `detach` / `reset` recycle a slot for a new tenant mid-flight
without touching neighbours.

With a `mesh`, chunk processing fans out over the channel axis via
`shard_map` (`sharding.rules.make_channel_fanout`) — channels are
independent, so multi-device scale needs no collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.teda import TedaState
from repro.engine.backends import get_backend
from repro.engine.state import (EngineState, engine_attach, engine_detach,
                                engine_init, engine_process, engine_reset)

__all__ = ["StreamEngine"]


class StreamEngine:
    """Stateful multi-stream TEDA detector over `capacity` slots.

    >>> eng = StreamEngine(capacity=256, backend="pallas", m=3.0)
    >>> verdicts = eng.process(chunk)          # chunk: (T, 256)
    >>> eng.reset([7])                         # recycle slot 7 mid-flight
    >>> eng.detach([3]); eng.attach([3])       # slot 3: new tenant

    Chunks may have any length T >= 1; state is carried exactly across
    calls (bit-for-bit on the Q path).  With `mesh=`, processing fans
    out over the channel axis via shard_map for multi-device scale.
    """

    def __init__(self, capacity: int, backend: str = "scan", *,
                 m: float = 3.0, fmt=None, block_t: int = 256,
                 interpret: Optional[bool] = None, lane_pad: int = 128,
                 mesh=None, axis_name: str = "data",
                 auto_attach: bool = True):
        self.capacity = int(capacity)
        self.backend = get_backend(backend, m=m, fmt=fmt, block_t=block_t,
                                   interpret=interpret, lane_pad=lane_pad)
        self.state = engine_init(self.capacity, self.backend.state_dtype,
                                 active=auto_attach)

        def core(x, k, mean, var, active):
            st, outs = engine_process(
                EngineState(k=k, mean=mean, var=var, active=active), x,
                self.backend)
            return (st.k, st.mean, st.var), (outs["ecc"], outs["outlier"])

        if mesh is not None:
            from repro.sharding.rules import make_channel_fanout
            n_shards = dict(mesh.shape)[axis_name]
            if self.capacity % n_shards:
                raise ValueError(
                    f"capacity {self.capacity} not divisible by mesh "
                    f"axis {axis_name!r} ({n_shards} shards)")
            core = make_channel_fanout(core, mesh, axis_name)
        self._fn = jax.jit(core)

    # ------------------------------------------------------ slot admin
    def attach(self, slots=None, n: Optional[int] = None):
        """Activate slots for new streams; returns the slot indices.

        With `slots=None`, grabs the first `n` free slots (all free
        slots when `n` is also None).
        """
        if slots is None:
            free = np.flatnonzero(~np.asarray(self.state.active))
            slots = free if n is None else free[:n]
            if n is not None and len(slots) < n:
                raise ValueError(f"wanted {n} free slots, have {len(free)}")
        idx = np.atleast_1d(np.asarray(slots))
        self.state = engine_attach(self.state, idx)
        return idx

    def detach(self, slots):
        self.state = engine_detach(self.state, slots)

    def reset(self, slots=None):
        self.state = engine_reset(self.state, slots)

    # ------------------------------------------------------ processing
    def process(self, x: jnp.ndarray) -> dict:
        """Feed one (T, capacity) chunk; returns per-sample verdicts."""
        x = jnp.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.capacity:
            raise ValueError(
                f"chunk must be (T, {self.capacity}), got {x.shape}")
        st = self.state
        (k, mean, var), (ecc, outlier) = self._fn(
            x, st.k, st.mean, st.var, st.active)
        self.state = EngineState(k=k, mean=mean, var=var, active=st.active)
        return {"ecc": ecc, "outlier": outlier}

    # ------------------------------------------------------- introspection
    @property
    def active_slots(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.state.active))

    @property
    def samples_seen(self) -> np.ndarray:
        """Per-slot sample counts (the honest per-channel k)."""
        return np.asarray(self.state.k)

    def teda_state(self) -> TedaState:
        """The packed state in the `repro.core` TedaState layout."""
        return TedaState(k=self.state.k, mean=self.state.mean[:, None],
                         var=self.state.var)
