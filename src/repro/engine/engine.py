"""Stateful multi-stream TEDA engine with ragged multi-tenant slots.

`StreamEngine` owns packed per-stream state (`engine/state.py`) and
processes arbitrary-length (T, C) chunks as they arrive, carrying exact
state across calls for every backend in the registry
(`engine/backends.py`).  Multi-tenancy is ragged by construction: every
slot has its own `k` and its own outlier threshold `m` (tenants run
different sensitivity levels in one batch), an `active` mask gates
state advancement, and `attach` / `detach` / `reset` recycle a slot for
a new tenant mid-flight without touching neighbours.  `process` takes
optional per-call raggedness controls: `valid_lens` gives every slot
its own retired-sample count for the call (0..T — one fused kernel
program serves prefill-heavy and decode-phase slots together), and the
`active` participation mask is the vlen=0 special case kept as sugar,
so a scheduler can freeze slots that have no data this step without
releasing them (the continuous-batching suspend, `launch/batching.py`).

With a `mesh`, chunk processing fans out over the channel axis via
`shard_map` (`sharding.rules.make_channel_fanout`) — channels are
independent, so multi-device scale needs no collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.teda import TedaState
from repro.engine.backends import get_backend
from repro.engine.state import (EngineState, engine_attach, engine_detach,
                                engine_init, engine_process, engine_reset,
                                slot_mask)
from repro.obs import NULL_TRACER, MetricsRegistry, auto_name

__all__ = ["StreamEngine"]


class StreamEngine:
    """Stateful multi-stream TEDA detector over `capacity` slots.

    >>> eng = StreamEngine(capacity=256, backend="pallas", m=3.0)
    >>> verdicts = eng.process(chunk)          # chunk: (T, 256)
    >>> eng.reset([7])                         # recycle slot 7 mid-flight
    >>> eng.detach([3]); eng.attach([3], m=2.5)  # slot 3: new tenant

    Chunks may have any length T >= 1; state is carried exactly across
    calls (bit-for-bit on the Q path).  With `mesh=`, processing fans
    out over the channel axis via shard_map for multi-device scale.
    """

    def __init__(self, capacity: int, backend: str = "scan", *,
                 m: float = 3.0, fmt=None, block_t: int = 256,
                 block_c: Optional[int] = None,
                 interpret: Optional[bool] = None, lane_pad: int = 128,
                 mesh=None, axis_name: str = "data",
                 auto_attach: bool = True, registry=None, tracer=None,
                 name: Optional[str] = None, **backend_opts):
        self.capacity = int(capacity)
        self.default_m = float(m)
        # observability (repro.obs): process-call / samples-retired /
        # program-compile counters, labelled by engine instance; the
        # tracer records a compile instant when a new (capacity, T)
        # program shape is first executed
        self.registry = (MetricsRegistry() if registry is None
                         else registry)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.name = auto_name("engine") if name is None else str(name)
        lbl = {"engine": self.name}
        self._c_calls = self.registry.counter(
            "engine_process_calls_total",
            "process() chunk calls", ("engine",)).labels(**lbl)
        self._c_samples = self.registry.counter(
            "engine_samples_retired_total",
            "samples retired across all slots (per the caller's "
            "valid_lens)", ("engine",)).labels(**lbl)
        self._c_programs = self.registry.counter(
            "engine_programs_compiled_total",
            "distinct (capacity, T) program shapes executed",
            ("engine",)).labels(**lbl)
        # host mirror of the active-slot count, keyed by the identity
        # of state.active (replaced by attach/detach/reset/resize):
        # metrics never force an extra device fetch per call
        self._active_cache = (None, 0)
        # block_c tiles the kernel grid's channel axis into parallel
        # strips (multi-core TPU scaling at wide capacity); extra
        # keyword options flow to the backend factory untouched (e.g.
        # verdict=False selects the full-trajectory Q path)
        self.backend = get_backend(backend, m=m, fmt=fmt, block_t=block_t,
                                   block_c=block_c, interpret=interpret,
                                   lane_pad=lane_pad, **backend_opts)
        # aux-carrying backends (the detector ensemble) grow the packed
        # state by backend.aux_rows rows per slot and take per-slot
        # detector-selection weights + vote thresholds each call
        n_aux = int(getattr(self.backend, "aux_rows", 0) or 0)
        self._ensemble = n_aux > 0
        if self._ensemble and mesh is not None:
            raise ValueError(
                "mesh fan-out is not supported with the ensemble "
                "backend (the aux state axis is not sharded)")
        self.state = engine_init(self.capacity, self.backend.state_dtype,
                                 active=auto_attach, aux_rows=n_aux)
        if self._ensemble:
            self._det_names = tuple(self.backend.detectors)
            self._det_w = np.broadcast_to(
                np.asarray(self.backend.weights, np.float32)[:, None],
                (len(self._det_names), self.capacity)).copy()
            self._det_thr = np.full((self.capacity,),
                                    self.backend.default_threshold,
                                    np.float32)
        # per-slot outlier sensitivity, eq (6) m — float even on the Q
        # path (the backend quantizes m^2+1 itself)
        self._m = np.full((self.capacity,), self.default_m, np.float32)
        # chunk lengths this engine has executed: together with the
        # capacity, T keys the jit program cache, so a flat set after
        # warmup means no tick recompiles (the adaptive-chunk guarantee
        # surfaced through SlotPool.stats()["programs"])
        self._t_shapes: set = set()

        if self._ensemble:
            def core(x, k, mean, var, aux, vlen, m, sel, thr):
                st, outs = engine_process(
                    EngineState(k=k, mean=mean, var=var, active=vlen > 0,
                                aux=aux),
                    x, self.backend, m=m, valid_lens=vlen, sel=sel,
                    thr=thr)
                return ((st.k, st.mean, st.var, st.aux),
                        (outs["ecc"], outs["outlier"], outs["scores"]))
        else:
            def core(x, k, mean, var, vlen, m):
                st, outs = engine_process(
                    EngineState(k=k, mean=mean, var=var, active=vlen > 0),
                    x, self.backend, m=m, valid_lens=vlen)
                return ((st.k, st.mean, st.var),
                        (outs["ecc"], outs["outlier"]))

        self._mesh = mesh
        if mesh is not None:
            from repro.sharding.rules import make_channel_fanout
            n_shards = dict(mesh.shape)[axis_name]
            if self.capacity % n_shards:
                raise ValueError(
                    f"capacity {self.capacity} not divisible by mesh "
                    f"axis {axis_name!r} ({n_shards} shards)")
            core = make_channel_fanout(core, mesh, axis_name)
        self._fn = jax.jit(core)

    # ------------------------------------------------------ slot admin
    def attach(self, slots=None, n: Optional[int] = None, *,
               m: Optional[float] = None, detectors=None, vote=None):
        """Activate slots for new streams; returns the slot indices.

        With `slots=None`, grabs the first `n` free slots (all free
        slots when `n` is also None).  Attaching an occupied slot, or
        asking for slots on a full engine, raises with the current
        occupancy — JAX scatter silently drops out-of-range updates, so
        without the check a bad attach would look like a success while
        clobbering (or skipping) a live tenant.  `m` sets the new
        tenants' outlier sensitivity (default: the engine's `m`).

        Under the ensemble backend, `detectors` selects the subset of
        the backend's detectors these tenants run (default: all of
        them) and `vote` their vote mode / threshold fraction (default:
        the backend's) — see `set_detectors`.  Both raise on a
        non-ensemble backend.
        """
        occupied = np.asarray(self.state.active)
        n_act, cap = int(occupied.sum()), self.capacity
        if slots is None:
            free = np.flatnonzero(~occupied)
            if n is None and not len(free):
                raise ValueError(
                    f"no free slots: engine full ({n_act}/{cap} active)")
            if n is not None and len(free) < n:
                raise ValueError(
                    f"wanted {n} free slots, have {len(free)} "
                    f"({n_act}/{cap} active)")
            idx = free if n is None else free[:n]
        else:
            idx = np.atleast_1d(np.asarray(slots))
            busy = np.unique(idx[occupied[idx]]) if idx.size else idx
            if busy.size:
                raise ValueError(
                    f"slots {busy.tolist()} already attached "
                    f"({n_act}/{cap} active); detach or reset them first")
        self.state = engine_attach(self.state, idx)
        self._m[idx] = self.default_m if m is None else float(m)
        if detectors is not None or vote is not None:
            self.set_detectors(idx, detectors=detectors, vote=vote)
        elif self._ensemble:
            self._reset_detectors(np.asarray(
                slot_mask(idx, self.capacity)))
        return idx

    def detach(self, slots):
        self.state = engine_detach(self.state, slots)
        # recycled slots revert to the default sensitivity/detectors
        mask = np.asarray(slot_mask(slots, self.capacity))
        self._m[mask] = self.default_m
        if self._ensemble:
            self._reset_detectors(mask)

    def _reset_detectors(self, mask: np.ndarray) -> None:
        self._det_w[:, mask] = np.asarray(
            self.backend.weights, np.float32)[:, None]
        self._det_thr[mask] = self.backend.default_threshold

    def set_detectors(self, slots=None, *, detectors=None,
                      vote=None) -> None:
        """Re-select the detector subset / vote mode of live slots.

        `detectors` is a subset of the backend's ensemble members
        (None keeps all of them); unselected members get weight 0 on
        those slots — their state still advances (the shared fabric is
        detector-agnostic) but they contribute neither flags nor vote
        weight, so a masked slot is exactly a smaller ensemble.  `vote`
        is a mode name ("any" / "majority" / "all") or a weight
        fraction in (0, 1]; None keeps the backend's mode, re-evaluated
        over the *selected* weights.  Only valid under the ensemble
        backend.
        """
        if not self._ensemble:
            raise ValueError(
                f"backend {self.backend.name!r} has no detector "
                "ensemble; per-slot detectors need backend='ensemble'")
        from repro.detectors import vote_threshold
        mask = np.asarray(slot_mask(slots, self.capacity))
        if detectors is None:
            w = np.asarray(self.backend.weights, np.float32)
        else:
            chosen = ((detectors,) if isinstance(detectors, str)
                      else tuple(detectors))
            unknown = [d for d in chosen if d not in self._det_names]
            if unknown or not chosen:
                raise ValueError(
                    f"detectors must be a non-empty subset of this "
                    f"ensemble's members {list(self._det_names)}, got "
                    f"{detectors!r}")
            w = np.asarray(
                [self.backend.weights[d] if name in chosen else 0.0
                 for d, name in enumerate(self._det_names)], np.float32)
        thr = vote_threshold(self.backend.vote if vote is None else vote,
                             w)
        self._det_w[:, mask] = w[:, None]
        self._det_thr[mask] = thr

    def detector_config(self, slot: int) -> dict:
        """The live detector selection of one slot: {"detectors":
        selected member names, "weights": (K,) per-member weights,
        "threshold": the vote-weight threshold}."""
        if not self._ensemble:
            raise ValueError(
                f"backend {self.backend.name!r} has no detector "
                "ensemble")
        w = self._det_w[:, slot]
        return {"detectors": tuple(n for d, n in enumerate(self._det_names)
                                   if w[d] > 0),
                "weights": w.copy(),
                "threshold": float(self._det_thr[slot])}

    def reset(self, slots=None):
        self.state = engine_reset(self.state, slots)

    def set_m(self, slots, m) -> None:
        """Retune the outlier sensitivity of the selected slots.

        With integer `slots`, a vector `m` is matched positionally
        (`set_m([3, 1], [2.0, 5.0])` sets slot 3 to 2.0 and slot 1 to
        5.0); `slots` may also be None (all) or a bool mask.
        """
        m = np.asarray(m, np.float32)
        if slots is None:
            self._m[:] = m
            return
        slots = np.asarray(slots)
        if slots.dtype == bool:
            self._m[slots.reshape(self.capacity)] = m
            return
        idx = np.atleast_1d(slots).astype(int)
        if idx.size and (idx.min() < 0 or idx.max() >= self.capacity):
            raise IndexError(
                f"slot indices {np.unique(idx).tolist()} out of range "
                f"for capacity {self.capacity}")
        self._m[idx] = m

    # ------------------------------------------------------ processing
    def _active_mask_host(self) -> np.ndarray:
        """Host copy of the active mask, cached by the identity of
        `state.active` (which only attach/detach/reset/resize replace)
        so per-call metrics never add a device fetch to the hot path."""
        arr = self.state.active
        if self._active_cache[0] is not arr:
            self._active_cache = (arr, np.asarray(arr))
        return self._active_cache[1]

    def _account(self, t_len: int, vc, had_vlens: bool, active) -> None:
        """Update the obs instruments for one `process` call.

        `vc` is the concrete valid_lens (None when traced under an
        outer jit — the retired count is then unknowable on host and
        skipped; calls/programs still count).
        """
        t_key = int(t_len)
        if t_key not in self._t_shapes:
            self._t_shapes.add(t_key)
            self._c_programs.inc()
            if self.tracer.enabled:
                self.tracer.instant("engine.compile", engine=self.name,
                                    capacity=self.capacity, t=t_key)
        self._c_calls.inc()
        if had_vlens and vc is None:
            return
        amask = self._active_mask_host()
        if active is not None:
            amask = amask & np.asarray(slot_mask(active, self.capacity))
        if not had_vlens:
            retired = t_key * int(amask.sum())
        elif vc.ndim == 0:
            retired = int(vc) * int(amask.sum())
        else:
            retired = int(vc[amask].sum())
        if retired:
            self._c_samples.inc(retired)

    def process(self, x: jnp.ndarray, active=None,
                valid_lens=None) -> dict:
        """Feed one (T, capacity) chunk; returns per-sample verdicts.

        `valid_lens` makes the call ragged: a scalar or per-slot
        (capacity,) int vector, slot c retires exactly valid_lens[c]
        leading rows of its column (0..T) in this one fused call — its
        state freezes after its own prefix (bit-for-bit on the Q path)
        and it never flags beyond it.  vlen=0 is the suspend: frozen,
        no flags, still attached.

        `active` optionally restricts the call to a subset of slots (a
        bool mask or integer indices) — sugar for vlen=0 on everyone
        else, composable with `valid_lens`.  Detached slots are always
        held at vlen=0 regardless of either argument.

        The call is non-blocking: the returned `ecc`/`outlier` (and the
        carried state) are JAX async-dispatch futures, so a scheduler
        can overlap its next tick's host bookkeeping with the device
        compute and fetch verdicts only when it consumes them
        (`launch/batching.py`'s double-buffered loop).
        """
        x = jnp.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.capacity:
            raise ValueError(
                f"chunk must be (T, {self.capacity}), got {x.shape}")
        t_len = x.shape[0]
        st = self.state
        part = st.active if active is None else jnp.logical_and(
            st.active, slot_mask(active, self.capacity))
        vc = None
        if valid_lens is None:
            vl = jnp.full((self.capacity,), t_len, jnp.int32)
        else:
            vl = jnp.asarray(valid_lens, jnp.int32)
            try:
                vc = np.asarray(vl)  # concrete: host bounds check
            except Exception:
                vc = None  # traced under jit
            if vc is not None and vc.size and (
                    vc.min() < 0 or vc.max() > t_len):
                raise ValueError(
                    f"valid_lens must lie in [0, T={t_len}], got "
                    f"[{vc.min()}, {vc.max()}]")
            if vl.ndim == 0:
                vl = jnp.broadcast_to(vl, (self.capacity,))
            elif vl.shape != (self.capacity,):
                raise ValueError(
                    f"valid_lens must be scalar or ({self.capacity},), "
                    f"got {vl.shape}")
        vl = jnp.where(part, vl, 0)
        # uniform sensitivity keeps the kernels' scalar fast path (the
        # in-kernel verdict); only a genuinely mixed batch pays the
        # vector-m eq (6) re-evaluation.  The fan-out path shards m as
        # a (C,) vector, and the ensemble kernel broadcasts m itself,
        # so both always take the vector form.
        mv = self._m
        if self._mesh is None and not self._ensemble \
                and (mv == mv[0]).all():
            mv = mv[0]
        self._account(t_len, vc, valid_lens is not None, active)
        if self._ensemble:
            (k, mean, var, aux), (bits, vote, scores) = self._fn(
                x, st.k, st.mean, st.var, st.aux, vl,
                jnp.asarray(self.backend.quantize_m(mv)),
                jnp.asarray(self._det_w), jnp.asarray(self._det_thr))
            self.state = EngineState(k=k, mean=mean, var=var,
                                     active=st.active, aux=aux)
            # det_flags doubles as the backend-native "ecc" stream so
            # the serving stack's fetch plumbing stays structurally
            # unchanged; both keys alias the same array.  "scores" is
            # the (K, T, C) per-detector float score-stream block.
            return {"ecc": bits, "outlier": vote, "det_flags": bits,
                    "scores": scores}
        (k, mean, var), (ecc, outlier) = self._fn(
            x, st.k, st.mean, st.var, vl,
            jnp.asarray(self.backend.quantize_m(mv)))
        self.state = EngineState(k=k, mean=mean, var=var, active=st.active)
        return {"ecc": ecc, "outlier": outlier}

    # ------------------------------------------------------- introspection
    @property
    def active_slots(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.state.active))

    @property
    def samples_seen(self) -> np.ndarray:
        """Per-slot sample counts (the honest per-channel k)."""
        return np.asarray(self.state.k)

    @property
    def slot_m(self) -> np.ndarray:
        """Per-slot outlier sensitivity (eq (6) m), a (capacity,) copy."""
        return self._m.copy()

    @property
    def program_shapes(self) -> list:
        """Sorted chunk lengths T this engine has executed — each is
        one entry of the jit program cache at this capacity."""
        return sorted(self._t_shapes)

    def teda_state(self) -> TedaState:
        """The packed state in the `repro.core` TedaState layout."""
        return TedaState(k=self.state.k, mean=self.state.mean[:, None],
                         var=self.state.var)
