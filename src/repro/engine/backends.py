"""Detector backend registry: interchangeable TEDA executors.

One streaming contract — `process(x, k, mean, var)` over (T, C) chunks
of C independent univariate channel streams with per-channel carried
state — behind which the three TEDA implementations are interchangeable
(the composable-engine structure of fSEAD, evaluated under the
runtime-vs-efficacy lens of Choudhary et al.):

  * "scan"     — pure-JAX associative scan (`core/scan.py`); runs on any
                 backend, the portability baseline.
  * "pallas"   — float Pallas TPU kernel, slim verdict outputs (the
                 serving hot path; `kernels/teda_scan.py`).
  * "pallas-q" — bit-accurate Q-format integer Pallas kernel, the
                 paper's FPGA datapath verbatim (needs a `QFormat`).

Every backend carries state as honest per-channel (C,) vectors (k never
collapses to a shared scalar) and is chunk-exact: feeding a stream in
arbitrary chunk sizes reproduces the single-shot result (bit-for-bit on
the Q path, to float32 rounding on the float paths).

Register out-of-tree executors with `@register_backend("name")`; the
factory is called with the engine's backend options and must return an
object with `.state_dtype` and `.process`.  `listed=False` registers a
backend that `get_backend` resolves but `list_backends()` omits — the
"ensemble" multi-detector backend (`repro.detectors`) lives there: it
is a different detection algorithm, not another TEDA executor, so the
TEDA conformance suites that parametrize over `list_backends()` must
not pick it up (`list_backends(all=True)` includes it).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.scan import teda_scan
from repro.core.teda import TedaState
from repro.fixedpoint.qformat import QFormat
from repro.fixedpoint.teda_q import msq1_const
from repro.kernels.ops import (teda_q_scan_tpu, teda_q_scan_verdict,
                               teda_scan_verdict)

__all__ = ["Backend", "register_backend", "get_backend", "list_backends"]

_REGISTRY: Dict[str, Callable[..., "Backend"]] = {}
_LISTED: Set[str] = set()


class Backend:
    """Streaming detector contract.

    `process(x, k, mean, var, m=None, valid_lens=None)` consumes one
    (T, C) chunk with carried per-channel state vectors (C,) and
    returns `(k', mean', var', ecc, outlier)` — the advanced state plus
    (T, C) per-sample verdicts.  `m` overrides the constructed outlier
    threshold per call: a scalar, or a per-channel (C,) vector so every
    slot runs its own sensitivity level (per-tenant thresholds in one
    batch).  `valid_lens` (scalar or per-channel (C,) vector) restricts
    each channel to its leading vlen rows: one ragged call retires a
    different sample count per slot, each channel's state freezing
    after its own prefix exactly as if it ran alone (bit-for-bit on the
    Q path), and `outlier` is False at rows >= vlen[c]; `None` means
    the whole chunk is valid for every channel (the uniform fast case).
    `state_dtype` is the dtype of the packed state (int32 for the Q
    datapath, float32 otherwise); `ecc` is reported in the backend's
    native domain (Q int32 for "pallas-q") and is unspecified at ragged
    tail rows.
    """

    name: str = "abstract"
    state_dtype = jnp.float32

    def process(self, x: jnp.ndarray, k: jnp.ndarray, mean: jnp.ndarray,
                var: jnp.ndarray, m=None,
                valid_lens=None) -> Tuple[jnp.ndarray, ...]:
        raise NotImplementedError

    def quantize_m(self, m):
        """Host-side preparation of an m override before it is traced.

        The engine calls this *outside* jit so backends can do exact
        host arithmetic: the Q backend turns float m into its
        bit-exact msq1 ROM constant here (float32 tracing would round
        it).  Default: float32 as-is.
        """
        return np.asarray(m, np.float32)

    def _m(self, m):
        return self.m if m is None else m


def register_backend(name: str, listed: bool = True):
    """Decorator: register a backend factory under `name`.

    `listed=False` keeps the backend resolvable by `get_backend` but
    out of the default `list_backends()` enumeration (see module docs).
    """

    def deco(factory):
        _REGISTRY[name] = factory
        if listed:
            _LISTED.add(name)
        else:
            _LISTED.discard(name)
        return factory

    return deco


def get_backend(name: str, **opts) -> Backend:
    """Instantiate a registered backend with the engine's options."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {list_backends()}"
        ) from None
    return factory(**opts)


def list_backends(all: bool = False):
    return sorted(_REGISTRY) if all else sorted(_LISTED)


def _as_teda_state(k, mean, var) -> TedaState:
    return TedaState(k=k, mean=mean[:, None], var=var)


@register_backend("scan")
class ScanBackend(Backend):
    """Pure-JAX associative-scan TEDA (`core/scan.py`)."""

    name = "scan"
    state_dtype = jnp.float32

    def __init__(self, m: float = 3.0, **_ignored):
        self.m = m

    def process(self, x, k, mean, var, m=None, valid_lens=None):
        final, out = teda_scan(x[..., None], self._m(m),
                               _as_teda_state(k, mean, var),
                               valid_lens=valid_lens)
        return final.k, final.mean[:, 0], final.var, out.ecc, out.outlier


@register_backend("pallas")
class PallasBackend(Backend):
    """Float Pallas kernel, slim verdict outputs (the serving hot path)."""

    name = "pallas"
    state_dtype = jnp.float32

    def __init__(self, m: float = 3.0, block_t: int = 256,
                 block_c: Optional[int] = None,
                 interpret: Optional[bool] = None, lane_pad: int = 128,
                 **_ignored):
        self.m = m
        self.block_t = block_t
        self.block_c = block_c
        self.interpret = interpret
        self.lane_pad = lane_pad

    def process(self, x, k, mean, var, m=None, valid_lens=None):
        final, out = teda_scan_verdict(
            x, self._m(m), _as_teda_state(k, mean, var),
            valid_lens=valid_lens, block_t=self.block_t,
            block_c=self.block_c, interpret=self.interpret,
            lane_pad=self.lane_pad)
        return (final.k, final.mean[:, 0], final.var, out["ecc"],
                out["outlier"])


@register_backend("pallas-q")
class PallasQBackend(Backend):
    """Bit-accurate Q-format integer Pallas kernel (FPGA datapath)."""

    name = "pallas-q"
    state_dtype = jnp.int32

    def __init__(self, fmt: Optional[QFormat] = None, m: float = 3.0,
                 block_t: int = 256, block_c: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 lane_pad: int = 128, verdict: bool = True, **_ignored):
        if fmt is None:
            raise ValueError("backend 'pallas-q' needs fmt=QFormat(...)")
        fmt.validate()
        self.fmt = fmt
        self.m = m
        self.block_t = block_t
        self.block_c = block_c
        self.interpret = interpret
        self.lane_pad = lane_pad
        # verdict=True is the serving hot path: the slim kernel skips
        # the per-row mean/var HBM streams and the wrapper skips the
        # host-side (T, C) bit-serial threshold re-derivation the
        # engine never reads (both bit-exact; measured ~2x+ at wide C).
        # verdict=False keeps the full (T, C) Q trajectory for A/B
        # benches and offline analysis.
        self.verdict = verdict

    def quantize_m(self, m):
        """Exact host msq1 (int32 Q) — `teda_q_scan_tpu` takes integer
        m as the pre-quantized ROM constant, so per-slot thresholds get
        the same bits as a scalar-m run (no float32 tracing rounding)."""
        return np.asarray(msq1_const(self.fmt, np.asarray(m, np.float64)),
                          np.int32)

    def process(self, x, k, mean, var, m=None, valid_lens=None):
        scan = teda_q_scan_verdict if self.verdict else teda_q_scan_tpu
        final, out = scan(
            x, self.fmt, self._m(m), _as_teda_state(k, mean, var),
            valid_lens=valid_lens, block_t=self.block_t,
            block_c=self.block_c, interpret=self.interpret,
            lane_pad=self.lane_pad)
        return (final.k, final.mean[:, 0], final.var, out["ecc"],
                out["outlier"])


@register_backend("ensemble", listed=False)
def _ensemble_factory(**opts) -> Backend:
    """Lazy factory for the fused multi-detector ensemble backend —
    imported on first use so `repro.engine` does not pull the detector
    package (and its kernel) in at import time."""
    from repro.detectors.backend import EnsembleBackend
    return EnsembleBackend(**opts)
